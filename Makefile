# ohhc-qsort build entry points.
#
#   make build      release build of the rust crate
#   make test       tier-1 gate: cargo build --release && cargo test -q
#   make fmt        rustfmt across the tree (check with make fmt-check)
#   make lint       clippy (warnings denied) + the repolint invariant gate
#   make repolint   just the repo-invariant lint (SAFETY comments,
#                   wall-clock bans, spawn allowlist, unwrap ratchet)
#   make fuzz-schedules  the seeded schedule-fuzz smoke (64 seeds;
#                   a failure prints the seed to replay)
#   make miri       nightly: cargo miri test over the unsafe-bearing suites
#   make tsan       nightly: ThreadSanitizer over executor/cluster suites
#   make bench-json data-plane phase bench → BENCH_dataplane.json
#   make doc        rustdoc with warnings denied + doc-test run
#   make campaign   the acceptance-criteria campaign grid
#   make artifacts  lower the L1/L2 JAX graphs to artifacts/*.hlo.txt
#   make pytest     python kernel/model tests

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test fmt fmt-check lint repolint fuzz-schedules miri tsan bench bench-json doc campaign artifacts pytest clean

build:
	cd rust && $(CARGO) build --release

test: build
	cd rust && $(CARGO) test -q

fmt:
	cd rust && $(CARGO) fmt

fmt-check:
	cd rust && $(CARGO) fmt --check

lint: repolint
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

repolint:
	cd rust && $(CARGO) run --quiet --bin repolint

# Schedule-fuzz smoke: compile the interleave points in and sweep the
# race scenarios across 64 seeds.  A failing assertion names its seed;
# replay with `cargo test --features schedules <test> -- --nocapture`.
fuzz-schedules:
	cd rust && $(CARGO) test --features schedules -q

# Nightly-only sanitizers (CI runs these allowed-to-fail; locally they
# need `rustup +nightly component add miri rust-src`).
miri:
	cd rust && MIRIFLAGS="-Zmiri-disable-isolation -Zmiri-ignore-leaks" \
		$(CARGO) +nightly miri test --lib -- coordinator::divide util::par runtime service::ticket
	cd rust && MIRIFLAGS="-Zmiri-disable-isolation -Zmiri-ignore-leaks" \
		$(CARGO) +nightly miri test --test dataplane --test pipeline

tsan:
	cd rust && RUSTFLAGS="-Zsanitizer=thread" \
		$(CARGO) +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
		--lib -- runtime util::par service::ticket
	cd rust && RUSTFLAGS="-Zsanitizer=thread" \
		$(CARGO) +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
		--test cluster --test integration

bench:
	cd rust && OHHC_BENCH_FAST=1 $(CARGO) bench

# Non-criterion JSON benches: the data-plane phase medians (flat arena
# vs legacy nested, EXPERIMENTS.md §Perf), the service offered-load
# levels (jobs/sec + p50/p99, EXPERIMENTS.md §Service), the cluster
# shard-scaling sweep plus its degraded-mode blackout/recovery section
# (jobs/sec at 1/2/4/8 shards and healthy-vs-blackout at 4,
# EXPERIMENTS.md §Cluster and §Cluster chaos), the persistent-executor
# small-array / fan-out medians (pooled vs scoped spawn, EXPERIMENTS.md
# §Perf), the typestate-session vs monolithic pipeline medians
# (EXPERIMENTS.md §Perf), and the divide-strategy × distribution
# robustness grid (EXPERIMENTS.md §Adversarial).
bench-json:
	cd rust && OHHC_BENCH_JSON=../BENCH_dataplane.json $(CARGO) bench --bench dataplane
	cd rust && OHHC_BENCH_JSON=../BENCH_service.json $(CARGO) bench --bench service
	cd rust && OHHC_BENCH_JSON=../BENCH_cluster.json \
		OHHC_BENCH_CHAOS_JSON=../BENCH_cluster_chaos.json $(CARGO) bench --bench cluster
	cd rust && OHHC_BENCH_JSON=../BENCH_executor.json $(CARGO) bench --bench executor
	cd rust && OHHC_BENCH_JSON=../BENCH_pipeline.json $(CARGO) bench --bench pipeline
	cd rust && OHHC_BENCH_JSON=../BENCH_divide.json $(CARGO) bench --bench divide

# API docs gate: every public item documented, every intra-doc link
# resolving, and every doc example (including the pipeline typestate
# compile_fail) compiled/run.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps
	cd rust && $(CARGO) test --doc -q

campaign: build
	cd rust && $(CARGO) run --release -- campaign \
		--dims 1,2 --dists random,sorted,reverse \
		--sizes 1048576,4194304 --backends threaded,des \
		--out ../results/campaign.json --csv ../results/campaign.csv

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

pytest:
	$(PYTHON) -m pytest python/tests -q

clean:
	cd rust && $(CARGO) clean
	rm -rf results artifacts python/**/__pycache__
