//! The coordinator: the paper's end-to-end parallel Quick Sort (§3.2).
//!
//! 1. **Divide** (§3.1) — compute the SubDivider step point and bucket
//!    every key ([`divide_native`]); natively or through the XLA artifact.
//! 2. **Scatter** — hand each simulated processor its bucket.
//! 3. **Local sort + three-phase gather** — run the static schedule on the
//!    threaded backend (wall clock, the paper's method) or the DES
//!    (virtual time + link models).
//! 4. **Verify** — the reassembled output must be a sorted permutation of
//!    the input (checked on every run; the paper's "automatically sorted"
//!    claim is enforced, not assumed).

mod divide;
mod ohhc_sort;

pub use crate::dataplane::FlatBuckets;
pub use divide::{
    bucket_of, divide_native, divide_sampled, divide_with_engine, divide_with_strategy, BucketFn,
    Divided,
};
pub use ohhc_sort::{OhhcSorter, SeqBaseline, SortReport};
