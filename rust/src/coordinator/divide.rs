//! The array division procedure (paper §3.1).
//!
//! `SubDivider = (max − min) / P`; every key goes to bucket
//! `(v − min) / SubDivider` (clamped).  Because bucket index is monotone
//! in key value, concatenating sorted buckets in rank order yields the
//! sorted array — the property that lets the paper skip the merge phase.

use std::time::{Duration, Instant};

use crate::config::{DivideEngine, DivideStrategy};
use crate::dataplane::FlatBuckets;
use crate::error::{Error, Result};
use crate::runtime::{ArtifactRegistry, XlaDivide};
use crate::util::par;

/// Result of the division: arena-backed per-processor buckets, scattered
/// into their final bucket-rank positions (see [`FlatBuckets`]).
#[derive(Debug, Clone)]
pub struct Divided {
    /// The flat bucket arena, rank order.
    pub buckets: FlatBuckets,
    /// Global minimum key.
    pub lo: i32,
    /// The step point (≥ 1).
    pub sub: i32,
    /// Wall time of the scatter pass alone (arena placement writes) —
    /// lets the pipeline's [`crate::pipeline::StageTrace`] split the
    /// divide phase into classification vs scatter.
    pub scatter_time: Duration,
}

impl Divided {
    /// Bucket sizes in keys (what the DES needs) — O(P) off the offset
    /// table, no bucket walk.
    pub fn sizes(&self) -> Vec<usize> {
        self.buckets.sizes()
    }

    /// Largest bucket / ideal bucket — load-imbalance factor, O(P).
    pub fn imbalance(&self) -> f64 {
        self.buckets.imbalance()
    }
}

/// Pure-rust division (the default hot path), parallelized like a
/// single-level radix partition.  Each pass is one wave of tasks
/// submitted to the persistent executor pool — no thread is spawned
/// anywhere in here (the pre-executor version stood up three scoped
/// thread teams per divide, paid inside the timed region):
///
/// 1. a wave of min/max reduction tasks over chunks;
/// 2. a wave of per-chunk classify tasks (bucket ids + histograms),
///    merged into per-(chunk, bucket) write offsets by a small serial
///    prefix scan;
/// 3. a wave of scatter tasks, fused per chunk with pass 2's output:
///    each chunk's scatter task consumes the bucket ids its classify
///    task cached (no re-division) and writes its keys into *disjoint*
///    ranges of one preallocated arena ([`FlatBuckets`]), so no
///    synchronization is needed on the write path and no per-bucket
///    allocations exist at all.
///
/// See EXPERIMENTS.md §Perf for the before/after (the serial version made
/// the divide phase ~40% of the sorted-input parallel runtime; the arena
/// scatter then removed the per-bucket allocations and the gather-side
/// assemble memcpy; the executor then removed the three per-divide
/// thread-team spawns).
pub fn divide_native(data: &[i32], num_buckets: usize) -> Result<Divided> {
    if data.is_empty() {
        return Err(Error::Config("cannot divide an empty array".into()));
    }
    if num_buckets == 0 {
        return Err(Error::Config("need at least one bucket".into()));
    }
    let (workers, chunk_ranges) = scatter_chunks(data.len());

    // Pass 1: parallel min/max.
    let (lo, hi) = par::par_reduce_indices(
        data.len(),
        workers,
        |r| {
            let mut lo = data[r.start];
            let mut hi = lo;
            for &v in &data[r] {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            (lo, hi)
        },
        |a, b| (a.0.min(b.0), a.1.max(b.1)),
        (i32::MAX, i32::MIN),
    );
    let sub = (((hi as i64 - lo as i64) / num_buckets as i64).max(1)) as i32;

    // Pass 2: bucket ids (ONE division per key, cached as u16 — the
    // division is the dominant per-key cost) + per-chunk histograms, in
    // parallel chunks.
    debug_assert!(num_buckets <= u16::MAX as usize + 1);
    let classify = BucketFn::new(lo, sub, num_buckets);
    let per_chunk: Vec<(Vec<u16>, Vec<u32>)> =
        par::par_map(chunk_ranges.clone(), workers, |(s, e)| {
            let mut ids = Vec::with_capacity(e - s);
            let mut h = vec![0u32; num_buckets];
            for &v in &data[s..e] {
                let b = classify.of(v);
                ids.push(b as u16);
                h[b] += 1;
            }
            (ids, h)
        });

    // Serial prefix scan: per-(chunk, bucket) offsets + bucket sizes.
    let (offsets, hist) = chunk_write_offsets(per_chunk.iter().map(|(_, h)| h), num_buckets);

    // Bucket offset table: exclusive prefix sum of the histogram.  This
    // is the whole gather-side bookkeeping — bucket b's final resting
    // place in the sorted output is arena[table[b]..table[b + 1]].
    let mut table = Vec::with_capacity(num_buckets + 1);
    let mut acc = 0usize;
    table.push(0);
    for &h in &hist {
        acc += h;
        table.push(acc);
    }
    debug_assert_eq!(acc, data.len());

    // Pass 3: parallel scatter through the cached ids (no re-division, no
    // zero-initialization) straight into one contiguous arena.  Each
    // chunk owns a disjoint [table[b] + offset, table[b] + offset + count)
    // range of every bucket's segment, so the raw writes never alias;
    // every slot is written exactly once, justifying the deferred
    // `set_len`.
    let scatter_t0 = Instant::now();
    let mut arena: Vec<i32> = Vec::with_capacity(data.len());
    {
        let ptr = ArenaPtr(arena.as_mut_ptr());
        let work: Vec<((usize, usize), (Vec<u16>, Vec<u32>), Vec<usize>)> = chunk_ranges
            .into_iter()
            .zip(per_chunk)
            .zip(offsets)
            .map(|((r, pc), o)| (r, pc, o))
            .collect();
        let ptr_ref = &ptr;
        let table_ref = &table;
        let final_cursors = par::par_map(work, workers, move |((s, e), (ids, _), mut offs)| {
            for (&v, &b) in data[s..e].iter().zip(&ids) {
                let b = b as usize;
                debug_assert!(
                    table_ref[b] + offs[b] < table_ref[b + 1],
                    "scatter overran bucket {b}: cursor {} at segment end {}",
                    table_ref[b] + offs[b],
                    table_ref[b + 1]
                );
                // SAFETY: table[b] + offs[b] stays inside bucket b's
                // chunk-private range (prefix-scan construction above,
                // span-checked per write in debug builds).
                unsafe { ptr_ref.0.add(table_ref[b] + offs[b]).write(v) };
                offs[b] += 1;
            }
            offs
        });
        // Cross-check of the written-slot count: the prefix scan seeds
        // each chunk's cursors where the previous chunk ends, so the
        // last chunk must finish exactly at every bucket's occupancy —
        // i.e. all `data.len()` slots written once, none skipped.  The
        // asserts compile out of release builds.
        if let Some(last) = final_cursors.last() {
            for b in 0..num_buckets {
                debug_assert_eq!(
                    table[b] + last[b],
                    table[b + 1],
                    "bucket {b}: scatter wrote {} of {} slots",
                    last[b],
                    table[b + 1] - table[b]
                );
            }
        }
    }
    // SAFETY: capacity is exactly `data.len()` and every slot was written.
    unsafe { arena.set_len(data.len()) };
    let scatter_time = scatter_t0.elapsed();
    let buckets = FlatBuckets::from_parts(arena, table);
    Ok(Divided {
        buckets,
        lo,
        sub,
        scatter_time,
    })
}

/// Below this input length the parallel machinery is pure overhead.
#[cfg(not(miri))]
const CHUNK_MIN: usize = 64 * 1024;
/// Under Miri every instruction costs orders of magnitude more, so the
/// chunk floor drops: the multi-chunk parallel scatter — the unsafe
/// path worth interpreting — stays covered at tractable input sizes.
#[cfg(miri)]
const CHUNK_MIN: usize = 256;

/// Shared raw arena pointer for the scatter waves.
struct ArenaPtr(*mut i32);
// SAFETY (Send/Sync): one buffer that outlives the pooled scatter tasks;
// write disjointness comes from the chunk-private offset ranges within
// each bucket's arena segment (see the callers' prefix-scan setup).
unsafe impl Send for ArenaPtr {}
unsafe impl Sync for ArenaPtr {}

/// Chunk `0..len` for the scatter passes: at most `available_workers()`
/// spans of at least [`CHUNK_MIN`] keys each.  Shared by the native
/// divide and the XLA id-scatter so the "disjoint chunk-private range"
/// construction has exactly one definition.
fn scatter_chunks(len: usize) -> (usize, Vec<(usize, usize)>) {
    let workers = par::available_workers().clamp(1, len.div_ceil(CHUNK_MIN).max(1));
    let chunk_len = len.div_ceil(workers);
    let ranges = (0..workers)
        .map(|w| (w * chunk_len, ((w + 1) * chunk_len).min(len)))
        .filter(|(s, e)| s < e)
        .collect();
    (workers, ranges)
}

/// Serial prefix scan over per-chunk bucket histograms: returns each
/// chunk's private write offset inside every bucket segment, plus the
/// total occupancy per bucket (the running sum after the last chunk).
fn chunk_write_offsets(
    hists: impl Iterator<Item = &Vec<u32>>,
    num_buckets: usize,
) -> (Vec<Vec<usize>>, Vec<usize>) {
    let mut offsets = Vec::new();
    let mut running = vec![0usize; num_buckets];
    for ch in hists {
        offsets.push(running.clone());
        for (b, &c) in ch.iter().enumerate() {
            running[b] += c as usize;
        }
    }
    (offsets, running)
}

/// Bucket index of one key.
#[inline(always)]
pub fn bucket_of(v: i32, lo: i32, sub: i32, num_buckets: usize) -> usize {
    (((v as i64 - lo as i64) / sub as i64) as usize).min(num_buckets - 1)
}

/// Division-free bucket classifier (Lemire & Kaser): for 32-bit
/// `x = v − lo` and divisor `d`, `x / d == (⌈2⁶⁴/d⌉ · x) >> 64` exactly.
/// A hardware `div` costs ~26 cycles per key; this is two multiplies.
#[derive(Debug, Clone, Copy)]
pub struct BucketFn {
    lo: i32,
    magic: u64, // 0 marks the sub == 1 fast path
    max_bucket: usize,
}

impl BucketFn {
    /// Build the classifier for a step point.
    pub fn new(lo: i32, sub: i32, num_buckets: usize) -> Self {
        debug_assert!(sub >= 1);
        BucketFn {
            lo,
            magic: if sub == 1 {
                0
            } else {
                u64::MAX / sub as u64 + 1
            },
            max_bucket: num_buckets - 1,
        }
    }

    /// Bucket of one key.
    #[inline(always)]
    pub fn of(&self, v: i32) -> usize {
        let x = (v as i64 - self.lo as i64) as u64; // < 2^32
        let q = if self.magic == 0 {
            x
        } else {
            ((self.magic as u128 * x as u128) >> 64) as u64
        };
        (q as usize).min(self.max_bucket)
    }
}

/// Parallel scatter over precomputed per-key bucket ids — the XLA
/// branch's counterpart of `divide_native` pass 3.  A wave of per-chunk
/// counting tasks rebuilds chunk-local histograms from the ids (no
/// re-division), a serial prefix scan turns them into chunk-private
/// write offsets, and a scatter wave writes every chunk's keys into
/// disjoint arena ranges.  This replaces the serial O(n) cursor walk the
/// XLA path used to pay after the kernel returned.
///
/// Malformed ids are an invariant **error**, never a panic or UB: the
/// id array length, the per-id bucket range, and the id-derived bucket
/// occupancy are all validated against `table` before any raw write.
fn scatter_by_ids(data: &[i32], ids: &[u32], table: &[usize]) -> Result<Vec<i32>> {
    let num_buckets = table.len() - 1;
    if ids.len() != data.len() {
        return Err(Error::Invariant(format!(
            "id/key length mismatch: {} ids for {} keys",
            ids.len(),
            data.len()
        )));
    }
    let (workers, chunk_ranges) = scatter_chunks(data.len());

    let per_chunk: Vec<(Vec<u32>, usize)> = par::par_map(chunk_ranges.clone(), workers, |(s, e)| {
        let mut h = vec![0u32; num_buckets];
        let mut out_of_range = 0usize;
        for &b in &ids[s..e] {
            match h.get_mut(b as usize) {
                Some(count) => *count += 1,
                None => out_of_range += 1,
            }
        }
        (h, out_of_range)
    });
    let out_of_range: usize = per_chunk.iter().map(|(_, bad)| *bad).sum();
    if out_of_range > 0 {
        return Err(Error::Invariant(format!(
            "{out_of_range} bucket ids out of range (>= {num_buckets})"
        )));
    }

    let (offsets, placed) = chunk_write_offsets(per_chunk.iter().map(|(h, _)| h), num_buckets);
    // The id-derived occupancy must agree with the offset table, or the
    // "disjoint chunk-private ranges" argument below does not hold.
    for b in 0..num_buckets {
        if placed[b] != table[b + 1] - table[b] {
            return Err(Error::Invariant(format!(
                "bucket {b}: ids place {} keys, histogram reserved {}",
                placed[b],
                table[b + 1] - table[b]
            )));
        }
    }

    let mut arena: Vec<i32> = Vec::with_capacity(data.len());
    {
        let ptr = ArenaPtr(arena.as_mut_ptr());
        let ptr_ref = &ptr;
        let work: Vec<((usize, usize), Vec<usize>)> =
            chunk_ranges.into_iter().zip(offsets).collect();
        let final_cursors = par::par_map(work, workers, move |((s, e), mut offs)| {
            for (&v, &b) in data[s..e].iter().zip(&ids[s..e]) {
                let b = b as usize;
                debug_assert!(
                    table[b] + offs[b] < table[b + 1],
                    "id-scatter overran bucket {b}: cursor {} at segment end {}",
                    table[b] + offs[b],
                    table[b + 1]
                );
                // SAFETY: table[b] + offs[b] stays inside bucket b's
                // chunk-private range (prefix-scan construction, verified
                // against `table` above, span-checked per write in debug
                // builds).
                unsafe { ptr_ref.0.add(table[b] + offs[b]).write(v) };
                offs[b] += 1;
            }
            offs
        });
        // Written-slot cross-check, mirroring the native scatter: the
        // last chunk's final cursors must land on each bucket's
        // occupancy exactly.
        if let Some(last) = final_cursors.last() {
            for b in 0..num_buckets {
                debug_assert_eq!(
                    table[b] + last[b],
                    table[b + 1],
                    "bucket {b}: id-scatter wrote {} of {} slots",
                    last[b],
                    table[b + 1] - table[b]
                );
            }
        }
    }
    // SAFETY: capacity is exactly `data.len()` and every slot was written.
    unsafe { arena.set_len(data.len()) };
    Ok(arena)
}

/// Division through the configured engine.  The XLA path runs the AOT
/// Pallas partition kernel via PJRT and scatters on the returned ids
/// with the same chunked prefix-scan scatter as the native path.
pub fn divide_with_engine(
    data: &[i32],
    num_buckets: usize,
    engine: DivideEngine,
    registry: Option<&ArtifactRegistry>,
) -> Result<Divided> {
    match engine {
        DivideEngine::Native => divide_native(data, num_buckets),
        DivideEngine::Xla => {
            let reg = registry.ok_or_else(|| {
                Error::Artifact("XLA divide engine requires an artifact registry".into())
            })?;
            let xd = XlaDivide::new(reg, num_buckets)?;
            let out = xd.divide(data)?;
            let mut table = Vec::with_capacity(num_buckets + 1);
            let mut acc = 0usize;
            table.push(0);
            for &h in &out.hist {
                acc += h;
                table.push(acc);
            }
            if acc != data.len() || out.ids.len() != data.len() {
                return Err(Error::Invariant(format!(
                    "XLA divide shape mismatch: {} ids, histogram covers {acc} of {} keys",
                    out.ids.len(),
                    data.len()
                )));
            }
            let scatter_t0 = Instant::now();
            let arena = scatter_by_ids(data, &out.ids, &table)?;
            let scatter_time = scatter_t0.elapsed();
            Ok(Divided {
                buckets: FlatBuckets::from_parts(arena, table),
                lo: out.lo,
                sub: out.sub,
                scatter_time,
            })
        }
    }
}

/// Sampling-based division (PSRS / hyperquicksort style): a regular
/// `p·(p−1)` sample of the input is sorted and its `p−1` quantiles
/// become the bucket splitters, so boundaries adapt to the *observed*
/// distribution instead of trusting the value range.  Keys route by
/// binary search over the splitters; keys equal to a tied splitter run
/// are spread round-robin across the tied bucket range (legal because
/// equal keys sort equal — concatenation stays sorted), which is what
/// keeps few-uniques and Zipf heads from collapsing onto one processor.
/// The scatter reuses the same chunked prefix-scan arena writes as the
/// native path ([`scatter_by_ids`]).
///
/// `Divided::lo`/`sub` have no step-point meaning here: `lo` is the
/// sample minimum and `sub` is 1 (only the paper-fixed rule has a real
/// step point; nothing downstream consumes these for splitter divides).
pub fn divide_sampled(data: &[i32], num_buckets: usize) -> Result<Divided> {
    if data.is_empty() {
        return Err(Error::Config("cannot divide an empty array".into()));
    }
    if num_buckets == 0 {
        return Err(Error::Config("need at least one bucket".into()));
    }
    let p = num_buckets;

    // Regular sample: p·(p−1) evenly spaced positions (clamped to n —
    // small inputs are sampled exhaustively, making the splitters exact
    // quantiles).
    let want = (p * p.saturating_sub(1)).clamp(1, data.len());
    let mut sample: Vec<i32> = (0..want).map(|k| data[k * data.len() / want]).collect();
    sample.sort_unstable();
    let splitters: Vec<i32> = (1..p).map(|k| sample[k * sample.len() / p]).collect();
    let lo = sample[0];

    // Classify: bucket = #splitters strictly below the key, ties spread
    // round-robin over the tied range.  Per-chunk ids + histograms, same
    // wave shape as the native pass 2.
    let (workers, chunk_ranges) = scatter_chunks(data.len());
    let splitters_ref = &splitters;
    let per_chunk: Vec<(Vec<u32>, Vec<u32>)> =
        par::par_map(chunk_ranges.clone(), workers, move |(s, e)| {
            let mut ids = Vec::with_capacity(e - s);
            let mut h = vec![0u32; p];
            // Round-robin cursor per tied splitter run, keyed by the run's
            // first bucket (a run never starts at bucket p−1, but sizing by
            // p keeps the indexing trivially in range).
            let mut rr = vec![0u32; p];
            for &v in &data[s..e] {
                let first = splitters_ref.partition_point(|&sp| sp < v);
                let last = splitters_ref.partition_point(|&sp| sp <= v);
                let b = if first == last {
                    first
                } else {
                    let span = (last - first + 1) as u32;
                    let r = rr[first];
                    rr[first] = (r + 1) % span;
                    first + r as usize
                };
                ids.push(b as u32);
                h[b] += 1;
            }
            (ids, h)
        });

    // Offset table from the summed histograms, then the shared validated
    // scatter.
    let mut table = Vec::with_capacity(p + 1);
    let mut acc = 0usize;
    table.push(0);
    for b in 0..p {
        acc += per_chunk.iter().map(|(_, h)| h[b] as usize).sum::<usize>();
        table.push(acc);
    }
    debug_assert_eq!(acc, data.len());
    let ids: Vec<u32> = per_chunk.into_iter().flat_map(|(ids, _)| ids).collect();
    let scatter_t0 = Instant::now();
    let arena = scatter_by_ids(data, &ids, &table)?;
    let scatter_time = scatter_t0.elapsed();
    Ok(Divided {
        buckets: FlatBuckets::from_parts(arena, table),
        lo,
        sub: 1,
        scatter_time,
    })
}

/// Division under a [`DivideStrategy`].  Returns the division plus the
/// number of skew re-divides it took (0 or 1 — only
/// [`DivideStrategy::Adaptive`] ever re-divides, when the paper-fixed
/// imbalance breaches [`DivideStrategy::SKEW_GUARDRAIL`]).
///
/// The sampling path is native-only (the XLA artifact bakes in the
/// paper's step-point kernel); `engine` applies to the paper-fixed rule
/// and to the adaptive strategy's first attempt.
pub fn divide_with_strategy(
    data: &[i32],
    num_buckets: usize,
    strategy: DivideStrategy,
    engine: DivideEngine,
    registry: Option<&ArtifactRegistry>,
) -> Result<(Divided, u32)> {
    match strategy {
        DivideStrategy::PaperFixed => {
            Ok((divide_with_engine(data, num_buckets, engine, registry)?, 0))
        }
        DivideStrategy::RegularSampling => Ok((divide_sampled(data, num_buckets)?, 0)),
        DivideStrategy::Adaptive => {
            let fixed = divide_with_engine(data, num_buckets, engine, registry)?;
            if fixed.imbalance() > DivideStrategy::SKEW_GUARDRAIL {
                Ok((divide_sampled(data, num_buckets)?, 1))
            } else {
                Ok((fixed, 0))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::workload;

    /// Size-heavy tests shrink under Miri; with the reduced
    /// [`CHUNK_MIN`] the shrunken inputs still drive the multi-chunk
    /// scatter, so the raw-pointer writes run under the interpreter.
    fn n(full: usize) -> usize {
        if cfg!(miri) {
            full / 50
        } else {
            full
        }
    }

    #[test]
    fn conservation_and_order_preservation() {
        for dist in Distribution::ALL {
            let data = workload::generate(dist, n(50_000), 3);
            let d = divide_native(&data, 36).unwrap();
            assert_eq!(d.buckets.total_keys(), data.len(), "{dist:?}");
            assert_eq!(d.sizes().iter().sum::<usize>(), data.len(), "{dist:?}");
            // Cross-bucket order: max(bucket b) <= min(bucket b+1).
            let mut last_max = i64::MIN;
            for b in d.buckets.iter() {
                if b.is_empty() {
                    continue;
                }
                let mn = *b.iter().min().unwrap() as i64;
                let mx = *b.iter().max().unwrap() as i64;
                assert!(mn >= last_max, "{dist:?}: bucket order violated");
                last_max = mx;
            }
        }
    }

    #[test]
    fn in_place_sorted_arena_is_globally_sorted() {
        let data = workload::random(n(20_000), 9);
        let mut d = divide_native(&data, 144).unwrap();
        for seg in d.buckets.segments_mut() {
            seg.sort_unstable();
        }
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(d.buckets.arena(), expect.as_slice());
    }

    #[test]
    fn constant_array_lands_in_bucket_zero() {
        let data = vec![42i32; 1000];
        let d = divide_native(&data, 36).unwrap();
        assert_eq!(d.sub, 1);
        assert_eq!(d.buckets.size(0), 1000);
        assert!((1..36).all(|b| d.buckets.size(b) == 0));
    }

    #[test]
    fn imbalance_is_near_one_for_uniform_ramp() {
        // The floor in `SubDivider = (max-min)/P` spills a sliver of the
        // top of the range into the last bucket (clamped), so perfect 1.0
        // is unattainable — the paper's procedure has the same property.
        let data: Vec<i32> = (0..36_000).collect();
        let d = divide_native(&data, 36).unwrap();
        assert!(d.imbalance() < 1.05, "{}", d.imbalance());
    }

    #[test]
    fn sorted_input_gives_contiguous_buckets() {
        let data = workload::sorted(n(10_000), 5);
        let d = divide_native(&data, 18).unwrap();
        // The arena in rank order equals the input directly.
        assert_eq!(d.buckets.arena(), data.as_slice());
    }

    #[test]
    fn rejects_empty_input() {
        assert!(divide_native(&[], 6).is_err());
        assert!(divide_native(&[1], 0).is_err());
    }

    #[test]
    fn bucket_fn_matches_division_exhaustively() {
        // The Lemire reciprocal must agree with the i64 division for every
        // (value, step-point) combination we can throw at it.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD117);
        let (step_points, probes) = if cfg!(miri) { (8, 40) } else { (200, 300) };
        for _ in 0..step_points {
            let lo = rng.range_i64(i32::MIN as i64, i32::MAX as i64 - 10) as i32;
            let span = rng.range_i64(1, (i32::MAX as i64 - lo as i64).min(1 << 31)) as i64;
            let p = 1 + rng.below(3000) as usize;
            let sub = ((span / p as i64).max(1)) as i32;
            let f = BucketFn::new(lo, sub, p);
            for _ in 0..probes {
                let v = (lo as i64 + rng.below(span as u64 + 1) as i64) as i32;
                assert_eq!(
                    f.of(v),
                    bucket_of(v, lo, sub, p),
                    "lo={lo} sub={sub} p={p} v={v}"
                );
            }
            // Boundary values.
            for v in [lo, (lo as i64 + span) as i32] {
                assert_eq!(f.of(v), bucket_of(v, lo, sub, p));
            }
        }
    }

    #[test]
    fn scatter_by_ids_matches_the_native_arena() {
        // The XLA branch's parallel scatter must land every key exactly
        // where the native pass-3 scatter does, given the same ids.
        for dist in Distribution::ALL {
            let data = workload::generate(dist, n(30_000), 13);
            let d = divide_native(&data, 36).unwrap();
            let classify = BucketFn::new(d.lo, d.sub, 36);
            let ids: Vec<u32> = data.iter().map(|&v| classify.of(v) as u32).collect();
            let table = d.buckets.offsets().to_vec();
            let arena = scatter_by_ids(&data, &ids, &table).unwrap();
            assert_eq!(arena.as_slice(), d.buckets.arena(), "{dist:?}");
        }
    }

    #[test]
    fn scatter_by_ids_rejects_malformed_ids_without_panicking() {
        // Ids that disagree with the reserved segment sizes must be an
        // invariant error before any raw write happens.
        let data = vec![1, 2, 3, 4];
        let ids = vec![0u32, 0, 0, 1];
        let table = vec![0usize, 2, 4]; // reserves 2 + 2, ids place 3 + 1
        assert!(scatter_by_ids(&data, &ids, &table).is_err());
        // Out-of-range bucket ids (a corrupt artifact) and a short id
        // array are errors too, not index panics in a pool task.
        assert!(scatter_by_ids(&data, &[0, 1, 2, 0], &table).is_err());
        assert!(scatter_by_ids(&data, &[0, 0], &table).is_err());
    }

    #[test]
    fn local_distribution_is_better_balanced_than_random_is_not() {
        // Both local and random spread roughly uniformly over the range —
        // the paper's observation that they behave alike (§6.2).
        let r = divide_native(&workload::random(n(100_000), 1), 36).unwrap();
        let l = divide_native(&workload::local_distribution(n(100_000), 1), 36).unwrap();
        assert!(r.imbalance() < 1.5);
        assert!(l.imbalance() < 1.5);
    }

    #[test]
    fn sampled_conservation_and_order_on_every_distribution() {
        for dist in Distribution::ALL.iter().chain(&Distribution::ADVERSARIAL) {
            let data = workload::generate(*dist, n(50_000), 3);
            let d = divide_sampled(&data, 36).unwrap();
            assert_eq!(d.buckets.total_keys(), data.len(), "{dist:?}");
            // Cross-bucket order still holds (equal keys may straddle
            // adjacent buckets — concatenation stays sorted).
            let mut last_max = i64::MIN;
            for b in d.buckets.iter() {
                if b.is_empty() {
                    continue;
                }
                let mn = *b.iter().min().unwrap() as i64;
                let mx = *b.iter().max().unwrap() as i64;
                assert!(mn >= last_max, "{dist:?}: bucket order violated");
                last_max = mx;
            }
            // Sorting segments in place sorts the arena globally.
            let mut d = d;
            for seg in d.buckets.segments_mut() {
                seg.sort_unstable();
            }
            let mut expect = data;
            expect.sort_unstable();
            assert_eq!(d.buckets.arena(), expect.as_slice(), "{dist:?}");
        }
    }

    #[test]
    fn sampled_stays_balanced_where_step_points_collapse() {
        // The acceptance headline at unit scope: anti_pivot dumps all but
        // one key into bucket 0 under the fixed rule; sampled splitters
        // keep max bucket ≤ 2× ideal.
        let data = workload::generate(Distribution::AntiPivot, n(60_000), 7);
        let fixed = divide_native(&data, 144).unwrap();
        let sampled = divide_sampled(&data, 144).unwrap();
        assert!(fixed.imbalance() > 2.0, "attack failed: {}", fixed.imbalance());
        assert!(sampled.imbalance() <= 2.0, "{}", sampled.imbalance());
    }

    #[test]
    fn sampled_splits_heavy_duplicates_across_tied_buckets() {
        // A constant array is the extreme duplicate case: round-robin tie
        // routing must spread it near-evenly instead of bucket 0.
        let data = vec![42i32; n(36_000)];
        let d = divide_sampled(&data, 36).unwrap();
        assert!(d.imbalance() <= 1.5, "{}", d.imbalance());
        assert_eq!(d.buckets.total_keys(), n(36_000));
    }

    #[test]
    fn sampled_edge_cases() {
        assert!(divide_sampled(&[], 6).is_err());
        assert!(divide_sampled(&[1], 0).is_err());
        // One bucket, fewer keys than processors — both legal.
        let d = divide_sampled(&[3, 1, 2], 1).unwrap();
        assert_eq!(d.buckets.size(0), 3);
        let d = divide_sampled(&[5, 4], 36).unwrap();
        assert_eq!(d.buckets.total_keys(), 2);
    }

    #[test]
    fn strategy_dispatch_counts_redivides() {
        let attack = workload::generate(Distribution::AntiPivot, n(40_000), 5);
        let friendly = workload::random(n(40_000), 5);

        // PaperFixed and RegularSampling never re-divide.
        let (d, r) = divide_with_strategy(
            &attack,
            36,
            DivideStrategy::PaperFixed,
            DivideEngine::Native,
            None,
        )
        .unwrap();
        assert_eq!(r, 0);
        assert!(d.imbalance() > DivideStrategy::SKEW_GUARDRAIL);
        let (d, r) = divide_with_strategy(
            &attack,
            36,
            DivideStrategy::RegularSampling,
            DivideEngine::Native,
            None,
        )
        .unwrap();
        assert_eq!(r, 0);
        assert!(d.imbalance() <= 2.0);

        // Adaptive: exactly one re-divide on the attack, none on friendly
        // input — and the friendly division is bit-identical to the
        // paper-fixed one (the guardrail never fires).
        let (d, r) = divide_with_strategy(
            &attack,
            36,
            DivideStrategy::Adaptive,
            DivideEngine::Native,
            None,
        )
        .unwrap();
        assert_eq!(r, 1);
        assert!(d.imbalance() <= 2.0);
        let (d, r) = divide_with_strategy(
            &friendly,
            36,
            DivideStrategy::Adaptive,
            DivideEngine::Native,
            None,
        )
        .unwrap();
        assert_eq!(r, 0);
        let fixed = divide_native(&friendly, 36).unwrap();
        assert_eq!(d.buckets.arena(), fixed.buckets.arena());
        assert_eq!(d.buckets.offsets(), fixed.buckets.offsets());
    }
}
