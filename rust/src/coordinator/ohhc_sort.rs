//! End-to-end OHHC parallel Quick Sort driver.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{Backend, ExperimentConfig};
use crate::coordinator::divide::{divide_with_engine, Divided};
use crate::error::{Error, Result};
use crate::runtime::ArtifactRegistry;
use crate::schedule::TopologyBundle;
use crate::sim::engine::{DesOutcome, DesSimulator};
use crate::sim::threaded::{ThreadMode, ThreadedSimulator};
use crate::sort::{is_sorted, quicksort, SortCounters};
use crate::topology::ohhc::Ohhc;
use crate::workload::Workload;

/// Everything one experiment run produces — the raw material for every
/// figure in the paper's §6.
#[derive(Debug)]
pub struct SortReport {
    /// Keys sorted.
    pub elements: usize,
    /// Total processors simulated.
    pub processors: usize,
    /// Wall time of the sequential baseline on the same input.
    pub sequential_time: Duration,
    /// Wall time of the parallel run (divide + scatter + sort + gather).
    pub parallel_time: Duration,
    /// Wall time of the divide phase alone.
    pub divide_time: Duration,
    /// Summed local-sort counters (parallel run).
    pub counters: SortCounters,
    /// Counters of the sequential baseline.
    pub sequential_counters: SortCounters,
    /// Load imbalance factor of the division.
    pub imbalance: f64,
    /// DES virtual completion time (ns), when the DES backend ran.
    pub des_completion_ns: Option<f64>,
    /// DES communication steps `(electrical, optical)`.
    pub des_steps: Option<(usize, usize)>,
    /// Full DES communication trace (for `--trace-out` export).
    pub des_trace: Option<crate::sim::trace::CommTrace>,
    /// Relative speedup `T_s / T_p`.
    pub speedup: f64,
    /// The paper's percentage presentation: `(T_s - T_p) / T_s · 100`.
    pub speedup_pct: f64,
    /// Efficiency `T_s / (P · T_p)`.
    pub efficiency: f64,
}

/// What one backend run contributes to the report.
struct BackendOutcome {
    parallel_time: Duration,
    counters: SortCounters,
    des: Option<DesOutcome>,
}

/// A measured sequential baseline (paper Fig 6.1): the sorted reference
/// output plus its wall time and counters.  Reusable across every run on
/// the same workload — the campaign engine memoizes one per
/// `(distribution, elements, seed)` fingerprint.
#[derive(Debug, Clone)]
pub struct SeqBaseline {
    /// The input sorted by the instrumented sequential Quick Sort.
    pub sorted: Vec<i32>,
    /// Wall time of that sort.
    pub time: Duration,
    /// Its instruction counters.
    pub counters: SortCounters,
}

impl SeqBaseline {
    /// Measure the baseline on one input.
    pub fn measure(data: &[i32]) -> Self {
        let mut sorted = data.to_vec();
        let t0 = Instant::now();
        let counters = quicksort(&mut sorted);
        let time = t0.elapsed();
        debug_assert!(is_sorted(&sorted));
        SeqBaseline { sorted, time, counters }
    }
}

/// Reusable experiment driver over a shared topology bundle.
///
/// `new` builds a private bundle (the historical one-shot behaviour);
/// `with_bundle` injects a shared `Arc<TopologyBundle>` so sweeps reuse
/// one topology + plan construction across many runs — the contract the
/// [`crate::campaign`] engine builds on.
pub struct OhhcSorter {
    cfg: ExperimentConfig,
    bundle: Arc<TopologyBundle>,
    registry: Option<ArtifactRegistry>,
}

impl OhhcSorter {
    /// Construct for a validated configuration, building a fresh topology.
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let bundle = Arc::new(TopologyBundle::build(cfg.dimension, cfg.construction)?);
        Self::with_bundle(cfg, bundle)
    }

    /// Construct over a pre-built (typically cached and shared) bundle.
    pub fn with_bundle(cfg: &ExperimentConfig, bundle: Arc<TopologyBundle>) -> Result<Self> {
        cfg.validate()?;
        if bundle.key() != (cfg.dimension, cfg.construction) {
            return Err(Error::Config(format!(
                "bundle is for (d={}, {}), config wants (d={}, {})",
                bundle.net.dimension,
                bundle.net.construction.label(),
                cfg.dimension,
                cfg.construction.label()
            )));
        }
        let registry = match cfg.divide_engine {
            crate::config::DivideEngine::Xla => Some(ArtifactRegistry::open(&cfg.artifact_dir)?),
            crate::config::DivideEngine::Native => None,
        };
        Ok(OhhcSorter {
            cfg: cfg.clone(),
            bundle,
            registry,
        })
    }

    /// The topology in use.
    pub fn network(&self) -> &Ohhc {
        &self.bundle.net
    }

    /// The bundle this sorter runs on (shareable with further sorters).
    pub fn bundle(&self) -> &Arc<TopologyBundle> {
        &self.bundle
    }

    /// Run the paper's full experiment cell: sequential baseline plus the
    /// parallel run on the configured backend, with verification.
    pub fn run(&self) -> Result<SortReport> {
        let workload = Workload::new(self.cfg.distribution, self.cfg.elements, self.cfg.seed);
        self.run_on(&workload)
    }

    /// Run on an externally supplied workload (measures a fresh
    /// sequential baseline).
    pub fn run_on(&self, workload: &Workload) -> Result<SortReport> {
        let baseline = SeqBaseline::measure(&workload.data);
        self.run_on_with_baseline(workload, &baseline)
    }

    /// Run on an externally supplied workload against a pre-measured
    /// sequential baseline (the campaign engine's memoized path — cells
    /// sharing a workload skip the re-clone + re-quicksort).
    pub fn run_on_with_baseline(
        &self,
        workload: &Workload,
        baseline: &SeqBaseline,
    ) -> Result<SortReport> {
        let data = &workload.data;
        let net = &self.bundle.net;
        let sequential_time = baseline.time;
        let sequential_counters = baseline.counters;
        let seq = &baseline.sorted;

        // Parallel run.
        let t0 = Instant::now();
        let divided = divide_with_engine(
            data,
            net.total_processors(),
            self.cfg.divide_engine,
            self.registry.as_ref(),
        )?;
        let divide_time = t0.elapsed();
        let imbalance = divided.imbalance();

        let out = match self.cfg.backend {
            Backend::Threaded => self.run_threaded(divided, data.len(), seq, divide_time)?,
            Backend::DiscreteEvent => self.run_des(divided, data.len(), seq, divide_time)?,
        };

        let ts = sequential_time.as_secs_f64();
        let tp = out.parallel_time.as_secs_f64();
        let p = net.total_processors() as f64;
        Ok(SortReport {
            elements: data.len(),
            processors: net.total_processors(),
            sequential_time,
            parallel_time: out.parallel_time,
            divide_time,
            counters: out.counters,
            sequential_counters,
            imbalance,
            des_completion_ns: out.des.as_ref().map(|d| d.completion_ns),
            des_steps: out.des.as_ref().map(|d| d.trace.steps()),
            des_trace: out.des.map(|d| d.trace),
            speedup: ts / tp,
            speedup_pct: (ts - tp) / ts * 100.0,
            efficiency: ts / (p * tp),
        })
    }

    fn run_threaded(
        &self,
        divided: Divided,
        total_len: usize,
        expect: &[i32],
        divide_time: Duration,
    ) -> Result<BackendOutcome> {
        let mode = if self.cfg.workers == 0 {
            ThreadMode::Direct
        } else {
            ThreadMode::Waves
        };
        let out = ThreadedSimulator::new(&self.bundle.net, &self.bundle.plans)
            .with_mode(mode)
            .run(divided.buckets, total_len)?;
        if out.sorted != expect {
            return Err(Error::Invariant(
                "parallel output differs from sequential baseline".into(),
            ));
        }
        Ok(BackendOutcome {
            parallel_time: divide_time + out.parallel_time,
            counters: out.counters,
            des: None,
        })
    }

    fn run_des(
        &self,
        divided: Divided,
        total_len: usize,
        expect: &[i32],
        divide_time: Duration,
    ) -> Result<BackendOutcome> {
        // Real local sorts (for counters + verified output) feed exact
        // work into the DES clock.  They run in place on the arena's
        // disjoint segments — the sorted arena is then compared against
        // the baseline directly, no reassembly copy.
        let mut buckets = divided.buckets;
        let mut counters_vec = Vec::with_capacity(buckets.num_buckets());
        let mut counters = SortCounters::default();
        for seg in buckets.segments_mut() {
            let c = quicksort(seg);
            counters_vec.push(c);
            counters += c;
        }

        if buckets.total_keys() != total_len || buckets.arena() != expect {
            return Err(Error::Invariant(
                "DES-path output differs from sequential baseline".into(),
            ));
        }

        let des = DesSimulator::new(&self.bundle.net, &self.bundle.plans, self.cfg.link_model)
            .run_buckets(&buckets, Some(&counters_vec))?;
        let virtual_time = Duration::from_nanos(des.completion_ns as u64);
        Ok(BackendOutcome {
            parallel_time: divide_time + virtual_time,
            counters,
            des: Some(des),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Construction, Distribution};

    fn cfg(d: u32, c: Construction, backend: Backend) -> ExperimentConfig {
        ExperimentConfig {
            dimension: d,
            construction: c,
            distribution: Distribution::Random,
            elements: 40_000,
            backend,
            ..Default::default()
        }
    }

    #[test]
    fn threaded_end_to_end_d1_full() {
        let report = OhhcSorter::new(&cfg(1, Construction::FullGroup, Backend::Threaded))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.elements, 40_000);
        assert_eq!(report.processors, 36);
        assert!(report.parallel_time > Duration::ZERO);
        assert!(report.speedup > 0.0);
        assert!((0.0..=1.5).contains(&report.efficiency));
    }

    #[test]
    fn threaded_end_to_end_d2_half_waves() {
        let mut c = cfg(2, Construction::HalfGroup, Backend::Threaded);
        c.workers = 8; // waves mode
        let report = OhhcSorter::new(&c).unwrap().run().unwrap();
        assert_eq!(report.processors, 72);
        assert!(report.counters.comparisons > 0);
    }

    #[test]
    fn des_end_to_end_reports_steps() {
        let report = OhhcSorter::new(&cfg(1, Construction::FullGroup, Backend::DiscreteEvent))
            .unwrap()
            .run()
            .unwrap();
        let (elec, opt) = report.des_steps.unwrap();
        // Scatter + gather trees: 2·(N−1) traversals, G−1 optical each way.
        assert_eq!(elec + opt, 2 * (36 - 1));
        assert_eq!(opt, 2 * (6 - 1));
        assert!(report.des_completion_ns.unwrap() > 0.0);
    }

    #[test]
    fn all_distributions_verify() {
        for dist in Distribution::ALL {
            let mut c = cfg(1, Construction::HalfGroup, Backend::Threaded);
            c.distribution = dist;
            c.workers = 4;
            let report = OhhcSorter::new(&c).unwrap().run().unwrap();
            assert!(report.counters.recursion_calls > 0, "{dist:?}");
        }
    }

    #[test]
    fn shared_bundle_runs_many_sorters() {
        let base = cfg(1, Construction::FullGroup, Backend::Threaded);
        let bundle = OhhcSorter::new(&base).unwrap().bundle().clone();
        for dist in [Distribution::Sorted, Distribution::Local] {
            let mut c = base.clone();
            c.distribution = dist;
            c.workers = 4;
            let r = OhhcSorter::with_bundle(&c, bundle.clone()).unwrap().run().unwrap();
            assert_eq!(r.processors, 36, "{dist:?}");
        }
    }

    #[test]
    fn mismatched_bundle_rejected() {
        let bundle = TopologyBundle::build(1, Construction::FullGroup).unwrap();
        let c = cfg(2, Construction::FullGroup, Backend::Threaded);
        assert!(OhhcSorter::with_bundle(&c, Arc::new(bundle)).is_err());
    }
}

// Needs `make artifacts` and the real PJRT runtime.
#[cfg(all(test, feature = "xla"))]
mod xla_tests {
    use super::*;
    use crate::config::{Construction, Distribution, DivideEngine};

    #[test]
    fn xla_divide_engine_end_to_end() {
        let c = ExperimentConfig {
            dimension: 1,
            construction: Construction::FullGroup,
            distribution: Distribution::Random,
            elements: 40_000,
            backend: Backend::Threaded,
            divide_engine: DivideEngine::Xla,
            workers: 4,
            ..Default::default()
        };
        let report = OhhcSorter::new(&c).unwrap().run().unwrap();
        assert_eq!(report.processors, 36);
    }
}
