//! End-to-end OHHC parallel Quick Sort driver — a thin configuration
//! adapter over the typestate [`Session`](crate::pipeline::Session):
//! it maps an [`ExperimentConfig`] onto a pipeline engine, drives the
//! three transitions, verifies the outcome against the sequential
//! baseline, and assembles the paper-facing [`SortReport`] from the
//! session's [`StageTrace`].

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{Backend, ExperimentConfig};
use crate::error::{Error, Result};
use crate::pipeline::{Engine, Observer, Session, StageTrace};
use crate::runtime::ArtifactRegistry;
use crate::schedule::TopologyBundle;
use crate::sort::{is_sorted, quicksort, SortCounters};
use crate::topology::fault::FaultSet;
use crate::topology::ohhc::Ohhc;
use crate::workload::Workload;

/// Everything one experiment run produces — the raw material for every
/// figure in the paper's §6.
#[derive(Debug)]
pub struct SortReport {
    /// Keys sorted.
    pub elements: usize,
    /// Total processors simulated.
    pub processors: usize,
    /// Wall time of the sequential baseline on the same input.
    pub sequential_time: Duration,
    /// Wall time of the parallel run (divide + scatter + sort + gather).
    pub parallel_time: Duration,
    /// Wall time of the divide phase alone.
    pub divide_time: Duration,
    /// Per-stage wall-time breakdown (divide / scatter / local sort /
    /// gather), straight from the session's trace.
    pub stage_times: StageTrace,
    /// Summed local-sort counters (parallel run).
    pub counters: SortCounters,
    /// Counters of the sequential baseline.
    pub sequential_counters: SortCounters,
    /// Load imbalance factor of the division.
    pub imbalance: f64,
    /// Skew-guardrail re-divides (0 or 1; only the adaptive divide
    /// strategy ever re-divides).
    pub skew_redivides: u32,
    /// DES virtual completion time (ns), when the DES backend ran.
    pub des_completion_ns: Option<f64>,
    /// DES communication steps `(electrical, optical)`.
    pub des_steps: Option<(usize, usize)>,
    /// Detours taken around injected faults: rerouted DES messages
    /// when the DES ran, otherwise gather-tree edges that needed a
    /// detour.  0 on a healthy network.
    pub detours: usize,
    /// Full DES communication trace (for `--trace-out` export).
    pub des_trace: Option<crate::sim::trace::CommTrace>,
    /// Relative speedup `T_s / T_p`.
    pub speedup: f64,
    /// The paper's percentage presentation: `(T_s - T_p) / T_s · 100`.
    pub speedup_pct: f64,
    /// Efficiency `T_s / (P · T_p)`.
    pub efficiency: f64,
}

/// A measured sequential baseline (paper Fig 6.1): the sorted reference
/// output plus its wall time and counters.  Reusable across every run on
/// the same workload — the campaign engine memoizes one per
/// `(distribution, elements, seed)` fingerprint.
#[derive(Debug, Clone)]
pub struct SeqBaseline {
    /// The input sorted by the instrumented sequential Quick Sort.
    pub sorted: Vec<i32>,
    /// Wall time of that sort.
    pub time: Duration,
    /// Its instruction counters.
    pub counters: SortCounters,
}

impl SeqBaseline {
    /// Measure the baseline on one input.
    pub fn measure(data: &[i32]) -> Self {
        let mut sorted = data.to_vec();
        let t0 = Instant::now();
        let counters = quicksort(&mut sorted);
        let time = t0.elapsed();
        debug_assert!(is_sorted(&sorted));
        SeqBaseline { sorted, time, counters }
    }
}

/// Reusable experiment driver over a shared topology bundle.
///
/// `new` builds a private bundle (the historical one-shot behaviour);
/// `with_bundle` injects a shared `Arc<TopologyBundle>` so sweeps reuse
/// one topology + plan construction across many runs — the contract the
/// [`crate::campaign`] engine builds on.
pub struct OhhcSorter {
    cfg: ExperimentConfig,
    bundle: Arc<TopologyBundle>,
    registry: Option<ArtifactRegistry>,
    observer: Option<Arc<dyn Observer + Send + Sync>>,
    faults: Option<FaultSet>,
}

impl OhhcSorter {
    /// Construct for a validated configuration, building a fresh topology.
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let bundle = Arc::new(TopologyBundle::build(cfg.dimension, cfg.construction)?);
        Self::with_bundle(cfg, bundle)
    }

    /// Construct over a pre-built (typically cached and shared) bundle.
    pub fn with_bundle(cfg: &ExperimentConfig, bundle: Arc<TopologyBundle>) -> Result<Self> {
        cfg.validate()?;
        if bundle.key() != (cfg.dimension, cfg.construction) {
            return Err(Error::Config(format!(
                "bundle is for (d={}, {}), config wants (d={}, {})",
                bundle.net.dimension,
                bundle.net.construction.label(),
                cfg.dimension,
                cfg.construction.label()
            )));
        }
        let registry = match cfg.divide_engine {
            crate::config::DivideEngine::Xla => Some(ArtifactRegistry::open(&cfg.artifact_dir)?),
            crate::config::DivideEngine::Native => None,
        };
        Ok(OhhcSorter {
            cfg: cfg.clone(),
            bundle,
            registry,
            observer: None,
            faults: None,
        })
    }

    /// Install a stage-boundary observer forwarded to every session
    /// this sorter drives (campaign progress, bench probes).
    pub fn with_stage_observer(mut self, observer: Arc<dyn Observer + Send + Sync>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Inject a fault set into every session this sorter drives.
    /// Routing detours around the failed elements and the run fails
    /// with [`Error::Stage`] when a stage has no surviving route.
    pub fn with_faults(mut self, faults: FaultSet) -> Self {
        self.faults = Some(faults);
        self
    }

    /// The topology in use.
    pub fn network(&self) -> &Ohhc {
        &self.bundle.net
    }

    /// The bundle this sorter runs on (shareable with further sorters).
    pub fn bundle(&self) -> &Arc<TopologyBundle> {
        &self.bundle
    }

    /// Run the paper's full experiment cell: sequential baseline plus the
    /// parallel run on the configured backend, with verification.
    pub fn run(&self) -> Result<SortReport> {
        let workload = Workload::new(self.cfg.distribution, self.cfg.elements, self.cfg.seed);
        self.run_on(&workload)
    }

    /// Run on an externally supplied workload (measures a fresh
    /// sequential baseline).
    pub fn run_on(&self, workload: &Workload) -> Result<SortReport> {
        let baseline = SeqBaseline::measure(&workload.data);
        self.run_on_with_baseline(workload, &baseline)
    }

    /// Run on an externally supplied workload against a pre-measured
    /// sequential baseline (the campaign engine's memoized path — cells
    /// sharing a workload skip the re-clone + re-quicksort).
    pub fn run_on_with_baseline(
        &self,
        workload: &Workload,
        baseline: &SeqBaseline,
    ) -> Result<SortReport> {
        let data = &workload.data;
        let net = &self.bundle.net;
        let sequential_time = baseline.time;
        let sequential_counters = baseline.counters;

        let engine = match self.cfg.backend {
            Backend::Threaded if self.cfg.workers == 0 => Engine::DirectThreads,
            Backend::Threaded => Engine::Pooled,
            Backend::DiscreteEvent => Engine::DiscreteEvent {
                link: self.cfg.link_model,
            },
        };
        let mut session = Session::single(net, &self.bundle.plans, data)
            .with_divide_engine(self.cfg.divide_engine, self.registry.as_ref())
            .with_divide_strategy(self.cfg.divide_strategy)
            .with_engine(engine);
        if let Some(obs) = &self.observer {
            session = session.with_observer(&**obs);
        }
        if let Some(f) = &self.faults {
            session = session.with_faults(f);
        }
        let outcome = session.divide()?.local_sort()?.gather()?;
        if outcome.sorted != baseline.sorted {
            return Err(Error::Invariant(
                "parallel output differs from sequential baseline".into(),
            ));
        }

        let divide_time = outcome.trace.divide_total();
        // Threaded backends report wall clock; the DES reports the
        // divide wall plus the simulated virtual completion time.
        let parallel_time = match &outcome.des {
            None => divide_time + outcome.parallel_time(),
            Some(des) => divide_time + Duration::from_nanos(des.completion_ns as u64),
        };

        let ts = sequential_time.as_secs_f64();
        let tp = parallel_time.as_secs_f64();
        let p = net.total_processors() as f64;
        Ok(SortReport {
            elements: data.len(),
            processors: net.total_processors(),
            sequential_time,
            parallel_time,
            divide_time,
            stage_times: outcome.trace,
            counters: outcome.counters,
            sequential_counters,
            imbalance: outcome.imbalance,
            skew_redivides: outcome.skew_redivides,
            des_completion_ns: outcome.des.as_ref().map(|d| d.completion_ns),
            des_steps: outcome.des.as_ref().map(|d| d.trace.steps()),
            detours: outcome.des.as_ref().map_or(outcome.detours, |d| d.detours),
            des_trace: outcome.des.map(|d| d.trace),
            speedup: ts / tp,
            speedup_pct: (ts - tp) / ts * 100.0,
            efficiency: ts / (p * tp),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Construction, Distribution};

    fn cfg(d: u32, c: Construction, backend: Backend) -> ExperimentConfig {
        ExperimentConfig {
            dimension: d,
            construction: c,
            distribution: Distribution::Random,
            elements: 40_000,
            backend,
            ..Default::default()
        }
    }

    #[test]
    fn threaded_end_to_end_d1_full() {
        let report = OhhcSorter::new(&cfg(1, Construction::FullGroup, Backend::Threaded))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.elements, 40_000);
        assert_eq!(report.processors, 36);
        assert!(report.parallel_time > Duration::ZERO);
        assert!(report.speedup > 0.0);
        assert!((0.0..=1.5).contains(&report.efficiency));
    }

    #[test]
    fn threaded_end_to_end_d2_half_waves() {
        let mut c = cfg(2, Construction::HalfGroup, Backend::Threaded);
        c.workers = 8; // waves mode
        let report = OhhcSorter::new(&c).unwrap().run().unwrap();
        assert_eq!(report.processors, 72);
        assert!(report.counters.comparisons > 0);
    }

    #[test]
    fn des_end_to_end_reports_steps() {
        let report = OhhcSorter::new(&cfg(1, Construction::FullGroup, Backend::DiscreteEvent))
            .unwrap()
            .run()
            .unwrap();
        let (elec, opt) = report.des_steps.unwrap();
        // Scatter + gather trees: 2·(N−1) traversals, G−1 optical each way.
        assert_eq!(elec + opt, 2 * (36 - 1));
        assert_eq!(opt, 2 * (6 - 1));
        assert!(report.des_completion_ns.unwrap() > 0.0);
    }

    #[test]
    fn stage_trace_sums_to_parallel_time() {
        // Pooled engine: every stage measured at its own transition.
        let mut c = cfg(1, Construction::FullGroup, Backend::Threaded);
        c.workers = 4;
        let r = OhhcSorter::new(&c).unwrap().run().unwrap();
        assert_eq!(r.stage_times.total(), r.parallel_time);
        assert_eq!(r.stage_times.divide_total(), r.divide_time);
        assert!(r.stage_times.local_sort > Duration::ZERO);

        // Direct engine: the fused region splits on its critical path,
        // so the sum is still exactly the reported parallel time.
        let r = OhhcSorter::new(&cfg(1, Construction::FullGroup, Backend::Threaded))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.stage_times.total(), r.parallel_time);
        assert!(r.stage_times.local_sort > Duration::ZERO);
        assert!(r.stage_times.gather > Duration::ZERO);
    }

    #[test]
    fn all_distributions_verify() {
        for dist in Distribution::ALL {
            let mut c = cfg(1, Construction::HalfGroup, Backend::Threaded);
            c.distribution = dist;
            c.workers = 4;
            let report = OhhcSorter::new(&c).unwrap().run().unwrap();
            assert!(report.counters.recursion_calls > 0, "{dist:?}");
        }
    }

    #[test]
    fn divide_strategies_verify_on_hostile_input() {
        use crate::config::DivideStrategy;
        let base = cfg(1, Construction::FullGroup, Backend::Threaded);
        let bundle = OhhcSorter::new(&base).unwrap().bundle().clone();
        for strategy in DivideStrategy::ALL {
            let mut c = base.clone();
            c.distribution = Distribution::AntiPivot;
            c.divide_strategy = strategy;
            c.workers = 4;
            let r = OhhcSorter::with_bundle(&c, bundle.clone()).unwrap().run().unwrap();
            match strategy {
                // The attack succeeds against the paper rule...
                DivideStrategy::PaperFixed => {
                    assert!(r.imbalance > 2.0, "{}", r.imbalance);
                    assert_eq!(r.skew_redivides, 0);
                }
                // ...and both hardened strategies bound it.
                DivideStrategy::RegularSampling => {
                    assert!(r.imbalance <= 2.0, "{}", r.imbalance);
                    assert_eq!(r.skew_redivides, 0);
                }
                DivideStrategy::Adaptive => {
                    assert!(r.imbalance <= 2.0, "{}", r.imbalance);
                    assert_eq!(r.skew_redivides, 1);
                }
            }
        }
    }

    #[test]
    fn shared_bundle_runs_many_sorters() {
        let base = cfg(1, Construction::FullGroup, Backend::Threaded);
        let bundle = OhhcSorter::new(&base).unwrap().bundle().clone();
        for dist in [Distribution::Sorted, Distribution::Local] {
            let mut c = base.clone();
            c.distribution = dist;
            c.workers = 4;
            let r = OhhcSorter::with_bundle(&c, bundle.clone()).unwrap().run().unwrap();
            assert_eq!(r.processors, 36, "{dist:?}");
        }
    }

    #[test]
    fn faulty_links_detour_but_still_verify() {
        // Seeded link faults never partition the network (bridges are
        // skipped), so both backends must still produce the baseline
        // order — the DES just pays for the detours.
        let base = cfg(1, Construction::FullGroup, Backend::Threaded);
        let bundle = OhhcSorter::new(&base).unwrap().bundle().clone();
        let faults = FaultSet::seeded_links(bundle.net.graph(), 250, 0xFA11);
        assert!(faults.num_failed_links() > 0);

        let threaded = OhhcSorter::with_bundle(&base, bundle.clone())
            .unwrap()
            .with_faults(faults.clone())
            .run()
            .unwrap();
        assert_eq!(threaded.processors, 36);

        let des_cfg = cfg(1, Construction::FullGroup, Backend::DiscreteEvent);
        let des = OhhcSorter::with_bundle(&des_cfg, bundle.clone())
            .unwrap()
            .with_faults(faults)
            .run()
            .unwrap();
        assert!(des.detours > 0);
        let healthy = OhhcSorter::with_bundle(&des_cfg, bundle).unwrap().run().unwrap();
        assert_eq!(healthy.detours, 0);
        assert!(des.des_completion_ns.unwrap() >= healthy.des_completion_ns.unwrap());
    }

    #[test]
    fn dead_node_fails_the_run_explicitly() {
        let mut faults = FaultSet::new();
        faults.fail_node(17);
        let err = OhhcSorter::new(&cfg(1, Construction::FullGroup, Backend::Threaded))
            .unwrap()
            .with_faults(faults)
            .run()
            .unwrap_err();
        assert!(matches!(err, Error::Stage(_)), "{err}");
    }

    #[test]
    fn mismatched_bundle_rejected() {
        let bundle = TopologyBundle::build(1, Construction::FullGroup).unwrap();
        let c = cfg(2, Construction::FullGroup, Backend::Threaded);
        assert!(OhhcSorter::with_bundle(&c, Arc::new(bundle)).is_err());
    }
}

// Needs `make artifacts` and the real PJRT runtime.
#[cfg(all(test, feature = "xla"))]
mod xla_tests {
    use super::*;
    use crate::config::{Construction, Distribution, DivideEngine};

    #[test]
    fn xla_divide_engine_end_to_end() {
        let c = ExperimentConfig {
            dimension: 1,
            construction: Construction::FullGroup,
            distribution: Distribution::Random,
            elements: 40_000,
            backend: Backend::Threaded,
            divide_engine: DivideEngine::Xla,
            workers: 4,
            ..Default::default()
        };
        let report = OhhcSorter::new(&c).unwrap().run().unwrap();
        assert_eq!(report.processors, 36);
    }
}
