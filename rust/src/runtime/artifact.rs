//! Artifact registry: discovers `artifacts/*.hlo.txt`, validates their
//! signatures against `manifest.json`, and compiles them (once) on the
//! PJRT CPU client.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::xla;

/// Declared I/O signature of one artifact (from `manifest.json`).
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    /// Input `(dtype, shape)` pairs.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Output `(dtype, shape)` pairs.
    pub outputs: Vec<(String, Vec<usize>)>,
    /// Truncated sha256 of the HLO text.
    pub sha256: String,
    /// HLO text size.
    pub bytes: usize,
}

/// `manifest.json` written by `python -m compile.aot`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Chunk length every streaming artifact was lowered for.
    pub chunk: usize,
    /// Artifact name → signature.
    pub artifacts: HashMap<String, ArtifactSig>,
}

impl ArtifactManifest {
    /// Parse the manifest JSON document.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let bad = |m: &str| Error::Artifact(format!("bad manifest.json: {m}"));
        let chunk = j
            .get("chunk")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing `chunk`"))?;
        let mut artifacts = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| bad("missing `artifacts`"))?;
        for (name, a) in arts {
            let io = |key: &str| -> Result<Vec<(String, Vec<usize>)>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| bad(&format!("{name}: missing `{key}`")))?
                    .iter()
                    .map(|pair| {
                        let pair = pair
                            .as_arr()
                            .filter(|p| p.len() == 2)
                            .ok_or_else(|| bad(&format!("{name}: bad {key} entry")))?;
                        let dtype = pair[0]
                            .as_str()
                            .ok_or_else(|| bad(&format!("{name}: bad dtype")))?
                            .to_string();
                        let shape = pair[1]
                            .as_arr()
                            .ok_or_else(|| bad(&format!("{name}: bad shape")))?
                            .iter()
                            .map(|d| {
                                d.as_usize()
                                    .ok_or_else(|| bad(&format!("{name}: bad dim")))
                            })
                            .collect::<Result<Vec<usize>>>()?;
                        Ok((dtype, shape))
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    inputs: io("inputs")?,
                    outputs: io("outputs")?,
                    sha256: a
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    bytes: a
                        .get("bytes")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| bad(&format!("{name}: missing bytes")))?,
                },
            );
        }
        Ok(ArtifactManifest { chunk, artifacts })
    }
}

/// Registry + lazy compilation cache.
pub struct ArtifactRegistry {
    dir: PathBuf,
    manifest: ArtifactManifest,
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    /// Open a registry over an artifact directory (reads `manifest.json`,
    /// creates the PJRT CPU client).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = ArtifactManifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            manifest,
            client,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    /// The chunk length artifacts were lowered for.
    pub fn chunk(&self) -> usize {
        self.manifest.chunk
    }

    /// Names of all known artifacts.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    /// Signature of an artifact.
    pub fn sig(&self, name: &str) -> Result<&ArtifactSig> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact `{name}`")))
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let sig = self.sig(name)?;
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("missing {}: {e}", path.display())))?;
        if text.len() != sig.bytes {
            return Err(Error::Artifact(format!(
                "{name}: size {} != manifest {} (stale artifacts? re-run `make artifacts`)",
                text.len(),
                sig.bytes
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// The PJRT client (platform info, diagnostics).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parser_rejects_malformed() {
        assert!(ArtifactManifest::parse("{}").is_err());
        assert!(ArtifactManifest::parse(r#"{"chunk": 4}"#).is_err());
        assert!(ArtifactManifest::parse(
            r#"{"chunk": 4, "artifacts": {"a": {"inputs": [], "outputs": []}}}"#
        )
        .is_err()); // missing bytes
        let ok = ArtifactManifest::parse(
            r#"{"chunk": 4, "artifacts":
               {"a": {"inputs": [["s32",[4]]], "outputs": [["s32",[1]]],
                      "sha256": "x", "bytes": 10}}}"#,
        )
        .unwrap();
        assert_eq!(ok.artifacts["a"].inputs[0].1, vec![4]);
    }
}

// Tests against real lowered artifacts need `make artifacts` plus the PJRT
// runtime, neither of which exists in the default offline build.
#[cfg(all(test, feature = "xla"))]
mod xla_tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from("artifacts")
    }

    #[test]
    fn open_registry_and_list() {
        let reg = ArtifactRegistry::open(&artifact_dir()).expect("make artifacts first");
        assert_eq!(reg.chunk(), 65536);
        let names = reg.names();
        assert!(names.iter().any(|n| n == "minmax_n65536"), "{names:?}");
        assert!(names.iter().any(|n| n == "partition_n65536_p36"));
        assert!(names.iter().any(|n| n == "bitonic_n65536_b1024"));
        // Paper Table 1.1: all eight processor counts are covered.
        for p in [18, 36, 72, 144, 288, 576, 1152, 2304] {
            assert!(
                names.iter().any(|n| n == &format!("partition_n65536_p{p}")),
                "missing partition for P={p}"
            );
        }
    }

    #[test]
    fn unknown_artifact_rejected() {
        let reg = ArtifactRegistry::open(&artifact_dir()).unwrap();
        assert!(reg.sig("nope").is_err());
        assert!(reg.executable("nope").is_err());
    }

    #[test]
    fn signatures_describe_shapes() {
        let reg = ArtifactRegistry::open(&artifact_dir()).unwrap();
        let sig = reg.sig("partition_n65536_p36").unwrap();
        assert_eq!(sig.inputs.len(), 3); // x, lo, sub
        assert_eq!(sig.inputs[0].1, vec![65536]);
        assert_eq!(sig.outputs[1].1, vec![36]); // histogram
    }
}
