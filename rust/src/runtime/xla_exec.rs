//! High-level executors over the AOT artifacts: the streaming divide
//! pipeline (min/max → SubDivider → bucket ids + histogram) and the
//! bitonic block sorter, both with shape-safe padding.

use std::sync::Arc;

use super::artifact::ArtifactRegistry;
use crate::error::{Error, Result};
use crate::xla;

/// Chunk length every streaming artifact was lowered for.
pub const CHUNK: usize = 65536;

/// Output of the divide pipeline.
#[derive(Debug, Clone)]
pub struct DivideOutput {
    /// Bucket id per input element.
    pub ids: Vec<u32>,
    /// Bucket occupancy histogram (`num_buckets` long).
    pub hist: Vec<usize>,
    /// Global minimum.
    pub lo: i32,
    /// Step point (`SubDivider`, ≥ 1).
    pub sub: i32,
}

/// XLA-backed array-division pipeline for a fixed bucket count.
pub struct XlaDivide {
    minmax: Arc<xla::PjRtLoadedExecutable>,
    partition: Arc<xla::PjRtLoadedExecutable>,
    num_buckets: usize,
    chunk: usize,
}

impl XlaDivide {
    /// Build over a registry for `num_buckets` processors (must be one of
    /// the Table 1.1 counts the artifacts were lowered for).
    pub fn new(reg: &ArtifactRegistry, num_buckets: usize) -> Result<Self> {
        let chunk = reg.chunk();
        let minmax = reg.executable(&format!("minmax_n{chunk}"))?;
        let partition = reg.executable(&format!("partition_n{chunk}_p{num_buckets}"))?;
        Ok(XlaDivide {
            minmax,
            partition,
            num_buckets,
            chunk,
        })
    }

    /// Run the full pipeline over `data` (any length ≥ 1).
    pub fn divide(&self, data: &[i32]) -> Result<DivideOutput> {
        if data.is_empty() {
            return Err(Error::Config("cannot divide an empty array".into()));
        }
        // Pass 1: global (min, max) chunk by chunk.  The tail chunk is
        // padded with the first element — value-neutral for min/max.
        let mut lo = i32::MAX;
        let mut hi = i32::MIN;
        let mut buf = vec![data[0]; self.chunk];
        for chunk in data.chunks(self.chunk) {
            let lit = if chunk.len() == self.chunk {
                xla::Literal::vec1(chunk)
            } else {
                buf[..chunk.len()].copy_from_slice(chunk);
                buf[chunk.len()..].fill(data[0]);
                xla::Literal::vec1(&buf)
            };
            let out = self.minmax.execute::<xla::Literal>(&[lit])?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            let mn = out[0].to_vec::<i32>()?[0];
            let mx = out[1].to_vec::<i32>()?[0];
            lo = lo.min(mn);
            hi = hi.max(mx);
        }
        let sub = (((hi as i64 - lo as i64) / self.num_buckets as i64).max(1)) as i32;

        // Pass 2: bucket ids + histogram.  Tail padding uses `hi`, which
        // clamps into the last bucket; the pad count is subtracted.
        let mut ids = Vec::with_capacity(data.len());
        let mut hist = vec![0usize; self.num_buckets];
        for chunk in data.chunks(self.chunk) {
            let pad = self.chunk - chunk.len();
            let lit = if pad == 0 {
                xla::Literal::vec1(chunk)
            } else {
                buf[..chunk.len()].copy_from_slice(chunk);
                buf[chunk.len()..].fill(hi);
                xla::Literal::vec1(&buf)
            };
            let args = [lit, xla::Literal::vec1(&[lo]), xla::Literal::vec1(&[sub])];
            let out = self
                .partition
                .execute::<xla::Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            let chunk_ids = out[0].to_vec::<i32>()?;
            let chunk_hist = out[1].to_vec::<i32>()?;
            ids.extend(chunk_ids[..chunk.len()].iter().map(|&v| v as u32));
            for (b, &count) in chunk_hist.iter().enumerate() {
                hist[b] += count as usize;
            }
            hist[self.num_buckets - 1] -= pad;
        }
        Ok(DivideOutput { ids, hist, lo, sub })
    }
}

/// XLA-backed splitter partition (the PSRS baseline's hot spot): buckets
/// keys by a sorted splitter list via the AOT splitter kernel.
pub struct XlaSplitterPartition {
    exe: Arc<xla::PjRtLoadedExecutable>,
    num_buckets: usize,
    chunk: usize,
}

impl XlaSplitterPartition {
    /// Build for one of the lowered splitter bucket counts (36, 144).
    pub fn new(reg: &ArtifactRegistry, num_buckets: usize) -> Result<Self> {
        let chunk = reg.chunk();
        let exe = reg.executable(&format!("splitter_n{chunk}_p{num_buckets}"))?;
        Ok(XlaSplitterPartition {
            exe,
            num_buckets,
            chunk,
        })
    }

    /// Bucket `data` by `splitters` (ascending, `num_buckets - 1` long).
    /// Returns `(ids, hist)`; the tail chunk is padded with `i32::MAX`
    /// (always the last bucket) and corrected.
    pub fn partition(&self, data: &[i32], splitters: &[i32]) -> Result<(Vec<u32>, Vec<usize>)> {
        if splitters.len() != self.num_buckets - 1 {
            return Err(Error::Config(format!(
                "need {} splitters, got {}",
                self.num_buckets - 1,
                splitters.len()
            )));
        }
        if data.is_empty() {
            return Ok((Vec::new(), vec![0; self.num_buckets]));
        }
        let mut ids = Vec::with_capacity(data.len());
        let mut hist = vec![0usize; self.num_buckets];
        let mut buf = vec![i32::MAX; self.chunk];
        for chunk in data.chunks(self.chunk) {
            let pad = self.chunk - chunk.len();
            let lit = if pad == 0 {
                xla::Literal::vec1(chunk)
            } else {
                buf[..chunk.len()].copy_from_slice(chunk);
                buf[chunk.len()..].fill(i32::MAX);
                xla::Literal::vec1(&buf)
            };
            let out = self
                .exe
                .execute::<xla::Literal>(&[lit, xla::Literal::vec1(splitters)])?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            let chunk_ids = out[0].to_vec::<i32>()?;
            let chunk_hist = out[1].to_vec::<i32>()?;
            ids.extend(chunk_ids[..chunk.len()].iter().map(|&v| v as u32));
            for (b, &c) in chunk_hist.iter().enumerate() {
                hist[b] += c as usize;
            }
            hist[self.num_buckets - 1] -= pad;
        }
        Ok((ids, hist))
    }
}

/// XLA-backed local sorter: bitonic blocks on-device, k-way merge on host.
pub struct XlaSortBlocks {
    exe: Arc<xla::PjRtLoadedExecutable>,
    chunk: usize,
    block: usize,
}

impl XlaSortBlocks {
    /// Build over a registry for a lowered block size (1024 or 4096).
    pub fn new(reg: &ArtifactRegistry, block: usize) -> Result<Self> {
        let chunk = reg.chunk();
        let exe = reg.executable(&format!("bitonic_n{chunk}_b{block}"))?;
        Ok(XlaSortBlocks { exe, chunk, block })
    }

    /// Sort a payload of any length: pad to the chunk shape with
    /// `i32::MAX`, bitonic-sort every block on the XLA side, then k-way
    /// merge the sorted blocks on the host.
    pub fn sort(&self, data: &[i32]) -> Result<Vec<i32>> {
        if data.is_empty() {
            return Ok(Vec::new());
        }
        let mut out = Vec::with_capacity(data.len());
        let mut buf = vec![i32::MAX; self.chunk];
        for chunk in data.chunks(self.chunk) {
            buf[..chunk.len()].copy_from_slice(chunk);
            buf[chunk.len()..].fill(i32::MAX);
            let lit = xla::Literal::vec1(&buf);
            let sorted = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
                .to_literal_sync()?
                .to_tuple1()?
                .to_vec::<i32>()?;
            merge_sorted_blocks(&sorted, self.block, chunk.len(), &mut out);
        }
        // Multi-chunk payloads: each chunk is internally sorted; merge the
        // chunk runs pairwise (rare path — payloads usually fit a chunk).
        if data.len() > self.chunk {
            let run = self.chunk.min(out.len());
            out = merge_runs(out, run);
        }
        Ok(out)
    }
}

/// K-way merge of consecutive sorted `block`-sized runs, keeping the first
/// `keep` non-sentinel keys.
fn merge_sorted_blocks(sorted: &[i32], block: usize, keep: usize, out: &mut Vec<i32>) {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heads: BinaryHeap<Reverse<(i32, usize)>> = sorted
        .chunks(block)
        .enumerate()
        .filter(|(_, c)| !c.is_empty())
        .map(|(i, c)| Reverse((c[0], i * block)))
        .collect();
    let mut taken = 0;
    while taken < keep {
        let Reverse((v, idx)) = heads.pop().expect("ran out of keys during merge");
        out.push(v);
        taken += 1;
        let next = idx + 1;
        if next % block != 0 && next < sorted.len() {
            heads.push(Reverse((sorted[next], next)));
        }
    }
}

/// Merge equal-length sorted runs of `run` keys into one sorted vector.
fn merge_runs(v: Vec<i32>, run: usize) -> Vec<i32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heads: BinaryHeap<Reverse<(i32, usize)>> = v
        .chunks(run)
        .enumerate()
        .filter(|(_, c)| !c.is_empty())
        .map(|(i, c)| Reverse((c[0], i * run)))
        .collect();
    let mut out = Vec::with_capacity(v.len());
    while let Some(Reverse((val, idx))) = heads.pop() {
        out.push(val);
        let next = idx + 1;
        if next % run != 0 && next < v.len() {
            heads.push(Reverse((v[next], next)));
        }
    }
    out
}

// These tests execute real lowered artifacts: they need `make artifacts`
// plus the PJRT runtime, neither of which exists in the default build.
#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use crate::workload;
    use std::path::PathBuf;

    fn registry() -> ArtifactRegistry {
        ArtifactRegistry::open(&PathBuf::from("artifacts")).expect("make artifacts first")
    }

    /// Native oracle for the divide pipeline.
    fn native_divide(data: &[i32], p: usize) -> (Vec<u32>, Vec<usize>, i32, i32) {
        let lo = *data.iter().min().unwrap();
        let hi = *data.iter().max().unwrap();
        let sub = (((hi as i64 - lo as i64) / p as i64).max(1)) as i32;
        let mut hist = vec![0usize; p];
        let ids: Vec<u32> = data
            .iter()
            .map(|&v| {
                let b = (((v as i64 - lo as i64) / sub as i64) as usize).min(p - 1);
                hist[b] += 1;
                b as u32
            })
            .collect();
        (ids, hist, lo, sub)
    }

    #[test]
    fn xla_divide_matches_native_exact_chunk() {
        let reg = registry();
        let data = workload::random(CHUNK, 42);
        let xd = XlaDivide::new(&reg, 36).unwrap();
        let out = xd.divide(&data).unwrap();
        let (ids, hist, lo, sub) = native_divide(&data, 36);
        assert_eq!(out.lo, lo);
        assert_eq!(out.sub, sub);
        assert_eq!(out.ids, ids);
        assert_eq!(out.hist, hist);
    }

    #[test]
    fn xla_divide_matches_native_with_padding() {
        let reg = registry();
        let data = workload::random(CHUNK + 12_345, 43);
        let xd = XlaDivide::new(&reg, 18).unwrap();
        let out = xd.divide(&data).unwrap();
        let (ids, hist, lo, sub) = native_divide(&data, 18);
        assert_eq!(out.lo, lo);
        assert_eq!(out.sub, sub);
        assert_eq!(out.ids, ids);
        assert_eq!(out.hist, hist);
        assert_eq!(out.hist.iter().sum::<usize>(), data.len());
    }

    #[test]
    fn xla_divide_small_input() {
        let reg = registry();
        let data = workload::sorted(1000, 7);
        let xd = XlaDivide::new(&reg, 36).unwrap();
        let out = xd.divide(&data).unwrap();
        assert_eq!(out.hist.iter().sum::<usize>(), 1000);
        // Monotone ids on sorted input.
        assert!(out.ids.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn xla_splitter_partition_matches_searchsorted() {
        let reg = registry();
        let sp = XlaSplitterPartition::new(&reg, 36).unwrap();
        let data = workload::random(CHUNK + 777, 5);
        let mut splitters: Vec<i32> = (1..36)
            .map(|k| (k as i64 * (1 << 24) / 36) as i32)
            .collect();
        splitters.sort_unstable();
        let (ids, hist) = sp.partition(&data, &splitters).unwrap();
        assert_eq!(hist.iter().sum::<usize>(), data.len());
        for (&v, &b) in data.iter().zip(&ids) {
            let expect = splitters.partition_point(|&s| s < v);
            assert_eq!(b as usize, expect, "v={v}");
        }
        // Wrong splitter count rejected.
        assert!(sp.partition(&data, &splitters[..10]).is_err());
    }

    #[test]
    fn xla_bitonic_sorts_payloads() {
        let reg = registry();
        let sorter = XlaSortBlocks::new(&reg, 1024).unwrap();
        for n in [1usize, 100, 1024, 5000, CHUNK] {
            let data = workload::random(n, n as u64);
            let got = sorter.sort(&data).unwrap();
            let mut expect = data;
            expect.sort_unstable();
            assert_eq!(got, expect, "n={n}");
        }
    }
}
