//! Persistent work-stealing executor — the one thread pool behind every
//! parallel phase of the sort pipeline.
//!
//! Before this module existed the hot path paid OS-thread spawn/teardown
//! *inside* the timed parallel region: `divide_native` stood up a fresh
//! scoped-thread team three times per sort (min/max, classify+histogram,
//! scatter), the Waves simulator spawned a fourth for the local sorts,
//! and every service job re-paid all of it.  The executor amortizes that
//! cost to zero after warmup: a lazily-initialized pool of long-lived
//! workers (per-worker FIFO deques plus a shared injector, work stealing
//! between them, park/unpark when idle) and a scope-style API that — like
//! `std::thread::scope` — lets tasks borrow stack data.
//!
//! Design notes:
//!
//! * **Scopes, not futures.**  [`Executor::scope`] blocks until every
//!   task submitted inside it has completed, which is what makes the
//!   borrowed-data lifetime erasure sound (see the `SAFETY` comment on
//!   [`Scope::submit`]).  All submission happens inside the scope
//!   closure; a task itself never holds a `&Scope`, so the scope
//!   wait-for graph is a strict fork/join tree — no wait cycles.
//! * **Callers help, within their scope.**  A thread waiting for its
//!   scope does not park while that scope has queued tasks — it digs
//!   them out of the deques/injector and executes them.  Helping never
//!   adopts *unrelated* work: a timed wait (a campaign cell's parallel
//!   region, a service job's sort latency) is never contaminated by
//!   another tenant's tasks.  Nested scopes opened from inside a pool
//!   task therefore cannot deadlock, and a scope completes even on a
//!   pool with zero workers.
//! * **Panics are contained.**  A panicking task never kills a worker;
//!   the first payload is stashed and re-thrown from `scope` on the
//!   submitting thread after the remaining tasks finish.
//!
//! The crate-wide singleton is [`Executor::global`]; private pools
//! (mainly for tests) come from [`Executor::new`].

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::mem;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A lifetime-erased unit of work (see [`Scope::submit`] for why the
/// erasure is sound).
type TaskFn = Box<dyn FnOnce() + Send + 'static>;

/// One queued task plus the scope it reports completion to.
struct Task {
    run: TaskFn,
    scope: Arc<ScopeState>,
}

/// Completion accounting for one scope.
struct ScopeState {
    sync: Mutex<ScopeSync>,
    done: Condvar,
}

struct ScopeSync {
    /// Tasks submitted and not yet finished.
    pending: usize,
    /// First panic payload caught in a task, re-thrown by `scope`.
    panic: Option<Box<dyn Any + Send>>,
}

/// State under the pool's injector lock (the `idle` condvar's mutex).
struct PoolShared {
    /// Externally submitted tasks (and the steal target of last resort).
    injector: VecDeque<Task>,
    /// Set once by [`Executor::drop`]; workers exit when idle.
    shutdown: bool,
}

struct Pool {
    shared: Mutex<PoolShared>,
    idle: Condvar,
    /// Bumped (SeqCst) on every push anywhere — parked workers re-check
    /// it, which closes the scan-then-park wakeup race without funneling
    /// worker-local pushes through the shared mutex.
    epoch: AtomicU64,
    /// Workers currently parked on `idle` (moved while holding `shared`,
    /// read lock-free by pushers) — lets a push skip the wakeup syscall
    /// entirely while every worker is busy.
    sleepers: AtomicUsize,
    /// Mirror of `shared.injector.len()`, maintained under the lock and
    /// read lock-free — dispatch skips the shared mutex when the
    /// injector is empty (the common state for worker-local waves).
    injector_len: AtomicUsize,
    /// Per-worker FIFO deques: owner pops the front, thieves the back.
    locals: Vec<Mutex<VecDeque<Task>>>,
}

thread_local! {
    /// `(pool identity, worker index)` when the current thread is a pool
    /// worker — routes its submissions to its own deque.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

impl Pool {
    fn identity(&self) -> usize {
        self as *const Pool as usize
    }

    /// Index of the current thread's deque, if it is a worker *of this
    /// pool* (a worker of pool A submitting to pool B is external to B).
    fn my_index(&self) -> Option<usize> {
        match WORKER.with(Cell::get) {
            Some((pid, idx)) if pid == self.identity() => Some(idx),
            _ => None,
        }
    }

    fn push(&self, task: Task) {
        if let Some(idx) = self.my_index() {
            // Worker-local fast path: own deque plus two lock-free
            // atomics — the shared mutex is untouched unless a worker
            // is actually parked.
            self.locals[idx].lock().unwrap().push_back(task);
        } else {
            let mut sh = self.shared.lock().unwrap();
            sh.injector.push_back(task);
            self.injector_len.store(sh.injector.len(), Ordering::SeqCst);
        }
        // The scan-then-park race window: a worker may be between its
        // empty scan and its epoch re-check right now.
        crate::interleave!("executor/push-epoch");
        self.epoch.fetch_add(1, Ordering::SeqCst);
        crate::interleave!("executor/push-sleepers");
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            // Lock-then-notify: a parking worker holds `shared` from its
            // final epoch re-check until `wait` releases it, so this
            // notify lands either before that re-check (which then sees
            // the bumped epoch) or after the park (and wakes it).
            let _guard = self.shared.lock().unwrap();
            self.idle.notify_all();
        }
    }

    /// Pop one runnable task from anywhere: own deque front, then the
    /// injector, then steal another worker's deque back.
    fn find_task(&self) -> Option<Task> {
        let me = self.my_index();
        if let Some(idx) = me {
            if let Some(t) = self.locals[idx].lock().unwrap().pop_front() {
                return Some(t);
            }
        }
        if self.injector_len.load(Ordering::SeqCst) > 0 {
            let mut sh = self.shared.lock().unwrap();
            if let Some(t) = sh.injector.pop_front() {
                self.injector_len.store(sh.injector.len(), Ordering::SeqCst);
                return Some(t);
            }
        }
        for (j, deque) in self.locals.iter().enumerate() {
            if Some(j) == me {
                continue;
            }
            if let Some(t) = deque.lock().unwrap().pop_back() {
                return Some(t);
            }
        }
        None
    }

    /// Dig the first task belonging to `state` out of the queues — the
    /// scope-filtered variant of [`Pool::find_task`] used while waiting
    /// out a scope, so a timed wait never adopts unrelated work.
    fn find_scope_task(&self, state: &ScopeState) -> Option<Task> {
        let target: *const ScopeState = state;
        let me = self.my_index();
        if let Some(idx) = me {
            let mut deque = self.locals[idx].lock().unwrap();
            if let Some(t) = take_scope_task(&mut deque, target) {
                return Some(t);
            }
        }
        if self.injector_len.load(Ordering::SeqCst) > 0 {
            let mut sh = self.shared.lock().unwrap();
            if let Some(t) = take_scope_task(&mut sh.injector, target) {
                self.injector_len.store(sh.injector.len(), Ordering::SeqCst);
                return Some(t);
            }
        }
        for (j, deque) in self.locals.iter().enumerate() {
            if Some(j) == me {
                continue;
            }
            let mut deque = deque.lock().unwrap();
            if let Some(t) = take_scope_task(&mut deque, target) {
                return Some(t);
            }
        }
        None
    }

    /// Execute one task, containing any panic and reporting completion
    /// to its scope.
    fn run_task(&self, task: Task) {
        let Task { run, scope } = task;
        let result = catch_unwind(AssertUnwindSafe(run));
        // Completion racing the scope waiter's pending re-check.
        crate::interleave!("executor/task-complete");
        let mut sync = scope.sync.lock().unwrap();
        if let Err(payload) = result {
            if sync.panic.is_none() {
                sync.panic = Some(payload);
            }
        }
        sync.pending -= 1;
        let finished = sync.pending == 0;
        drop(sync);
        if finished {
            scope.done.notify_all();
        }
    }

    /// Block until `state.pending == 0`, executing this scope's queued
    /// tasks instead of idling.  Every task of `state` was pushed before
    /// this is called, so a filtered sweep that finds nothing means the
    /// stragglers are executing on other threads — then parking on the
    /// scope condvar is safe (completion notifies it; scopes form a
    /// fork/join tree, so the threads executing them make progress).
    fn wait_scope(&self, state: &ScopeState) {
        loop {
            crate::interleave!("executor/wait-scope");
            if state.sync.lock().unwrap().pending == 0 {
                return;
            }
            if let Some(t) = self.find_scope_task(state) {
                self.run_task(t);
                continue;
            }
            let sync = state.sync.lock().unwrap();
            if sync.pending == 0 {
                return;
            }
            let guard = state.done.wait(sync).unwrap();
            drop(guard);
        }
    }

    /// Long-lived worker body: run tasks while any exist, park otherwise.
    fn worker_loop(&self) {
        loop {
            let seen = self.epoch.load(Ordering::SeqCst);
            if let Some(t) = self.find_task() {
                self.run_task(t);
                continue;
            }
            let mut sh = self.shared.lock().unwrap();
            if sh.shutdown {
                return;
            }
            if let Some(t) = sh.injector.pop_front() {
                self.injector_len.store(sh.injector.len(), Ordering::SeqCst);
                drop(sh);
                self.run_task(t);
                continue;
            }
            // Park only if nothing was pushed since the (empty) scan.
            // SeqCst ordering makes the race two-sided: a pusher either
            // bumps the epoch before the re-check below (we rescan), or
            // its later sleeper-count read sees the increment we publish
            // first (it notifies).
            crate::interleave!("executor/park-announce");
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            crate::interleave!("executor/park-recheck");
            if self.epoch.load(Ordering::SeqCst) == seen {
                sh = self.idle.wait(sh).unwrap();
            }
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
            if sh.shutdown {
                return;
            }
            drop(sh);
        }
    }
}

/// Handle to a worker pool.  Dropping a (non-global) executor shuts its
/// workers down once they go idle; the global instance lives for the
/// process.
pub struct Executor {
    pool: Arc<Pool>,
    workers: usize,
}

impl Executor {
    /// Build a private pool with `workers` long-lived threads.  `0` is
    /// legal: scopes then execute entirely on the submitting thread via
    /// the helping loop (deterministic mode for tests).
    pub fn new(workers: usize) -> Executor {
        let pool = Arc::new(Pool {
            shared: Mutex::new(PoolShared {
                injector: VecDeque::new(),
                shutdown: false,
            }),
            idle: Condvar::new(),
            epoch: AtomicU64::new(0),
            sleepers: AtomicUsize::new(0),
            injector_len: AtomicUsize::new(0),
            locals: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
        });
        for idx in 0..workers {
            let pool = Arc::clone(&pool);
            std::thread::Builder::new()
                .name(format!("ohhc-exec-{idx}"))
                .spawn(move || {
                    WORKER.with(|w| w.set(Some((pool.identity(), idx))));
                    pool.worker_loop();
                })
                .expect("spawn executor worker");
        }
        Executor { pool, workers }
    }

    /// The process-wide shared pool, spun up on first use with one worker
    /// per hardware thread (override with `OHHC_POOL_WORKERS`).  Every
    /// sort-pipeline layer — divide waves, Waves local sorts, campaign
    /// sweeps, service jobs — submits here, so a burst of small jobs
    /// never multiplies thread-spawn cost by job count.
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let workers = std::env::var("OHHC_POOL_WORKERS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(crate::util::par::available_workers);
            Executor::new(workers)
        })
    }

    /// Worker threads owned by this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` with a [`Scope`] whose tasks may borrow anything that
    /// outlives the call, then block until every submitted task has
    /// finished.  The first task panic (or a panic in `f` itself) is
    /// re-thrown here after the remaining tasks complete, so borrowed
    /// data is never observable by a live task past this frame.
    pub fn scope<'scope, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'scope>) -> R,
    {
        let state = Arc::new(ScopeState {
            sync: Mutex::new(ScopeSync {
                pending: 0,
                panic: None,
            }),
            done: Condvar::new(),
        });
        let scope = Scope {
            pool: Arc::clone(&self.pool),
            state: Arc::clone(&state),
            _marker: PhantomData,
        };
        // `f` may panic after submitting tasks; the wait below must still
        // happen before this frame unwinds (tasks borrow from it).
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        self.pool.wait_scope(&state);
        let task_panic = state.sync.lock().unwrap().panic.take();
        match result {
            Err(payload) => resume_unwind(payload),
            Ok(value) => {
                if let Some(payload) = task_panic {
                    resume_unwind(payload);
                }
                value
            }
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let mut sh = self.pool.shared.lock().unwrap();
        sh.shutdown = true;
        self.pool.epoch.fetch_add(1, Ordering::SeqCst);
        self.pool.idle.notify_all();
        drop(sh);
    }
}

/// Remove the first task belonging to `target` from a queue (not just
/// the ends — a matching task may sit behind unrelated work).
fn take_scope_task(queue: &mut VecDeque<Task>, target: *const ScopeState) -> Option<Task> {
    let idx = queue.iter().position(|t| std::ptr::eq(Arc::as_ptr(&t.scope), target))?;
    queue.remove(idx)
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor").field("workers", &self.workers).finish()
    }
}

/// Submission surface passed to the [`Executor::scope`] closure.
///
/// The `'scope` lifetime is invariant (the `PhantomData` below), exactly
/// as in `std::thread::scope` — it pins the set of borrows tasks may
/// capture to data that strictly outlives the `scope` call.
pub struct Scope<'scope> {
    pool: Arc<Pool>,
    state: Arc<ScopeState>,
    _marker: PhantomData<&'scope mut &'scope ()>,
}

impl<'scope> Scope<'scope> {
    /// Submit one task.  It may run on any pool worker — or on the
    /// submitting thread itself while it waits out the scope.
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        self.state.sync.lock().unwrap().pending += 1;
        let run: Box<dyn FnOnce() + Send + 'scope> = Box::new(f);
        // SAFETY: the `'scope` borrow is erased to `'static` only for
        // storage in the queues.  `Executor::scope` does not return (or
        // unwind) before `wait_scope` has observed `pending == 0`, i.e.
        // before this closure has been called and dropped, so it never
        // outlives the data it borrows.
        let run = unsafe {
            mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, TaskFn>(run)
        };
        self.pool.push(Task {
            run,
            scope: Arc::clone(&self.state),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_every_task_and_returns_value() {
        let exec = Executor::new(3);
        let total = AtomicUsize::new(0);
        let out = exec.scope(|s| {
            for i in 0..100usize {
                let total = &total;
                s.submit(move || {
                    total.fetch_add(i, Ordering::Relaxed);
                });
            }
            "done"
        });
        assert_eq!(out, "done");
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn tasks_borrow_and_mutate_disjoint_stack_data() {
        let exec = Executor::new(2);
        let mut slots = vec![0usize; 64];
        exec.scope(|s| {
            for (i, slot) in slots.iter_mut().enumerate() {
                s.submit(move || *slot = i * i);
            }
        });
        for (i, &v) in slots.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn saturation_many_more_tasks_than_workers_no_deadlock() {
        let exec = Executor::new(2);
        let count = AtomicUsize::new(0);
        exec.scope(|s| {
            for _ in 0..10_000 {
                let count = &count;
                s.submit(move || {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 10_000);
    }

    #[test]
    fn zero_worker_pool_completes_scopes_on_the_caller() {
        // Correctness must never depend on pool workers existing: the
        // scope caller helps until the count drains.
        let exec = Executor::new(0);
        let count = AtomicUsize::new(0);
        exec.scope(|s| {
            for _ in 0..500 {
                let count = &count;
                s.submit(move || {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn nested_scopes_from_pool_tasks_do_not_deadlock() {
        // Outer tasks occupy every worker, then each opens an inner
        // scope on the same pool — the workers must help themselves.
        let exec = Executor::new(2);
        let count = AtomicUsize::new(0);
        exec.scope(|s| {
            for _ in 0..4 {
                let count = &count;
                let exec = &exec;
                s.submit(move || {
                    exec.scope(|inner| {
                        for _ in 0..8 {
                            inner.submit(move || {
                                count.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn external_thread_submission_like_a_service_worker() {
        // A long-lived non-pool thread (the service worker pattern)
        // submits through the injector and helps drain its own scope.
        let exec = Executor::new(1);
        let count = AtomicUsize::new(0);
        std::thread::scope(|ts| {
            for _ in 0..3 {
                let exec = &exec;
                let count = &count;
                ts.spawn(move || {
                    exec.scope(|s| {
                        for _ in 0..50 {
                            s.submit(move || {
                                count.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 150);
    }

    #[test]
    fn panic_in_task_is_contained_and_rethrown() {
        let exec = Executor::new(2);
        let survivors = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.scope(|s| {
                for i in 0..16 {
                    let survivors = &survivors;
                    s.submit(move || {
                        if i == 7 {
                            panic!("task 7 exploded");
                        }
                        survivors.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }));
        assert!(result.is_err(), "scope must rethrow the task panic");
        // Every non-panicking task still ran to completion.
        assert_eq!(survivors.load(Ordering::Relaxed), 15);
        // ...and the pool survived: workers are intact for the next scope.
        let after = AtomicUsize::new(0);
        exec.scope(|s| {
            for _ in 0..32 {
                let after = &after;
                s.submit(move || {
                    after.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(after.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = Executor::global() as *const Executor;
        let b = Executor::global() as *const Executor;
        assert_eq!(a, b);
        assert!(Executor::global().workers() >= 1);
    }
}
