//! Schedule-fuzzing race harness — seeded, replayable preemption
//! injection for the crate's lock-free and unsafe-bearing paths.
//!
//! Nine PRs of growth left this crate with a concurrency-heavy core:
//! the work-stealing executor's park/unpark epochs, `util::par`'s
//! index-claiming slot arrays, the ticket slot state machine
//! (Queued → Claimed → Done → Taken | Cancelled), and the cluster's
//! split-job completion slots.  Plain `cargo test` exercises only the
//! interleavings the host scheduler happens to produce; this module
//! widens that set deterministically.
//!
//! Two complementary tools live here:
//!
//! 1. **Seeded preemption injection.**  Hot concurrency code is
//!    sprinkled with [`crate::interleave!`] points.  In a default
//!    build the macro expands to *nothing* — zero code, zero cost.
//!    Compiled with `--features schedules`, each crossing consults
//!    [`decision`], a pure function of `(seed, site, k)` where `k` is
//!    the crossing count of that site, and either runs on, yields the
//!    OS slice, or spins — perturbing the schedule around exactly the
//!    operations whose orderings matter (park/unpark, claim/cancel,
//!    publish/drain).  Because the decision stream per site is a pure
//!    function of the seed, a failing seed printed by the smoke test
//!    replays its decision schedule **bit-identically** (the OS still
//!    owns final thread placement; the injected perturbation — which
//!    crossing yields, which spins — is exact).
//! 2. **Exhaustive small-state-space enumeration.**  For state
//!    machines small enough to enumerate, [`interleavings`] yields
//!    every merge order of two operation sequences; the ticket
//!    cancel-vs-claim model test and the `ShardHealth` breaker walk
//!    run the *real* production types through every single ordering
//!    instead of sampling.
//!
//! The injection state is process-global and inert until [`fuzz`]
//! activates it; sessions serialize on an internal lock so two
//! concurrently running `#[test]`s cannot mix seeds.  Everything here
//! is dependency-free (crate policy) and wall-clock-free (decisions
//! are counter-driven, so the harness itself cannot introduce timing
//! nondeterminism).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::topology::fault::splitmix64;

/// What one crossing of an interleave point does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Proceed without perturbation.
    Run,
    /// Give up the OS time slice (`std::thread::yield_now`).
    Yield,
    /// Busy-spin briefly — perturbs relative progress without a
    /// syscall, catching races a full reschedule would mask.
    Spin,
}

/// FNV-1a over the site name — the crate's standard string hash,
/// re-rolled here so `runtime` stays independent of `service`.
fn site_hash(site: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in site.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The pure decision function: what the `k`-th crossing of `site`
/// does under `seed`.  This is the whole determinism story — no
/// hidden state, so replaying a seed replays every site's decision
/// stream exactly.
pub fn decision(seed: u64, site: &str, k: u64) -> Decision {
    let h = splitmix64(
        seed ^ site_hash(site).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ k.wrapping_mul(0xBF58_476D_1CE4_E5B9),
    );
    match h % 8 {
        0 | 1 => Decision::Yield,
        2 => Decision::Spin,
        _ => Decision::Run,
    }
}

/// Per-site crossing counters, indexed by site-name hash.  A hash
/// collision merely merges two sites' counter streams — decisions stay
/// deterministic because [`decision`] hashes the site name itself.
const SITE_SLOTS: usize = 64;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SEED: AtomicU64 = AtomicU64::new(0);
static CROSSINGS: AtomicU64 = AtomicU64::new(0);
// A const item (not an inline-const repeat) keeps the crate's declared
// MSRV: each array element gets its own copy of the initializer.
#[allow(clippy::declare_interior_mutable_const)]
const COUNTER_ZERO: AtomicU64 = AtomicU64::new(0);
static COUNTERS: [AtomicU64; SITE_SLOTS] = [COUNTER_ZERO; SITE_SLOTS];

/// Serializes fuzz sessions: two concurrent sessions would race on
/// [`SEED`], silently breaking seed replay.
static SESSION: Mutex<()> = Mutex::new(());

/// Disarms injection when the session closure unwinds, so a failing
/// (panicking) fuzz test cannot leave perturbation armed for the rest
/// of the test binary.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
    }
}

/// Run `f` with schedule perturbation armed under `seed`, then disarm.
///
/// Counters reset at entry, so the same seed always sees the same
/// decision stream regardless of what ran before.  Sessions are
/// process-exclusive (internal lock); nesting deadlocks by design —
/// a fuzzed region must not re-arm itself.
///
/// In a build without `--features schedules` no interleave point is
/// compiled in, so this runs `f` unperturbed — callers can share one
/// test body between the plain and fuzzed suites.
pub fn fuzz<R>(seed: u64, f: impl FnOnce() -> R) -> R {
    let _session = SESSION.lock().unwrap_or_else(|poison| poison.into_inner());
    for c in &COUNTERS {
        c.store(0, Ordering::SeqCst);
    }
    CROSSINGS.store(0, Ordering::SeqCst);
    SEED.store(seed, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    let disarm = Disarm;
    let out = f();
    drop(disarm);
    out
}

/// Total interleave-point crossings observed by the current (or most
/// recent) fuzz session — the smoke test's "did the harness actually
/// bite" assertion.
pub fn crossings() -> u64 {
    CROSSINGS.load(Ordering::SeqCst)
}

/// One interleave-point crossing.  Call through [`crate::interleave!`],
/// never directly — the macro is what keeps default builds free of the
/// hook.  Inert (one relaxed load) unless a [`fuzz`] session is live.
pub fn interleave_point(site: &str) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    CROSSINGS.fetch_add(1, Ordering::Relaxed);
    let slot = (site_hash(site) % SITE_SLOTS as u64) as usize;
    let k = COUNTERS[slot].fetch_add(1, Ordering::Relaxed);
    match decision(SEED.load(Ordering::Relaxed), site, k) {
        Decision::Run => {}
        Decision::Yield => std::thread::yield_now(),
        Decision::Spin => {
            for _ in 0..64 {
                std::hint::spin_loop();
            }
        }
    }
}

/// Every way to merge two operation sequences of lengths `a` and `b`
/// while preserving each sequence's internal order: `C(a + b, a)`
/// schedules, each a vector of booleans (`true` = next op of A,
/// `false` = next op of B).
///
/// This is the enumerator behind the exhaustive model tests: run the
/// real type through *all* schedules of two logical threads instead
/// of whichever ones the host scheduler samples.  Keep `a + b` small —
/// the count is binomial.
pub fn interleavings(a: usize, b: usize) -> Vec<Vec<bool>> {
    fn rec(a: usize, b: usize, cur: &mut Vec<bool>, out: &mut Vec<Vec<bool>>) {
        if a == 0 && b == 0 {
            out.push(cur.clone());
            return;
        }
        if a > 0 {
            cur.push(true);
            rec(a - 1, b, cur, out);
            cur.pop();
        }
        if b > 0 {
            cur.push(false);
            rec(a, b - 1, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(a, b, &mut Vec::with_capacity(a + b), &mut out);
    out
}

/// Inject a schedule perturbation point (see [`crate::runtime::check`]).
///
/// Expands to nothing unless the crate is compiled with
/// `--features schedules`, so production and tier-1 test builds carry
/// zero overhead — not even a branch.  Under the feature, each
/// crossing consults the seeded decision function of the live
/// [`fuzz`](crate::runtime::check::fuzz) session (and is inert when no
/// session is armed).
///
/// ```
/// # fn claim_slot() {}
/// ohhc_qsort::interleave!("doc/claim");
/// claim_slot();
/// ```
#[macro_export]
macro_rules! interleave {
    ($site:expr) => {{
        #[cfg(feature = "schedules")]
        $crate::runtime::check::interleave_point($site);
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_stream_is_a_pure_function_of_seed_site_and_index() {
        // Bit-identical replay: the decision stream for a seed is the
        // same however many times it is recomputed...
        let a: Vec<Decision> = (0..256).map(|k| decision(42, "executor/push", k)).collect();
        let b: Vec<Decision> = (0..256).map(|k| decision(42, "executor/push", k)).collect();
        assert_eq!(a, b);
        // ...perturbs at least once over a realistic window (3/8 of
        // crossings yield in expectation)...
        assert!(a.iter().any(|&d| d != Decision::Run), "seed 42 never perturbed");
        // ...and distinct seeds / sites give distinct streams.
        let other_seed: Vec<Decision> =
            (0..256).map(|k| decision(43, "executor/push", k)).collect();
        let other_site: Vec<Decision> = (0..256).map(|k| decision(42, "ticket/claim", k)).collect();
        assert_ne!(a, other_seed);
        assert_ne!(a, other_site);
    }

    #[test]
    fn fuzz_session_arms_resets_and_disarms() {
        // Without the `schedules` feature no call site is compiled in,
        // so drive the hook directly: the session must count crossings
        // and reset its counters per session (seed replay).
        let first = fuzz(7, || {
            for _ in 0..10 {
                interleave_point("check/self");
            }
            crossings()
        });
        assert_eq!(first, 10);
        let second = fuzz(7, || {
            for _ in 0..10 {
                interleave_point("check/self");
            }
            crossings()
        });
        assert_eq!(second, 10, "counters must reset between sessions");
        // Disarmed outside a session: crossings stay frozen.
        interleave_point("check/self");
        assert_eq!(crossings(), 10);
    }

    #[test]
    fn fuzz_disarms_even_when_the_body_panics() {
        let result = std::panic::catch_unwind(|| {
            fuzz(3, || panic!("fuzzed body failed"));
        });
        assert!(result.is_err());
        let before = crossings();
        interleave_point("check/after-panic");
        assert_eq!(crossings(), before, "injection must disarm on unwind");
    }

    #[test]
    fn interleavings_enumerate_the_full_binomial() {
        // C(4, 2) = 6 merges of two 2-op sequences.
        let all = interleavings(2, 2);
        assert_eq!(all.len(), 6);
        // Every schedule has exactly two ops of each thread, and all
        // schedules are distinct.
        for s in &all {
            assert_eq!(s.len(), 4);
            assert_eq!(s.iter().filter(|&&x| x).count(), 2);
        }
        let mut uniq = all.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), all.len());
        // Degenerate shapes.
        assert_eq!(interleavings(0, 0), vec![Vec::<bool>::new()]);
        assert_eq!(interleavings(1, 0), vec![vec![true]]);
        // C(7, 3) = 35.
        assert_eq!(interleavings(3, 4).len(), 35);
    }
}
