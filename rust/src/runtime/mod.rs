//! XLA/PJRT runtime — loads the AOT-compiled L1/L2 artifacts and runs them
//! from the rust hot path.  Python never executes at request time.
//!
//! Flow (see /opt/xla-example/load_hlo/ for the reference wiring):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file(artifact)` →
//! `client.compile(...)` → `executable.execute(...)`.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (aot.py documents the same constraint).

mod artifact;
mod executor;

pub use artifact::{ArtifactManifest, ArtifactRegistry, ArtifactSig};
pub use executor::{DivideOutput, XlaDivide, XlaSortBlocks, XlaSplitterPartition, CHUNK};
