//! Runtime substrate shared by every layer of the sort pipeline.
//!
//! * [`Executor`] — the persistent work-stealing thread pool behind all
//!   of `util::par`: divide task waves, Waves-mode local sorts, campaign
//!   sweep concurrency, and service jobs all submit here, so the hot
//!   path spawns zero threads after warmup.
//! * XLA/PJRT loading ([`ArtifactRegistry`], [`XlaDivide`], …) — loads
//!   the AOT-compiled L1/L2 artifacts and runs them from the rust hot
//!   path; Python never executes at request time.
//! * [`check`] — the schedule-fuzzing race harness: seeded preemption
//!   injection behind the zero-cost [`crate::interleave!`] points
//!   threaded through the executor, `util::par`, the ticket slot
//!   machine, and the cluster completion slots, plus the exhaustive
//!   interleaving enumerator the model tests run on.
//!
//! XLA flow (see /opt/xla-example/load_hlo/ for the reference wiring):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file(artifact)` →
//! `client.compile(...)` → `executable.execute(...)`.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (aot.py documents the same constraint).

mod artifact;
pub mod check;
mod executor;
mod xla_exec;

pub use artifact::{ArtifactManifest, ArtifactRegistry, ArtifactSig};
pub use executor::{Executor, Scope};
pub use xla_exec::{DivideOutput, XlaDivide, XlaSortBlocks, XlaSplitterPartition, CHUNK};
