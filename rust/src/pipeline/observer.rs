//! Stage-boundary hooks: progress reporting, service stats, and bench
//! probes observe a [`Session`] instead of inlining timing code into
//! the drivers.
//!
//! [`Session`]: crate::pipeline::Session

use std::sync::Mutex;
use std::time::Duration;

use crate::pipeline::trace::{Stage, StageTrace};

/// A stage-boundary hook.  The session invokes `on_stage` once per
/// completed transition, from whichever thread drives the session —
/// implementations must be cheap and thread-safe (the service installs
/// one shared observer across every worker).
pub trait Observer {
    /// `stage` just finished after `elapsed` of wall time; `trace`
    /// holds everything recorded so far (including this stage).
    fn on_stage(&self, stage: Stage, elapsed: Duration, trace: &StageTrace);
}

/// Observer that records every stage event — the test/bench probe.
#[derive(Debug, Default)]
pub struct CollectingObserver {
    events: Mutex<Vec<(Stage, Duration)>>,
}

impl CollectingObserver {
    /// Fresh, empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Every `(stage, elapsed)` event observed so far, in firing order.
    pub fn events(&self) -> Vec<(Stage, Duration)> {
        self.events.lock().unwrap().clone()
    }

    /// Stage labels in firing order (compact assertion helper).
    pub fn stages(&self) -> Vec<&'static str> {
        self.events.lock().unwrap().iter().map(|(s, _)| s.label()).collect()
    }
}

impl Observer for CollectingObserver {
    fn on_stage(&self, stage: Stage, elapsed: Duration, _trace: &StageTrace) {
        self.events.lock().unwrap().push((stage, elapsed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_records_in_order() {
        let c = CollectingObserver::new();
        let trace = StageTrace::default();
        c.on_stage(Stage::Divide, Duration::from_micros(1), &trace);
        c.on_stage(Stage::LocalSort, Duration::from_micros(2), &trace);
        c.on_stage(Stage::Gather, Duration::from_micros(3), &trace);
        assert_eq!(c.stages(), vec!["divide", "local_sort", "gather"]);
        let events = c.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[2].1, Duration::from_micros(3));
    }
}
