//! One typestate pipeline API behind every driver.
//!
//! The paper's algorithm is a single fixed pipeline — array division
//! (§3.1), scatter, parallel local Quick Sort, three-phase gather
//! (§5) — yet it runs under several execution modes (Fasha's
//! comparative study frames exactly this: one algorithm, many modes).
//! A [`Session`] makes the pipeline itself the first-class object and
//! plugs the modes in as [`Engine`]s:
//!
//! ```text
//! Session<Configured> --divide()--> Session<Divided>
//!                     --local_sort()--> Session<Sorted>
//!                     --gather()--> Outcome
//! ```
//!
//! Each state owns **exactly** the data legal at that stage: the
//! [`FlatBuckets`](crate::dataplane::FlatBuckets) arena threads
//! through by move, so the zero-copy guarantee (the sorted output *is*
//! the divide allocation) is structural, not conventional.  Each
//! transition records its wall time into a [`StageTrace`], and an
//! [`Observer`] hook fires at every stage boundary — campaign
//! reports, service stats, and bench probes subscribe there instead of
//! inlining timing code into drivers.
//!
//! Every driver in the crate runs through a session: the coordinator's
//! [`OhhcSorter`](crate::coordinator::OhhcSorter) is a thin
//! config-to-`Session` adapter, service-pool workers drive sessions
//! stage by stage (so the pool can interleave stages of different
//! jobs on the shared executor), and the batcher's coalesced pass is a
//! [`Session::batched`] over a multi-span arena.
//!
//! # Example
//!
//! ```
//! use ohhc_qsort::config::Construction;
//! use ohhc_qsort::pipeline::{Engine, Session};
//! use ohhc_qsort::schedule::TopologyBundle;
//!
//! let bundle = TopologyBundle::build(1, Construction::FullGroup)?;
//! let data = ohhc_qsort::workload::random(10_000, 7);
//! let outcome = Session::single(&bundle.net, &bundle.plans, &data)
//!     .with_engine(Engine::Pooled)
//!     .divide()?
//!     .local_sort()?
//!     .gather()?;
//! assert!(outcome.sorted.windows(2).all(|w| w[0] <= w[1]));
//! assert_eq!(outcome.sorted.len(), 10_000);
//! # Ok::<(), ohhc_qsort::Error>(())
//! ```
//!
//! # Stage order is enforced at compile time
//!
//! A `Session<Configured>` has no `gather` (or `local_sort`) method —
//! skipping a stage is a type error, not a runtime panic:
//!
//! ```compile_fail
//! use ohhc_qsort::config::Construction;
//! use ohhc_qsort::pipeline::Session;
//! use ohhc_qsort::schedule::TopologyBundle;
//!
//! let bundle = TopologyBundle::build(1, Construction::FullGroup).unwrap();
//! let data = vec![3, 1, 2];
//! // ERROR: `gather` is only reachable from `Session<Sorted>`.
//! let _ = Session::single(&bundle.net, &bundle.plans, &data).gather();
//! ```

mod observer;
mod session;
mod trace;

pub use observer::{CollectingObserver, Observer};
pub use session::{Configured, Divided, Engine, Outcome, Session, Sorted};
pub use trace::{Stage, StageTrace};
