//! The typestate pipeline session — see the [module docs](crate::pipeline).

use std::ops::Range;
use std::time::{Duration, Instant};

use crate::config::{DivideEngine, DivideStrategy, LinkModel};
use crate::coordinator::divide_with_strategy;
use crate::dataplane::FlatBuckets;
use crate::error::{Error, Result, StageError};
use crate::pipeline::observer::Observer;
use crate::pipeline::trace::{Stage, StageTrace};
use crate::runtime::ArtifactRegistry;
use crate::schedule::NodePlan;
use crate::service::batcher::coalesce;
use crate::sim::engine::{DesOutcome, DesSimulator};
use crate::sim::threaded::{finish_gather, DirectRun, ThreadedSimulator};
use crate::sort::{Quicksort, SortCounters};
use crate::topology::fault::{route_avoiding, FaultSet, RouteOutcome};
use crate::topology::ohhc::Ohhc;

/// How the local-sort and gather stages execute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Engine {
    /// Pooled waves on the persistent executor (the Waves mode): a
    /// local-sort task wave over the arena segments, then the
    /// bookkeeping gather.  The fast mode for sweeps and the service.
    Pooled,
    /// The paper's §5 methodology: one OS thread per simulated
    /// processor, local sort and gather overlapped inside one thread
    /// region.  Stage times split the fused region on its critical
    /// path (see [`StageTrace`]).
    DirectThreads,
    /// Real instrumented local sorts feeding the discrete-event
    /// simulator; the gather runs in virtual time under `link`.
    DiscreteEvent {
        /// Electrical/optical link timing parameters.
        link: LinkModel,
    },
}

/// Typestate marker + payload: a configured session that has not
/// divided yet.  Holds (only) the input keys.
pub struct Configured<'d> {
    input: Input<'d>,
}

enum Input<'d> {
    Single(&'d [i32]),
    Batched(Vec<&'d [i32]>),
}

/// Typestate marker + payload: the input has been divided; the state
/// owns the scattered arena and the per-job spans.
pub struct Divided {
    buckets: FlatBuckets,
    total: usize,
    spans: Vec<Range<usize>>,
    imbalance: f64,
    skew_redivides: u32,
}

/// Typestate marker + payload: every bucket segment is sorted in
/// place; the state owns whatever the configured engine needs to
/// terminate the gather.
pub struct Sorted {
    payload: SortedPayload,
    total: usize,
    spans: Vec<Range<usize>>,
    imbalance: f64,
    skew_redivides: u32,
    counters: SortCounters,
    max_local_sort: Duration,
    detours: usize,
}

enum SortedPayload {
    /// Pooled wave sorted the arena; gather is bookkeeping.
    Pooled { buckets: FlatBuckets },
    /// The fused Direct region already ran; gather validates it.
    Direct(Box<DirectRun>),
    /// Serial sorts ran; gather is the DES in virtual time.
    Des {
        buckets: FlatBuckets,
        counters_vec: Vec<SortCounters>,
        link: LinkModel,
    },
}

/// What a completed session hands back: the sorted arena (the divide
/// allocation — never a copy), per-job output spans, the stage trace,
/// and the engine-specific observables.
#[derive(Debug)]
pub struct Outcome {
    /// The globally sorted keys — the divide arena itself (pointer and
    /// capacity equal to the scattered arena; tested).
    pub sorted: Vec<i32>,
    /// Per-job output ranges of `sorted`, in submission order (one
    /// `0..n` span for single-input sessions).
    pub spans: Vec<Range<usize>>,
    /// Wall time of every stage.
    pub trace: StageTrace,
    /// Summed local-sort counters.
    pub counters: SortCounters,
    /// Wall time of the slowest local sort (load-imbalance witness).
    pub max_local_sort: Duration,
    /// Messages passed by the gather (0 for the DES engine, which
    /// reports its communication in `des` instead).
    pub messages: usize,
    /// Division load-imbalance factor.
    pub imbalance: f64,
    /// Skew-guardrail re-divides the divide stage performed (0 or 1;
    /// only [`DivideStrategy::Adaptive`] ever re-divides).
    pub skew_redivides: u32,
    /// Gather-tree edges whose planned link is failed but that still
    /// route over a detour (degraded-mode witness; 0 when healthy).
    pub detours: usize,
    /// DES observables, when the session ran on that engine.
    pub des: Option<DesOutcome>,
}

impl Outcome {
    /// Job `j`'s sorted output slice.
    pub fn job(&self, j: usize) -> &[i32] {
        &self.sorted[self.spans[j].clone()]
    }

    /// Wall time of the parallel region (local sort + gather stages) —
    /// what the threaded backends report as parallel time, divide
    /// excluded.
    pub fn parallel_time(&self) -> Duration {
        self.trace.local_sort + self.trace.gather
    }
}

/// The state-independent half of a session: topology, plans, engine
/// and sorter configuration, hooks, and the accumulating trace.
/// Moving it whole between typestates keeps every transition a
/// two-field struct literal — no per-field copying to forget.
struct Core<'a> {
    net: &'a Ohhc,
    plans: &'a [NodePlan],
    engine: Engine,
    sorter: Quicksort,
    divide_engine: DivideEngine,
    divide_strategy: DivideStrategy,
    registry: Option<&'a ArtifactRegistry>,
    observer: Option<&'a dyn Observer>,
    faults: Option<&'a FaultSet>,
    trace: StageTrace,
}

impl Core<'_> {
    fn emit(&self, stage: Stage, elapsed: Duration) {
        if let Some(obs) = self.observer {
            obs.on_stage(stage, elapsed, &self.trace);
        }
    }

    /// Pre-flight the gather tree against the fault set: every planned
    /// tree edge must still route on the surviving subgraph.  Returns
    /// how many tree edges need a detour; errors with
    /// [`Error::Stage`] when a processor on the schedule is dead or the
    /// fault set partitions the tree.  The DES additionally *charges*
    /// those detours at real link costs; the wall-clock engines treat
    /// the check as the modeled network's admission gate.
    fn preflight_tree(&self) -> Result<usize> {
        let faults = match self.faults {
            Some(f) if !f.is_empty() => f,
            _ => return Ok(0),
        };
        let g = self.net.graph();
        let mut detours = 0;
        for (id, plan) in self.plans.iter().enumerate() {
            let dst = match plan.last().send_to {
                Some(a) => self.net.id(a),
                None => {
                    if faults.is_node_failed(id) {
                        return Err(Error::Stage(StageError::NodeFailed { node: id }));
                    }
                    continue;
                }
            };
            if faults.is_node_failed(id) {
                return Err(Error::Stage(StageError::NodeFailed { node: id }));
            }
            if faults.is_node_failed(dst) {
                return Err(Error::Stage(StageError::NodeFailed { node: dst }));
            }
            match route_avoiding(g, faults, id, dst) {
                RouteOutcome::Path(p) if p.len() > 2 => detours += 1,
                RouteOutcome::Path(_) => {}
                RouteOutcome::Unreachable => {
                    return Err(Error::Stage(StageError::LinkFailed { src: id, dst }));
                }
            }
        }
        Ok(detours)
    }
}

/// One pipeline run as a typestate object: `Session<Configured>` →
/// [`divide`](Session::divide) → `Session<Divided>` →
/// [`local_sort`](Session::local_sort) → `Session<Sorted>` →
/// [`gather`](Session::gather) → [`Outcome`].  Each state owns exactly
/// the data legal at that stage; the arena moves through by value, so
/// the zero-copy guarantee is structural, and out-of-order stage calls
/// do not compile (see the [module docs](crate::pipeline)).
pub struct Session<'a, S> {
    core: Core<'a>,
    state: S,
}

impl<S> Session<'_, S> {
    /// The stage trace recorded so far.
    pub fn trace(&self) -> &StageTrace {
        &self.core.trace
    }
}

impl<'a, 'd> Session<'a, Configured<'d>> {
    /// A session over one input array: the whole topology sorts `data`
    /// (the coordinator's path).
    pub fn single(net: &'a Ohhc, plans: &'a [NodePlan], data: &'d [i32]) -> Self {
        Self::with_input(net, plans, Input::Single(data))
    }

    /// A session over a batch of tenant jobs: each job receives a
    /// contiguous bucket span of one shared arena and is divided by
    /// its own step point (the batcher's multi-span path).  Spans in
    /// the outcome follow `jobs` order.
    pub fn batched(net: &'a Ohhc, plans: &'a [NodePlan], jobs: &[&'d [i32]]) -> Self {
        Self::with_input(net, plans, Input::Batched(jobs.to_vec()))
    }

    fn with_input(net: &'a Ohhc, plans: &'a [NodePlan], input: Input<'d>) -> Self {
        Session {
            core: Core {
                net,
                plans,
                engine: Engine::Pooled,
                sorter: Quicksort::default(),
                divide_engine: DivideEngine::Native,
                divide_strategy: DivideStrategy::PaperFixed,
                registry: None,
                observer: None,
                faults: None,
                trace: StageTrace::default(),
            },
            state: Configured { input },
        }
    }

    /// Select the local-sort/gather engine (default [`Engine::Pooled`]).
    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.core.engine = engine;
        self
    }

    /// Override the local sorter configuration.
    pub fn with_sorter(mut self, sorter: Quicksort) -> Self {
        self.core.sorter = sorter;
        self
    }

    /// Select the divide engine.  [`DivideEngine::Xla`] requires a
    /// registry and applies to single-input sessions only (batched
    /// sessions always divide natively, per job).
    pub fn with_divide_engine(
        mut self,
        engine: DivideEngine,
        registry: Option<&'a ArtifactRegistry>,
    ) -> Self {
        self.core.divide_engine = engine;
        self.core.registry = registry;
        self
    }

    /// Select the divide strategy (default
    /// [`DivideStrategy::PaperFixed`], the paper's rule).  Applies to
    /// single-input sessions; batched sessions always divide per job
    /// with the paper's step points (jobs small enough to batch are
    /// bounded by their span allotment, so one tenant's skew cannot
    /// starve the batch).
    pub fn with_divide_strategy(mut self, strategy: DivideStrategy) -> Self {
        self.core.divide_strategy = strategy;
        self
    }

    /// Install a stage-boundary observer.
    pub fn with_observer(mut self, observer: &'a dyn Observer) -> Self {
        self.core.observer = Some(observer);
        self
    }

    /// Run the pipeline under a fault set.  Dead tree links are
    /// detoured (and, on the DES engine, charged at real
    /// electronic/optical hop costs); a partitioned tree surfaces as
    /// [`Error::Stage`] with [`StageError::LinkFailed`] /
    /// [`StageError::NodeFailed`] from `local_sort()` on every engine.
    pub fn with_faults(mut self, faults: &'a FaultSet) -> Self {
        self.core.faults = Some(faults);
        self
    }

    /// Stage 1 — array division (paper §3.1): classify every key by
    /// its step point and scatter it to its final arena position.
    pub fn divide(self) -> Result<Session<'a, Divided>> {
        let Session { mut core, state } = self;
        let p = core.net.total_processors();
        let t0 = Instant::now();
        let (buckets, spans, scatter, skew_redivides) = match state.input {
            Input::Single(data) => {
                let (d, redivides) = divide_with_strategy(
                    data,
                    p,
                    core.divide_strategy,
                    core.divide_engine,
                    core.registry,
                )?;
                (d.buckets, vec![0..data.len()], d.scatter_time, redivides)
            }
            Input::Batched(jobs) => {
                let batch = coalesce(&jobs, p)?;
                let spans = (0..batch.num_jobs()).map(|j| batch.job_range(j)).collect();
                (batch.buckets, spans, batch.scatter_time, 0)
            }
        };
        let elapsed = t0.elapsed();
        core.trace.scatter = scatter;
        core.trace.divide = elapsed.saturating_sub(scatter);
        core.emit(Stage::Divide, elapsed);
        let imbalance = buckets.imbalance();
        let total = buckets.total_keys();
        Ok(Session {
            core,
            state: Divided {
                buckets,
                total,
                spans,
                imbalance,
                skew_redivides,
            },
        })
    }
}

impl<'a> Session<'a, Divided> {
    /// The scattered arena (bucket `i` = processor `i`'s sub-array).
    pub fn buckets(&self) -> &FlatBuckets {
        &self.state.buckets
    }

    /// Per-job arena spans, submission order.
    pub fn spans(&self) -> &[Range<usize>] {
        &self.state.spans
    }

    /// Division load-imbalance factor.
    pub fn imbalance(&self) -> f64 {
        self.state.imbalance
    }

    /// Stage 2 — parallel local Quick Sorts on the disjoint arena
    /// segments (paper §3.2 step 3), on the configured engine.
    pub fn local_sort(self) -> Result<Session<'a, Sorted>> {
        let Session { mut core, state } = self;
        let n = core.net.total_processors();
        let Divided {
            mut buckets,
            total,
            spans,
            imbalance,
            skew_redivides,
        } = state;
        if buckets.num_buckets() != n {
            return Err(Error::Sim(format!(
                "expected {n} buckets, got {}",
                buckets.num_buckets()
            )));
        }
        if buckets.total_keys() != total {
            return Err(Error::Invariant(format!(
                "payload loss: buckets hold {} of {total} keys",
                buckets.total_keys()
            )));
        }
        // Fail fast before any sort work when the modeled network cannot
        // complete the gather; count the detours it will need otherwise.
        let detours = core.preflight_tree()?;
        let sim = ThreadedSimulator::new(core.net, core.plans).with_sorter(core.sorter);
        let t0 = Instant::now();
        let (payload, counters, max_local_sort) = match core.engine {
            Engine::Pooled => {
                let stats = sim.local_sort_wave(&mut buckets);
                (
                    SortedPayload::Pooled { buckets },
                    stats.counters,
                    stats.max_local_sort,
                )
            }
            Engine::DirectThreads => {
                let run = sim.run_direct_raw(buckets)?;
                let (counters, max) = (run.counters, run.max_local_sort);
                (SortedPayload::Direct(Box::new(run)), counters, max)
            }
            Engine::DiscreteEvent { link } => {
                let mut counters_vec = Vec::with_capacity(buckets.num_buckets());
                let mut counters = SortCounters::default();
                let mut max = Duration::ZERO;
                for seg in buckets.segments_mut() {
                    let s0 = Instant::now();
                    let c = core.sorter.sort(seg);
                    max = max.max(s0.elapsed());
                    counters_vec.push(c);
                    counters += c;
                }
                (
                    SortedPayload::Des {
                        buckets,
                        counters_vec,
                        link,
                    },
                    counters,
                    max,
                )
            }
        };
        let elapsed = t0.elapsed();
        // The fused Direct region covers sort AND gather; attribute the
        // critical-path sort here and leave the remainder to gather().
        core.trace.local_sort = match core.engine {
            Engine::DirectThreads => max_local_sort,
            _ => elapsed,
        };
        core.emit(Stage::LocalSort, core.trace.local_sort);
        Ok(Session {
            core,
            state: Sorted {
                payload,
                total,
                spans,
                imbalance,
                skew_redivides,
                counters,
                max_local_sort,
                detours,
            },
        })
    }
}

impl Session<'_, Sorted> {
    /// Summed local-sort counters so far.
    pub fn counters(&self) -> SortCounters {
        self.state.counters
    }

    /// Stage 3 — terminate the three-phase gather and surrender the
    /// arena, which in bucket-rank order **is** the globally sorted
    /// array (zero key copies on every engine).
    pub fn gather(self) -> Result<Outcome> {
        let Session { mut core, state } = self;
        let Sorted {
            payload,
            total,
            spans,
            imbalance,
            skew_redivides,
            counters,
            max_local_sort,
            detours,
        } = state;
        let t0 = Instant::now();
        let (sorted, messages, des, gather_time) = match payload {
            SortedPayload::Pooled { buckets } => {
                let sim = ThreadedSimulator::new(core.net, core.plans);
                let messages = sim.gather_bookkeeping()?;
                let (sorted, _) = buckets.into_arena();
                (sorted, messages, None, t0.elapsed())
            }
            SortedPayload::Direct(run) => {
                let run = *run;
                // The fused region already gathered; validate coverage
                // and attribute the region's non-sort remainder here so
                // local_sort + gather equals the measured region
                // (master-finish semantics, teardown excluded).
                let gather_time = run.region.saturating_sub(run.max_local_sort);
                let messages = run.messages;
                let sorted = finish_gather(run.subarrays, run.buckets, total)?;
                (sorted, messages, None, gather_time)
            }
            SortedPayload::Des {
                buckets,
                counters_vec,
                link,
            } => {
                let mut sim = DesSimulator::new(core.net, core.plans, link);
                if let Some(f) = core.faults {
                    sim = sim.with_faults(f);
                }
                let des = sim.run_buckets(&buckets, Some(&counters_vec))?;
                let (sorted, _) = buckets.into_arena();
                (sorted, 0, Some(des), t0.elapsed())
            }
        };
        core.trace.gather = gather_time;
        core.emit(Stage::Gather, gather_time);
        Ok(Outcome {
            sorted,
            spans,
            trace: core.trace,
            counters,
            max_local_sort,
            messages,
            imbalance,
            skew_redivides,
            detours,
            des,
        })
    }
}
