//! Per-stage timing: the [`StageTrace`] every [`Session`] transition
//! writes into and every driver reads its report from.
//!
//! [`Session`]: crate::pipeline::Session

use std::time::Duration;

use crate::util::json::Json;

/// The pipeline stages a [`crate::pipeline::Session`] moves through.
/// Observers receive one callback per completed transition; `Divide`
/// covers both the classification and the arena scatter (their wall
/// times are split inside the trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Step-point classification + arena scatter (paper §3.1).
    Divide,
    /// Per-bucket local Quick Sorts (paper §3.2 step 3).
    LocalSort,
    /// Three-phase gather / result validation (paper §3.2 step 4).
    Gather,
}

impl Stage {
    /// Stable label for logs, JSON, and observer output.
    pub fn label(self) -> &'static str {
        match self {
            Stage::Divide => "divide",
            Stage::LocalSort => "local_sort",
            Stage::Gather => "gather",
        }
    }
}

/// Wall time of each pipeline stage, filled in transition by
/// transition.  `divide` is the classification work (step point +
/// bucket ids); `scatter` is the arena placement writes — together they
/// make up the coordinator's historical "divide phase".
///
/// Stage attribution per engine:
///
/// * **Pooled** — every stage is measured at its own transition.
/// * **Direct threads** — the paper's §5 methodology overlaps local
///   sort and gather inside one thread region, so the fused region is
///   split on its critical path: `local_sort` is the slowest local
///   sort, `gather` is the remainder (their sum is exactly the
///   measured parallel region, master-finish semantics included).
/// * **Discrete event** — `local_sort` and `gather` are the *host*
///   wall times (serial instrumented sorts, DES engine run); the
///   simulated virtual time lives in the outcome's `des` field.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageTrace {
    /// Step-point + bucket-id classification time.
    pub divide: Duration,
    /// Arena scatter (placement writes) time.
    pub scatter: Duration,
    /// Local-sort stage time.
    pub local_sort: Duration,
    /// Gather stage time.
    pub gather: Duration,
}

impl StageTrace {
    /// The historical "divide phase": classification + scatter.
    pub fn divide_total(&self) -> Duration {
        self.divide + self.scatter
    }

    /// Sum of every stage — the whole pipeline's wall time as seen by
    /// the trace.
    pub fn total(&self) -> Duration {
        self.divide + self.scatter + self.local_sort + self.gather
    }

    /// The trace as a JSON object (nanoseconds per stage).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("divide_ns", Json::num(self.divide.as_nanos() as f64)),
            ("gather_ns", Json::num(self.gather.as_nanos() as f64)),
            ("local_sort_ns", Json::num(self.local_sort.as_nanos() as f64)),
            ("scatter_ns", Json::num(self.scatter.as_nanos() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_labels() {
        let t = StageTrace {
            divide: Duration::from_micros(10),
            scatter: Duration::from_micros(5),
            local_sort: Duration::from_micros(100),
            gather: Duration::from_micros(1),
        };
        assert_eq!(t.divide_total(), Duration::from_micros(15));
        assert_eq!(t.total(), Duration::from_micros(116));
        assert_eq!(Stage::Divide.label(), "divide");
        assert_eq!(Stage::LocalSort.label(), "local_sort");
        assert_eq!(Stage::Gather.label(), "gather");
    }

    #[test]
    fn json_carries_every_stage() {
        let t = StageTrace {
            divide: Duration::from_nanos(7),
            ..Default::default()
        };
        let j = t.to_json();
        assert_eq!(j.get("divide_ns").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("scatter_ns").unwrap().as_f64(), Some(0.0));
        assert!(j.get("local_sort_ns").is_some());
        assert!(j.get("gather_ns").is_some());
    }
}
