//! Discrete-event core: virtual clock and the event queue.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in picoseconds (integer so ordering is total and exact).
pub type Time = u64;

/// Picoseconds per nanosecond.
pub const PS_PER_NS: f64 = 1000.0;

/// Convert nanoseconds (model units) to picosecond ticks.
pub fn ns_to_ticks(ns: f64) -> Time {
    (ns * PS_PER_NS).round() as Time
}

/// Convert ticks back to nanoseconds.
pub fn ticks_to_ns(t: Time) -> f64 {
    t as f64 / PS_PER_NS
}

/// An event scheduled on the queue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event<P> {
    /// Firing time.
    pub time: Time,
    /// Monotonic tie-breaker (FIFO among simultaneous events).
    pub seq: u64,
    /// Payload.
    pub payload: P,
}

impl<P: Eq> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

impl<P: Eq> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with deterministic FIFO tie-breaking.
#[derive(Debug)]
pub struct EventQueue<P: Eq> {
    heap: BinaryHeap<Reverse<Event<P>>>,
    seq: u64,
    processed: u64,
}

impl<P: Eq> Default for EventQueue<P> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            processed: 0,
        }
    }
}

impl<P: Eq> EventQueue<P> {
    /// New empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `payload` at `time`.
    pub fn push(&mut self, time: Time, payload: P) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Event { time, seq, payload }));
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event<P>> {
        let e = self.heap.pop().map(|Reverse(e)| e);
        if e.is_some() {
            self.processed += 1;
        }
        e
    }

    /// Events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a1");
        q.push(10, "a2");
        q.push(20, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a1", "a2", "b", "c"]);
        assert_eq!(q.processed(), 4);
    }

    #[test]
    fn tick_conversions_round_trip() {
        assert_eq!(ns_to_ticks(1.0), 1000);
        assert_eq!(ns_to_ticks(0.5), 500);
        assert!((ticks_to_ns(ns_to_ticks(123.456)) - 123.456).abs() < 1e-9);
    }
}
