//! Simulation backends for the OHHC parallel Quick Sort.
//!
//! Two complementary engines execute the same static schedule
//! ([`crate::schedule`]):
//!
//! * [`threaded`] — **the paper's own methodology** (§5): one OS thread per
//!   simulated processor, message passing over channels, wall-clock
//!   timing.  Like the paper's C++ simulation it cannot express the
//!   electrical/optical speed difference (the paper concedes this in its
//!   conclusion).
//! * [`engine`] — a **discrete-event simulator** with store-and-forward
//!   link models (electrical vs optical latency/bandwidth, §1.5), virtual
//!   time, per-message delays and communication-step traces.  This is the
//!   engine that lets us check Theorems 3 and 6 empirically, which the
//!   paper could only derive analytically.
//!
//! [`transfer`] extends the same price list to cluster scale: the
//! sharded service's cross-shard scatter/merge traffic is charged at
//! the DES's optical-hop prices (see [`crate::cluster`]).

pub mod engine;
pub mod event;
pub mod message;
pub mod threaded;
pub mod trace;
pub mod transfer;

pub use engine::{DesOutcome, DesSimulator};
pub use message::{Batch, SubArray};
pub use threaded::{DirectRun, LocalSortStats, ThreadedOutcome, ThreadedSimulator};
pub use trace::CommTrace;
pub use transfer::{InterShardModel, SplitTransfer};
