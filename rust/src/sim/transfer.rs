//! Inter-shard transfer cost model: the cluster's scatter/merge
//! traffic priced at optical-hop prices.
//!
//! The cluster layer ([`crate::cluster`]) maps OTIS groups to shards:
//! traffic inside a shard rides the electronic intra-group links the
//! shard's own DES runs already charge for, while a split job's spans
//! cross the **optical transpose fabric** to reach the other shards
//! and cross it again on the way back to the merger.  This model
//! extends the paper's §5 analytical story to cluster scale by pricing
//! exactly that cross-shard traffic with the *same* store-and-forward
//! optical parameters the DES engine uses for a single optical hop
//! (`latency + bytes / bandwidth`, see
//! [`DesSimulator`](crate::sim::DesSimulator)).
//!
//! The shape of the charge: the home shard's router serializes the
//! remote spans onto its transpose port, so one direction costs one
//! optical latency plus the serialized remote bytes; the merge-side
//! return path is symmetric.  Spans that stay on the home shard are
//! free — they never leave the group.

use crate::config::LinkModel;

/// Bytes per key — the DES charges `i32` keys at 4 bytes and so do we.
pub const KEY_BYTES: u64 = 4;

/// What one split job's scatter + merge-return traffic costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SplitTransfer {
    /// Bytes that crossed the optical fabric, both directions summed.
    pub cross_shard_bytes: u64,
    /// Virtual ns of the scatter + return transfers at optical prices.
    pub transfer_ns: f64,
}

/// Prices cross-shard span traffic over the optical transpose fabric.
#[derive(Debug, Clone)]
pub struct InterShardModel {
    link: LinkModel,
}

impl InterShardModel {
    /// A model over the given link parameters (only the optical pair is
    /// consulted; electronic traffic stays inside the shards).
    pub fn new(link: LinkModel) -> Self {
        InterShardModel { link }
    }

    /// The link parameters in use.
    pub fn link(&self) -> &LinkModel {
        &self.link
    }

    /// One store-and-forward optical hop carrying `bytes` — identical
    /// arithmetic to the DES engine's optical hop cost.
    pub fn optical_hop_ns(&self, bytes: u64) -> f64 {
        self.link.optical_latency_ns + bytes as f64 / self.link.optical_bandwidth
    }

    /// Price one split job: `span_keys[i]` keys go to shard `i`, the
    /// span staying on `home` never leaves the group.  Both directions
    /// (scatter out, sorted spans back to the merger) are charged; a
    /// job whose every key stays home costs nothing.
    pub fn split_transfer(&self, home: usize, span_keys: &[usize]) -> SplitTransfer {
        let remote_keys: u64 = span_keys
            .iter()
            .enumerate()
            .filter(|&(shard, _)| shard != home)
            .map(|(_, &keys)| keys as u64)
            .sum();
        let one_way = remote_keys * KEY_BYTES;
        if one_way == 0 {
            return SplitTransfer {
                cross_shard_bytes: 0,
                transfer_ns: 0.0,
            };
        }
        SplitTransfer {
            cross_shard_bytes: 2 * one_way,
            transfer_ns: 2.0 * self.optical_hop_ns(one_way),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optical_hop_matches_the_des_formula() {
        let m = InterShardModel::new(LinkModel::default());
        // Defaults: 25 ns latency, 16 B/ns — 4000 bytes = 25 + 250 ns.
        assert!((m.optical_hop_ns(4_000) - 275.0).abs() < 1e-9);
        assert!((m.optical_hop_ns(0) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn home_only_traffic_is_free() {
        let m = InterShardModel::new(LinkModel::default());
        let t = m.split_transfer(0, &[10_000, 0, 0, 0]);
        assert_eq!(t.cross_shard_bytes, 0);
        assert_eq!(t.transfer_ns, 0.0);
    }

    #[test]
    fn remote_spans_pay_both_directions() {
        let m = InterShardModel::new(LinkModel::default());
        // Home is shard 1; shards 0 and 2 hold 500 keys each.
        let t = m.split_transfer(1, &[500, 9_000, 500]);
        assert_eq!(t.cross_shard_bytes, 2 * 1_000 * KEY_BYTES);
        let expect = 2.0 * (25.0 + (1_000.0 * KEY_BYTES as f64) / 16.0);
        assert!((t.transfer_ns - expect).abs() < 1e-9, "{}", t.transfer_ns);
    }

    #[test]
    fn transfer_cost_is_monotone_in_remote_bytes() {
        let m = InterShardModel::new(LinkModel::default());
        let mut last = 0.0;
        for keys in [1usize, 10, 100, 1_000, 100_000] {
            let t = m.split_transfer(0, &[0, keys]);
            assert!(t.transfer_ns > last, "{keys}");
            last = t.transfer_ns;
        }
    }
}
