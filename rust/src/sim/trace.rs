//! Communication traces: every link traversal the DES performs, so the
//! analytical claims (Theorem 3 step counts, Theorem 6 message delays)
//! can be checked against simulation instead of taken on faith.

use crate::schedule::Phase;
use crate::topology::graph::LinkKind;

/// One message crossing one physical link.
#[derive(Debug, Clone, Copy)]
pub struct MsgRecord {
    /// Sender flat id.
    pub src: usize,
    /// Receiver flat id.
    pub dst: usize,
    /// Link medium.
    pub kind: LinkKind,
    /// Payload bytes.
    pub bytes: u64,
    /// Departure time (ns).
    pub depart_ns: f64,
    /// Arrival time (ns).
    pub arrive_ns: f64,
    /// Scatter (`None`) or the gather phase it belongs to.
    pub phase: Option<Phase>,
}

impl MsgRecord {
    /// End-to-end delay of this traversal (ns).
    pub fn delay_ns(&self) -> f64 {
        self.arrive_ns - self.depart_ns
    }
}

/// Accumulated trace of one DES run.
#[derive(Debug, Default, Clone)]
pub struct CommTrace {
    /// All link traversals, in schedule order.
    pub records: Vec<MsgRecord>,
}

impl CommTrace {
    /// Record one traversal.
    pub fn record(&mut self, rec: MsgRecord) {
        self.records.push(rec);
    }

    /// Communication steps (= link traversals) by medium:
    /// `(electrical, optical)` — the quantities of Theorem 3.
    pub fn steps(&self) -> (usize, usize) {
        let e = self
            .records
            .iter()
            .filter(|r| r.kind == LinkKind::Electrical)
            .count();
        (e, self.records.len() - e)
    }

    /// Total communication steps.
    pub fn total_steps(&self) -> usize {
        self.records.len()
    }

    /// Maximum single-traversal delay in ns (Theorem 6's worst message).
    pub fn max_delay_ns(&self) -> f64 {
        self.records
            .iter()
            .map(MsgRecord::delay_ns)
            .fold(0.0, f64::max)
    }

    /// Total bytes moved per medium: `(electrical, optical)`.
    pub fn bytes(&self) -> (u64, u64) {
        let mut e = 0;
        let mut o = 0;
        for r in &self.records {
            match r.kind {
                LinkKind::Electrical => e += r.bytes,
                LinkKind::Optical => o += r.bytes,
            }
        }
        (e, o)
    }

    /// Steps attributed to the scatter (distribution) phase.
    pub fn scatter_steps(&self) -> usize {
        self.records.iter().filter(|r| r.phase.is_none()).count()
    }

    /// Serialize the trace as JSON (for offline analysis / plotting).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let records = self
            .records
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("src".into(), Json::Num(r.src as f64));
                m.insert("dst".into(), Json::Num(r.dst as f64));
                m.insert(
                    "kind".into(),
                    Json::Str(
                        match r.kind {
                            LinkKind::Electrical => "electrical",
                            LinkKind::Optical => "optical",
                        }
                        .into(),
                    ),
                );
                m.insert("bytes".into(), Json::Num(r.bytes as f64));
                m.insert("depart_ns".into(), Json::Num(r.depart_ns));
                m.insert("arrive_ns".into(), Json::Num(r.arrive_ns));
                m.insert(
                    "phase".into(),
                    match r.phase {
                        None => Json::Str("scatter".into()),
                        Some(p) => Json::Str(format!("{p:?}")),
                    },
                );
                Json::Obj(m)
            })
            .collect();
        let (e, o) = self.steps();
        let mut top = BTreeMap::new();
        top.insert("electrical_steps".into(), Json::Num(e as f64));
        top.insert("optical_steps".into(), Json::Num(o as f64));
        top.insert("max_delay_ns".into(), Json::Num(self.max_delay_ns()));
        top.insert("records".into(), Json::Arr(records));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(kind: LinkKind, bytes: u64, d: f64, a: f64) -> MsgRecord {
        MsgRecord {
            src: 0,
            dst: 1,
            kind,
            bytes,
            depart_ns: d,
            arrive_ns: a,
            phase: None,
        }
    }

    #[test]
    fn json_export_round_trips() {
        let mut t = CommTrace::default();
        t.record(rec(LinkKind::Optical, 128, 1.0, 3.5));
        let j = t.to_json();
        let parsed = crate::util::json::Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("optical_steps").unwrap().as_usize(), Some(1));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].get("kind").unwrap().as_str(), Some("optical"));
        assert_eq!(recs[0].get("phase").unwrap().as_str(), Some("scatter"));
    }

    #[test]
    fn step_and_byte_census() {
        let mut t = CommTrace::default();
        t.record(rec(LinkKind::Electrical, 100, 0.0, 10.0));
        t.record(rec(LinkKind::Electrical, 50, 5.0, 9.0));
        t.record(rec(LinkKind::Optical, 200, 2.0, 4.0));
        assert_eq!(t.steps(), (2, 1));
        assert_eq!(t.total_steps(), 3);
        assert_eq!(t.bytes(), (150, 200));
        assert!((t.max_delay_ns() - 10.0).abs() < 1e-12);
        assert_eq!(t.scatter_steps(), 3);
    }
}
