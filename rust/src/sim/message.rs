//! Message payloads exchanged by simulated processors.

use std::ops::Range;

/// One sorted sub-array descriptor tagged with its bucket rank.  Because
/// the step-point division is order-preserving across buckets (paper
/// §3.1) and the keys already live at their final arena positions
/// ([`crate::dataplane::FlatBuckets`]), messages carry `(bucket, range)`
/// descriptors instead of owned key vectors — the master terminates the
/// gather by checking coverage, not by copying keys.  The DES link model
/// still charges for the full payload via [`SubArray::bytes`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubArray {
    /// Bucket rank (equal to the owning processor's flat id).
    pub bucket: u32,
    /// The bucket's arena range (sorted keys live there in place).
    pub range: Range<usize>,
}

impl SubArray {
    /// Number of keys described.
    pub fn len(&self) -> usize {
        self.range.len()
    }

    /// True when the bucket is empty.
    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }

    /// Payload size in bytes (4 bytes per key) — what the DES link model
    /// charges for.
    pub fn bytes(&self) -> usize {
        self.range.len() * 4
    }
}

/// A batch of sub-arrays traveling together (the paper's nodes forward
/// their whole accumulated payload in one send).
#[derive(Debug, Clone, Default)]
pub struct Batch {
    /// Accumulated sub-arrays, in arrival order (ranks restore order).
    pub subarrays: Vec<SubArray>,
}

impl Batch {
    /// Batch holding a single sub-array.
    pub fn single(sub: SubArray) -> Self {
        Batch {
            subarrays: vec![sub],
        }
    }

    /// Number of sub-arrays in the batch.
    pub fn count(&self) -> usize {
        self.subarrays.len()
    }

    /// Total payload bytes.
    pub fn bytes(&self) -> usize {
        self.subarrays.iter().map(SubArray::bytes).sum()
    }

    /// Absorb another batch.
    pub fn merge(&mut self, other: Batch) {
        self.subarrays.extend(other.subarrays);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let mut b = Batch::single(SubArray {
            bucket: 0,
            range: 0..3,
        });
        b.merge(Batch::single(SubArray {
            bucket: 1,
            range: 3..4,
        }));
        assert_eq!(b.count(), 2);
        assert_eq!(b.bytes(), 16);
    }

    #[test]
    fn subarray_descriptor_accounting() {
        let s = SubArray {
            bucket: 7,
            range: 10..14,
        };
        assert_eq!(s.len(), 4);
        assert_eq!(s.bytes(), 16);
        assert!(!s.is_empty());
        assert!(SubArray {
            bucket: 0,
            range: 5..5
        }
        .is_empty());
    }
}
