//! Message payloads exchanged by simulated processors.

/// One sorted sub-array tagged with its bucket rank.  Because the step-
/// point division is order-preserving across buckets (paper §3.1), the
/// master reassembles the sorted output by writing each sub-array at its
/// bucket's prefix offset — no merge required.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubArray {
    /// Bucket rank (equal to the owning processor's flat id).
    pub bucket: u32,
    /// Sorted keys.
    pub data: Vec<i32>,
}

impl SubArray {
    /// Payload size in bytes (4 bytes per key) — what the DES link model
    /// charges for.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// A batch of sub-arrays traveling together (the paper's nodes forward
/// their whole accumulated payload in one send).
#[derive(Debug, Clone, Default)]
pub struct Batch {
    /// Accumulated sub-arrays, in arrival order (ranks restore order).
    pub subarrays: Vec<SubArray>,
}

impl Batch {
    /// Batch holding a single sub-array.
    pub fn single(sub: SubArray) -> Self {
        Batch {
            subarrays: vec![sub],
        }
    }

    /// Number of sub-arrays in the batch.
    pub fn count(&self) -> usize {
        self.subarrays.len()
    }

    /// Total payload bytes.
    pub fn bytes(&self) -> usize {
        self.subarrays.iter().map(SubArray::bytes).sum()
    }

    /// Absorb another batch.
    pub fn merge(&mut self, other: Batch) {
        self.subarrays.extend(other.subarrays);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let mut b = Batch::single(SubArray {
            bucket: 0,
            data: vec![1, 2, 3],
        });
        b.merge(Batch::single(SubArray {
            bucket: 1,
            data: vec![4],
        }));
        assert_eq!(b.count(), 2);
        assert_eq!(b.bytes(), 16);
    }
}
