//! Discrete-event simulation of the OHHC Quick Sort with store-and-forward
//! optoelectronic links.
//!
//! The DES executes the same static schedule as the threaded backend but
//! in **virtual time**: every link traversal is charged
//! `latency(kind) + bytes / bandwidth(kind)` and every local sort is
//! charged by a calibrated comparison-cost model (or exact measured
//! counters).  This is the engine the paper *lacked* — its conclusion
//! concedes that thread-based simulation "was not easy" to extend with
//! the electrical/optical speed difference; here both media are
//! first-class.
//!
//! Phases simulated:
//!
//! 1. **Divide** — one linear pass over the master array (the paper calls
//!    it a "simple (O(n)) one iteration process").
//! 2. **Scatter** — payloads stream down the reverse-gather tree with
//!    per-port serialization (a node forwards one child batch at a time).
//! 3. **Local sort** — starts at each processor the moment its payload
//!    lands.
//! 4. **Gather** — wait-for counts trigger single sends, ending with the
//!    master's terminal accumulation (Figs 3.1–3.5).
//!
//! # Faults
//!
//! Under a [`FaultSet`] ([`DesSimulator::with_faults`]) every scatter and
//! gather message whose planned tree edge is dead is **detoured** over the
//! min-cost surviving path (Dijkstra under the §1.5 per-kind hop prices),
//! store-and-forward, with one trace record per hop — so degraded-mode
//! `completion_ns` stays analytically honest.  Port occupancy is charged
//! at the planned link's rate regardless of the detour, which keeps
//! departure schedules comparable across nested fault sets and makes
//! completion time provably monotone in the failure rate.  A partitioned
//! tree edge (or a dead processor on the schedule) aborts the run with
//! [`Error::Stage`].

use crate::config::LinkModel;
use crate::error::{Error, Result, StageError};
use crate::schedule::{NodePlan, Phase};
use crate::sim::event::{ns_to_ticks, ticks_to_ns, EventQueue, Time};
use crate::sim::threaded::gather_wave_order;
use crate::sim::trace::{CommTrace, MsgRecord};
use crate::sort::SortCounters;
use crate::topology::fault::{cheapest_path, FaultSet};
use crate::topology::graph::LinkKind;
use crate::topology::ohhc::Ohhc;

/// What the DES reports for one run.
#[derive(Debug, Clone)]
pub struct DesOutcome {
    /// Virtual completion time (ns): divide start → master holds all.
    pub completion_ns: f64,
    /// Virtual time when the scatter finished everywhere (ns).
    pub scatter_done_ns: f64,
    /// Virtual time when the last local sort finished (ns).
    pub sort_done_ns: f64,
    /// Full communication trace (steps, delays, bytes).
    pub trace: CommTrace,
    /// Events processed (engine health metric for the perf pass).
    pub events: u64,
    /// Messages rerouted around failed elements (scatter and gather
    /// count separately; 0 on a healthy network).
    pub detours: usize,
}

/// Per-node DES state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeState {
    /// Waiting for the scatter payload.
    AwaitingPayload,
    /// Local sort in flight.
    Sorting,
    /// Accumulating sub-arrays for the gather.
    Gathering,
    /// Sent (or, for the master, finished).
    Done,
}

/// An in-flight gather batch (counts + bytes, no real keys).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct DesBatch {
    subarrays: usize,
    bytes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// Scatter payload lands at a node.
    PayloadArrive { node: usize, batch: DesBatch },
    /// Local sort completes.
    SortDone { node: usize },
    /// Gather batch lands.
    GatherArrive { node: usize, batch: DesBatch },
}

/// The simulator.
pub struct DesSimulator<'a> {
    net: &'a Ohhc,
    plans: &'a [NodePlan],
    link: LinkModel,
    faults: Option<&'a FaultSet>,
}

impl<'a> DesSimulator<'a> {
    /// Create a DES over a network, schedule, and link model.
    pub fn new(net: &'a Ohhc, plans: &'a [NodePlan], link: LinkModel) -> Self {
        DesSimulator {
            net,
            plans,
            link,
            faults: None,
        }
    }

    /// Simulate under a fault set: dead tree edges are detoured at real
    /// per-kind hop costs; partitions abort with [`Error::Stage`].
    pub fn with_faults(mut self, faults: &'a FaultSet) -> Self {
        self.faults = Some(faults);
        self
    }

    /// The planned tree hop `src → dst`, or its min-cost surviving
    /// detour under the fault set.
    fn edge_path(&self, src: usize, dst: usize, bytes: u64) -> Result<Vec<usize>> {
        match self.faults {
            None => Ok(vec![src, dst]),
            Some(f) if f.allows(src, dst) => Ok(vec![src, dst]),
            Some(f) => {
                if f.is_node_failed(src) {
                    return Err(Error::Stage(StageError::NodeFailed { node: src }));
                }
                if f.is_node_failed(dst) {
                    return Err(Error::Stage(StageError::NodeFailed { node: dst }));
                }
                cheapest_path(self.net.graph(), f, src, dst, |k| self.hop_ticks(k, bytes))
                    .map(|(path, _)| path)
                    .ok_or(Error::Stage(StageError::LinkFailed { src, dst }))
            }
        }
    }

    /// Store-and-forward a payload along `path`, recording one trace
    /// entry per hop; returns the final arrival time.
    #[allow(clippy::too_many_arguments)]
    fn send_along(
        &self,
        path: &[usize],
        bytes: u64,
        depart: Time,
        phase: Option<Phase>,
        trace: &mut CommTrace,
        detours: &mut usize,
    ) -> Time {
        let mut t = depart;
        for w in path.windows(2) {
            let kind = self
                .net
                .graph()
                .edge_kind(w[0], w[1])
                .expect("route hop must be a physical link");
            let arrive = t + self.hop_ticks(kind, bytes);
            trace.record(MsgRecord {
                src: w[0],
                dst: w[1],
                kind,
                bytes,
                depart_ns: ticks_to_ns(t),
                arrive_ns: ticks_to_ns(arrive),
                phase,
            });
            t = arrive;
        }
        if path.len() > 2 {
            *detours += 1;
        }
        t
    }

    fn hop_ticks(&self, kind: LinkKind, bytes: u64) -> Time {
        let (lat, bw) = match kind {
            LinkKind::Electrical => {
                (self.link.electrical_latency_ns, self.link.electrical_bandwidth)
            }
            LinkKind::Optical => (self.link.optical_latency_ns, self.link.optical_bandwidth),
        };
        ns_to_ticks(lat + bytes as f64 / bw)
    }

    /// Transmission-only time (port occupancy) for serialization.
    fn tx_ticks(&self, kind: LinkKind, bytes: u64) -> Time {
        let bw = match kind {
            LinkKind::Electrical => self.link.electrical_bandwidth,
            LinkKind::Optical => self.link.optical_bandwidth,
        };
        ns_to_ticks(bytes as f64 / bw)
    }

    /// Estimated sort cost: measured counters if supplied, else the
    /// `m·log₂m` comparison model.
    fn sort_ticks(&self, m: usize, counters: Option<&SortCounters>) -> Time {
        let work = match counters {
            Some(c) => c.work() as f64,
            None => {
                let m = m as f64;
                if m < 2.0 {
                    1.0
                } else {
                    m * m.log2()
                }
            }
        };
        ns_to_ticks(work * self.link.compute_ns_per_cmp)
    }

    /// Run the DES straight off an arena-backed bucket set — sizes come
    /// from the offset table (O(P), no bucket walk).
    pub fn run_buckets(
        &self,
        buckets: &crate::dataplane::FlatBuckets,
        counters: Option<&[SortCounters]>,
    ) -> Result<DesOutcome> {
        self.run(&buckets.sizes(), counters)
    }

    /// Run the DES on per-processor bucket sizes (in keys).  `counters`,
    /// when given, supplies exact per-processor sort work.
    pub fn run(
        &self,
        bucket_sizes: &[usize],
        counters: Option<&[SortCounters]>,
    ) -> Result<DesOutcome> {
        let n = self.net.total_processors();
        if bucket_sizes.len() != n {
            return Err(Error::Sim(format!(
                "expected {n} bucket sizes, got {}",
                bucket_sizes.len()
            )));
        }
        if let Some(c) = counters {
            if c.len() != n {
                return Err(Error::Sim("counters length mismatch".into()));
            }
        }
        let total_keys: usize = bucket_sizes.iter().sum();

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut trace = CommTrace::default();
        let mut state = vec![NodeState::AwaitingPayload; n];
        let mut held = vec![DesBatch {
            subarrays: 0,
            bytes: 0,
        }; n];

        // ---- Phase 1+2: divide at the master, then tree scatter. ------
        // Divide: one pass over all keys (bucket-id per key).
        let divide_done = ns_to_ticks(total_keys as f64 * self.link.compute_ns_per_cmp);

        // Subtree payload bytes (what each tree edge must carry).
        let parents: Vec<Option<usize>> = self
            .plans
            .iter()
            .map(|p| p.last().send_to.map(|a| self.net.id(a)))
            .collect();
        // O(n) subtree payload sizes: walk the gather tree leaves-first
        // (children precede parents in wave order) accumulating bytes.
        let mut subtree_bytes: Vec<u64> = bucket_sizes.iter().map(|&s| s as u64 * 4).collect();
        let mut subtree_children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for id in 0..n {
            if let Some(par) = parents[id] {
                subtree_children[par].push(id);
            }
        }
        for &id in &gather_wave_order(self.net, self.plans) {
            if let Some(par) = parents[id] {
                subtree_bytes[par] += subtree_bytes[id];
            }
        }

        // Master's own payload is "delivered" when the divide finishes;
        // every child batch then streams down with port serialization.
        let mut scatter_done_ns: f64 = 0.0;
        let mut detours = 0usize;
        {
            // BFS from the root so departure times cascade.
            let mut ready = vec![0 as Time; n];
            ready[0] = divide_done;
            q.push(
                divide_done,
                Ev::PayloadArrive {
                    node: 0,
                    batch: DesBatch {
                        subarrays: 1,
                        bytes: bucket_sizes[0] as u64 * 4,
                    },
                },
            );
            let mut stack = vec![0usize];
            while let Some(u) = stack.pop() {
                let mut port_free = ready[u];
                for &child in &subtree_children[u] {
                    let kind = self
                        .net
                        .graph()
                        .edge_kind(u, child)
                        .expect("tree edge must be a physical link");
                    let bytes = subtree_bytes[child];
                    let depart = port_free;
                    let path = self.edge_path(u, child, bytes)?;
                    let arrive = self.send_along(&path, bytes, depart, None, &mut trace, &mut detours);
                    // Port occupancy is charged at the planned link's rate
                    // even when detoured (see the module docs).
                    port_free += self.tx_ticks(kind, bytes);
                    ready[child] = arrive;
                    q.push(
                        arrive,
                        Ev::PayloadArrive {
                            node: child,
                            batch: DesBatch {
                                subarrays: 1,
                                bytes: bucket_sizes[child] as u64 * 4,
                            },
                        },
                    );
                    stack.push(child);
                }
            }
        }

        // ---- Phases 3+4: event loop. -----------------------------------
        let mut sort_done_ns: f64 = 0.0;
        let mut completion: Option<Time> = None;
        let mut now: Time = 0;

        while let Some(ev) = q.pop() {
            debug_assert!(ev.time >= now, "time went backwards");
            now = ev.time;
            match ev.payload {
                Ev::PayloadArrive { node, batch: _ } => {
                    debug_assert_eq!(state[node], NodeState::AwaitingPayload);
                    state[node] = NodeState::Sorting;
                    scatter_done_ns = scatter_done_ns.max(ticks_to_ns(now));
                    let cost = self.sort_ticks(bucket_sizes[node], counters.map(|c| &c[node]));
                    q.push(now + cost, Ev::SortDone { node });
                }
                Ev::SortDone { node } => {
                    debug_assert_eq!(state[node], NodeState::Sorting);
                    state[node] = NodeState::Gathering;
                    sort_done_ns = sort_done_ns.max(ticks_to_ns(now));
                    let own = DesBatch {
                        subarrays: 1,
                        bytes: bucket_sizes[node] as u64 * 4,
                    };
                    self.accumulate(
                        node, own, now, &mut state, &mut held, &mut q, &mut trace,
                        &mut detours,
                    )?;
                }
                Ev::GatherArrive { node, batch } => {
                    self.accumulate(
                        node, batch, now, &mut state, &mut held, &mut q, &mut trace,
                        &mut detours,
                    )?;
                }
            }
            if state[0] == NodeState::Done && completion.is_none() {
                completion = Some(now);
            }
        }

        let completion = completion
            .ok_or_else(|| Error::Sim("master never completed the gather".into()))?;
        Ok(DesOutcome {
            completion_ns: ticks_to_ns(completion),
            scatter_done_ns,
            sort_done_ns,
            trace,
            events: q.processed(),
            detours,
        })
    }

    /// Fold a batch into a node; fire its send when the wait-for is met.
    #[allow(clippy::too_many_arguments)]
    fn accumulate(
        &self,
        node: usize,
        batch: DesBatch,
        now: Time,
        state: &mut [NodeState],
        held: &mut [DesBatch],
        q: &mut EventQueue<Ev>,
        trace: &mut CommTrace,
        detours: &mut usize,
    ) -> Result<()> {
        held[node].subarrays += batch.subarrays;
        held[node].bytes += batch.bytes;
        // A gather batch may land while the node is still sorting — it
        // simply accumulates (the channel buffers it, as in the threaded
        // backend); the send check only applies once the node is gathering.
        if state[node] != NodeState::Gathering {
            return Ok(());
        }
        let action = self.plans[node].last();
        if held[node].subarrays < action.wait_for {
            return Ok(());
        }
        debug_assert_eq!(held[node].subarrays, action.wait_for, "node {node}");
        match action.send_to {
            None => state[node] = NodeState::Done,
            Some(dst) => {
                let dst = self.net.id(dst);
                let batch = held[node];
                let path = self.edge_path(node, dst, batch.bytes)?;
                let arrive =
                    self.send_along(&path, batch.bytes, now, Some(action.phase), trace, detours);
                held[node] = DesBatch {
                    subarrays: 0,
                    bytes: 0,
                };
                state[node] = NodeState::Done;
                q.push(arrive, Ev::GatherArrive { node: dst, batch });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;
    use crate::schedule::gather_plan;

    fn run_des(d: u32, c: Construction, sizes: &[usize]) -> DesOutcome {
        let net = Ohhc::new(d, c).unwrap();
        let plans = gather_plan(&net);
        DesSimulator::new(&net, &plans, LinkModel::default())
            .run(sizes, None)
            .unwrap()
    }

    fn uniform(d: u32, c: Construction, per: usize) -> (Ohhc, Vec<usize>) {
        let net = Ohhc::new(d, c).unwrap();
        let n = net.total_processors();
        (net, vec![per; n])
    }

    #[test]
    fn completes_all_dimensions_and_constructions() {
        for d in 1..=3 {
            for c in [Construction::FullGroup, Construction::HalfGroup] {
                let (net, sizes) = uniform(d, c, 100);
                let out = run_des(d, c, &sizes);
                assert!(out.completion_ns > 0.0, "d={d} {c:?}");
                // Scatter + gather each traverse N-1 tree edges.
                let n = net.total_processors();
                assert_eq!(out.trace.total_steps(), 2 * (n - 1), "d={d} {c:?}");
                assert!(out.scatter_done_ns <= out.sort_done_ns);
                assert!(out.sort_done_ns <= out.completion_ns);
            }
        }
    }

    #[test]
    fn optical_steps_count_matches_group_heads() {
        // Gather: G-1 optical sends (one per non-zero group head);
        // scatter mirrors them: G-1 more.
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let (net, sizes) = uniform(2, c, 50);
            let out = run_des(2, c, &sizes);
            let (_, optical) = out.trace.steps();
            assert_eq!(optical, 2 * (net.groups - 1), "{c:?}");
        }
    }

    #[test]
    fn more_processors_finish_sorting_sooner() {
        // Same total keys, higher dimension → smaller buckets → the last
        // local sort ends earlier in virtual time (the paper's Fig 6.2
        // claim, modulo communication overhead).
        let total = 36 * 2304; // divisible by every processor count
        let mut sort_times = Vec::new();
        for d in 1..=3 {
            let net = Ohhc::new(d, Construction::FullGroup).unwrap();
            let n = net.total_processors();
            let sizes = vec![total / n; n];
            let out = run_des(d, Construction::FullGroup, &sizes);
            sort_times.push(out.sort_done_ns - out.scatter_done_ns);
        }
        assert!(sort_times[0] > sort_times[1]);
        assert!(sort_times[1] > sort_times[2]);
    }

    #[test]
    fn exact_counters_override_model() {
        let (net, sizes) = uniform(1, Construction::FullGroup, 1000);
        let n = net.total_processors();
        let plans = gather_plan(&net);
        let zero = vec![SortCounters::default(); n];
        let fast = DesSimulator::new(&net, &plans, LinkModel::default())
            .run(&sizes, Some(&zero))
            .unwrap();
        let modeled = DesSimulator::new(&net, &plans, LinkModel::default())
            .run(&sizes, None)
            .unwrap();
        assert!(fast.completion_ns < modeled.completion_ns);
    }

    #[test]
    fn faster_optics_shrink_completion() {
        let (net, sizes) = uniform(2, Construction::FullGroup, 5000);
        let plans = gather_plan(&net);
        let slow_optics = LinkModel {
            optical_bandwidth: 0.1,
            ..Default::default()
        };
        let fast_optics = LinkModel {
            optical_bandwidth: 64.0,
            ..Default::default()
        };
        let a = DesSimulator::new(&net, &plans, slow_optics)
            .run(&sizes, None)
            .unwrap();
        let b = DesSimulator::new(&net, &plans, fast_optics)
            .run(&sizes, None)
            .unwrap();
        assert!(
            b.completion_ns < a.completion_ns,
            "{} !< {}",
            b.completion_ns,
            a.completion_ns
        );
    }

    #[test]
    fn empty_buckets_are_fine() {
        // Extreme skew: all keys in one bucket (the paper's worst-case
        // partitioning, Theorem 6 worst case).
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let n = net.total_processors();
        let mut sizes = vec![0usize; n];
        sizes[7] = 10_000;
        let out = run_des(1, Construction::FullGroup, &sizes);
        assert!(out.completion_ns > 0.0);
        assert_eq!(out.trace.total_steps(), 2 * (n - 1));
    }

    #[test]
    fn run_buckets_matches_run_on_sizes() {
        use crate::dataplane::FlatBuckets;
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let plans = gather_plan(&net);
        let nested: Vec<Vec<i32>> = (0..net.total_processors()).map(|i| vec![0; 10 + i]).collect();
        let buckets = FlatBuckets::from_nested(nested);
        let des = DesSimulator::new(&net, &plans, LinkModel::default());
        let a = des.run_buckets(&buckets, None).unwrap();
        let b = des.run(&buckets.sizes(), None).unwrap();
        assert_eq!(a.completion_ns, b.completion_ns);
        assert_eq!(a.trace.total_steps(), b.trace.total_steps());
    }

    #[test]
    fn rejects_wrong_sizes_length() {
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let plans = gather_plan(&net);
        let r = DesSimulator::new(&net, &plans, LinkModel::default()).run(&[1, 2, 3], None);
        assert!(r.is_err());
    }

    #[test]
    fn faulted_tree_edge_is_detoured_and_charged() {
        let (net, sizes) = uniform(1, Construction::FullGroup, 200);
        let plans = gather_plan(&net);
        let healthy = DesSimulator::new(&net, &plans, LinkModel::default())
            .run(&sizes, None)
            .unwrap();
        assert_eq!(healthy.detours, 0);
        // Kill node 1's gather-tree edge: scatter and gather both detour.
        let parent = net.id(plans[1].last().send_to.unwrap());
        let mut f = FaultSet::new();
        f.fail_link(1, parent);
        let faulted = DesSimulator::new(&net, &plans, LinkModel::default())
            .with_faults(&f)
            .run(&sizes, None)
            .unwrap();
        assert!(faulted.detours >= 2, "detours: {}", faulted.detours);
        // Each detour adds hops: more per-hop records than healthy, and
        // no recorded hop crosses the dead link.
        let n = net.total_processors();
        assert!(faulted.trace.total_steps() > 2 * (n - 1));
        for r in &faulted.trace.records {
            assert!(f.allows(r.src, r.dst), "hop {}→{} uses the dead link", r.src, r.dst);
        }
        assert!(faulted.completion_ns >= healthy.completion_ns);
    }

    #[test]
    fn nested_fault_sets_degrade_completion_monotonically() {
        let (net, sizes) = uniform(1, Construction::FullGroup, 500);
        let plans = gather_plan(&net);
        let mut last = f64::NEG_INFINITY;
        for permille in [0, 100, 250, 400] {
            let f = FaultSet::seeded_links(net.graph(), permille, 0x00C0_FFEE);
            let out = DesSimulator::new(&net, &plans, LinkModel::default())
                .with_faults(&f)
                .run(&sizes, None)
                .unwrap();
            assert!(
                out.completion_ns >= last,
                "{permille}‰: {} < {last}",
                out.completion_ns
            );
            last = out.completion_ns;
        }
    }

    #[test]
    fn dead_processor_fails_loudly() {
        let (net, sizes) = uniform(1, Construction::FullGroup, 100);
        let plans = gather_plan(&net);
        let mut f = FaultSet::new();
        f.fail_node(3);
        let err = DesSimulator::new(&net, &plans, LinkModel::default())
            .with_faults(&f)
            .run(&sizes, None)
            .unwrap_err();
        assert!(
            matches!(
                err,
                Error::Stage(StageError::NodeFailed { node: 3 } | StageError::LinkFailed { .. })
            ),
            "{err}"
        );
    }
}
