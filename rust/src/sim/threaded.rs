//! The paper's simulation methodology: one OS thread per simulated OHHC
//! processor, channel message passing, wall-clock timing (§5).
//!
//! Every thread executes its static [`NodePlan`]: sort its disjoint
//! arena segment in place with the instrumented sequential Quick Sort,
//! accumulate incoming sub-array descriptors until the wait-for count is
//! met, then forward everything in one send.  The master thread
//! terminates the gather by validating descriptor coverage — because the
//! [`FlatBuckets`] arena is laid out in bucket-rank order, the arena
//! itself **is** the globally sorted array; no keys move after the
//! divide scatter.
//!
//! A `Waves` mode executes the same schedule on the persistent
//! work-stealing executor ([`crate::runtime::Executor`]) in gather-tree
//! depth order — semantically identical, cheaper than 2304 OS threads,
//! and the mode used for huge sweep runs and the sort service.  Its
//! local sorts are pool tasks, so a Waves run spawns **zero** threads.
//! `Direct` remains the paper-faithful default and is deliberately the
//! one thread-spawning site left on the sort path: the paper's §5
//! methodology *is* one OS thread per simulated processor.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::dataplane::FlatBuckets;
use crate::error::{Error, Result};
use crate::schedule::NodePlan;
use crate::sim::message::{Batch, SubArray};
use crate::sort::{Quicksort, SortCounters};
use crate::topology::ohhc::Ohhc;

/// Execution strategy for the threaded backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThreadMode {
    /// One OS thread per simulated processor (the paper's method).
    Direct,
    /// Bounded worker pool, gather-tree wave order (fast mode for sweeps).
    Waves,
}

/// Result of one threaded simulation run.
#[derive(Debug)]
pub struct ThreadedOutcome {
    /// The sorted keys — the divide arena handed back untouched (the
    /// gather moves descriptors, never keys).
    pub sorted: Vec<i32>,
    /// Wall-clock duration of the parallel region (threads spawned →
    /// master finished its gather), the quantity behind Figs 6.2–6.11.
    pub parallel_time: Duration,
    /// Per-processor local-sort counters, summed (Figs 6.20–6.24).
    pub counters: SortCounters,
    /// Wall-clock of the slowest local sort (load-imbalance witness).
    pub max_local_sort: Duration,
    /// Number of messages passed.
    pub messages: usize,
}

/// Stats of one pooled local-sort wave (see
/// [`ThreadedSimulator::local_sort_wave`]).
#[derive(Debug, Clone, Copy)]
pub struct LocalSortStats {
    /// Summed per-segment counters.
    pub counters: SortCounters,
    /// Wall clock of the slowest local sort.
    pub max_local_sort: Duration,
}

/// Raw outcome of the fused paper-faithful Direct region, before the
/// master-side gather validation — what
/// [`ThreadedSimulator::run_direct_raw`] hands a
/// [`crate::pipeline::Session`] so the validation can run (and be
/// timed) as its own gather stage.
#[derive(Debug)]
pub struct DirectRun {
    /// The arena, every segment sorted in place.
    pub buckets: FlatBuckets,
    /// The descriptors the master accumulated.
    pub subarrays: Vec<SubArray>,
    /// Wall clock of the parallel region (threads spawned → master
    /// finished its gather, worker teardown excluded).
    pub region: Duration,
    /// Summed per-processor local-sort counters.
    pub counters: SortCounters,
    /// Wall clock of the slowest local sort.
    pub max_local_sort: Duration,
    /// Messages passed.
    pub messages: usize,
}

/// Threaded simulator: owns the topology, plans, and sorter config.
pub struct ThreadedSimulator<'a> {
    net: &'a Ohhc,
    plans: &'a [NodePlan],
    sorter: Quicksort,
    mode: ThreadMode,
}

impl<'a> ThreadedSimulator<'a> {
    /// Create a simulator over a network and its gather plans.
    pub fn new(net: &'a Ohhc, plans: &'a [NodePlan]) -> Self {
        ThreadedSimulator {
            net,
            plans,
            sorter: Quicksort::default(),
            mode: ThreadMode::Direct,
        }
    }

    /// Override the local sorter configuration.
    pub fn with_sorter(mut self, sorter: Quicksort) -> Self {
        self.sorter = sorter;
        self
    }

    /// Override the execution mode.
    pub fn with_mode(mut self, mode: ThreadMode) -> Self {
        self.mode = mode;
        self
    }

    /// Run the gather on the scattered arena (`buckets.bucket(i)` =
    /// processor `i`'s sub-array, already placed by the coordinator).
    pub fn run(&self, buckets: FlatBuckets, total_len: usize) -> Result<ThreadedOutcome> {
        let n = self.net.total_processors();
        if buckets.num_buckets() != n {
            return Err(Error::Sim(format!(
                "expected {n} buckets, got {}",
                buckets.num_buckets()
            )));
        }
        if buckets.total_keys() != total_len {
            return Err(Error::Invariant(format!(
                "payload loss: buckets hold {} of {total_len} keys",
                buckets.total_keys()
            )));
        }
        match self.mode {
            ThreadMode::Direct => self.run_direct(buckets, total_len),
            ThreadMode::Waves => self.run_waves(buckets, total_len),
        }
    }

    /// Paper-faithful mode: one thread per processor.  Each thread owns
    /// its disjoint `&mut [i32]` arena segment; channel messages carry
    /// `(bucket, range)` descriptors only.
    fn run_direct(&self, buckets: FlatBuckets, total_len: usize) -> Result<ThreadedOutcome> {
        let run = self.run_direct_raw(buckets)?;
        let parallel_time = run.region;
        let (counters, max_local_sort, messages) =
            (run.counters, run.max_local_sort, run.messages);
        let sorted = finish_gather(run.subarrays, run.buckets, total_len)?;
        Ok(ThreadedOutcome {
            sorted,
            parallel_time,
            counters,
            max_local_sort,
            messages,
        })
    }

    /// The fused Direct region without the master-side validation:
    /// spawn one OS thread per processor, sort + gather, and hand back
    /// the raw pieces ([`DirectRun`]) so a
    /// [`crate::pipeline::Session`] can validate and time the gather
    /// termination as its own stage.
    pub fn run_direct_raw(&self, mut buckets: FlatBuckets) -> Result<DirectRun> {
        let n = self.net.total_processors();
        let offsets: Vec<usize> = buckets.offsets().to_vec();
        let (txs, rxs): (Vec<Sender<Batch>>, Vec<Receiver<Batch>>) =
            (0..n).map(|_| channel()).unzip();
        // std receivers are not clonable; each thread takes its own.
        let rxs: Vec<Mutex<Option<Receiver<Batch>>>> =
            rxs.into_iter().map(|rx| Mutex::new(Some(rx))).collect();
        let (done_tx, done_rx) = channel::<(usize, SortCounters, Duration, usize)>();
        let (out_tx, out_rx) = channel::<(Vec<SubArray>, Instant)>();

        let start = Instant::now();
        {
            let segments = buckets.segments_mut();
            std::thread::scope(|scope| {
                for (id, seg) in segments.into_iter().enumerate() {
                    let range = offsets[id]..offsets[id + 1];
                    let rx = rxs[id].lock().unwrap().take().expect("receiver taken twice");
                    let txs = &txs;
                    let net = self.net;
                    let plan = &self.plans[id];
                    let sorter = self.sorter;
                    let done_tx = done_tx.clone();
                    let out_tx = out_tx.clone();
                    std::thread::Builder::new()
                        .name(format!("ohhc-p{id}"))
                        // Iterative quicksort → small stacks are safe even for
                        // thousands of simulated processors.
                        .stack_size(256 * 1024)
                        .spawn_scoped(scope, move || {
                            let t0 = Instant::now();
                            let counters = sorter.sort(seg);
                            let sort_time = t0.elapsed();

                            let own = SubArray { bucket: id as u32, range };
                            let mut held = Batch::single(own);
                            let mut sent = 0usize;
                            let action = plan.last();
                            while held.count() < action.wait_for {
                                let batch = rx.recv().expect("gather channel closed early");
                                held.merge(batch);
                            }
                            debug_assert_eq!(held.count(), action.wait_for);
                            match action.send_to {
                                Some(dst) => {
                                    txs[net.id(dst)].send(held).expect("send failed");
                                    sent = 1;
                                }
                                None => {
                                    // The master's gather ends *here* —
                                    // before the remaining worker threads
                                    // are joined — so the reported
                                    // parallel time excludes teardown of
                                    // up to 2304 OS threads.
                                    let output = (held.subarrays, Instant::now());
                                    out_tx.send(output).expect("master output");
                                }
                            }
                            done_tx.send((id, counters, sort_time, sent)).ok();
                        })
                        .expect("thread spawn");
                }
                drop(done_tx);
                drop(out_tx);
            });
        }

        let (subarrays, master_finished) = out_rx
            .recv()
            .map_err(|_| Error::Sim("master produced no output".into()))?;
        let region = master_finished.duration_since(start);

        let mut counters = SortCounters::default();
        let mut max_local_sort = Duration::ZERO;
        let mut messages = 0usize;
        while let Ok((_, c, t, sent)) = done_rx.try_recv() {
            counters += c;
            max_local_sort = max_local_sort.max(t);
            messages += sent;
        }

        Ok(DirectRun {
            buckets,
            subarrays,
            region,
            counters,
            max_local_sort,
            messages,
        })
    }

    /// Pooled local-sort stage: one task wave on the shared executor,
    /// sorting the disjoint arena segments in place — no thread spawn
    /// anywhere in this region.  The Waves half of the pipeline's
    /// local-sort stage; composed with [`Self::gather_bookkeeping`] by
    /// both [`Self::run`] and [`crate::pipeline::Session`].
    pub fn local_sort_wave(&self, buckets: &mut FlatBuckets) -> LocalSortStats {
        use crate::util::par;
        let workers = par::available_workers();
        let sorter = self.sorter;
        let results: Vec<(SortCounters, Duration)> = {
            let segments = buckets.segments_mut();
            par::par_map(segments, workers, move |seg| {
                let t0 = Instant::now();
                let c = sorter.sort(seg);
                (c, t0.elapsed())
            })
        };
        LocalSortStats {
            counters: results.iter().map(|r| r.0).sum(),
            max_local_sort: results.iter().map(|r| r.1).max().unwrap_or_default(),
        }
    }

    /// Pooled gather stage: drain the gather tree in depth order.
    /// Pure bookkeeping — each node forwards descriptor *counts*; no
    /// key ever moves because the arena already is the sorted array.
    /// Message counting mirrors the Direct mode.  Returns the number
    /// of messages passed.
    pub fn gather_bookkeeping(&self) -> Result<usize> {
        let n = self.net.total_processors();
        let mut held: Vec<usize> = vec![1; n];
        let order = gather_wave_order(self.net, self.plans);
        let mut messages = 0usize;
        for id in order {
            let action = self.plans[id].last();
            debug_assert_eq!(held[id], action.wait_for, "node {id}");
            if let Some(dst) = action.send_to {
                let moved = std::mem::take(&mut held[id]);
                held[self.net.id(dst)] += moved;
                messages += 1;
            }
        }
        if held[0] != n {
            return Err(Error::Invariant(format!(
                "gather terminated with {} of {n} sub-arrays at the master",
                held[0]
            )));
        }
        Ok(messages)
    }

    /// Wave mode: the two pooled stages back to back.
    fn run_waves(&self, mut buckets: FlatBuckets, total_len: usize) -> Result<ThreadedOutcome> {
        let start = Instant::now();
        let stats = self.local_sort_wave(&mut buckets);
        let messages = self.gather_bookkeeping()?;
        let parallel_time = start.elapsed();

        debug_assert_eq!(buckets.total_keys(), total_len);
        let (sorted, _) = buckets.into_arena();
        Ok(ThreadedOutcome {
            sorted,
            parallel_time,
            counters: stats.counters,
            max_local_sort: stats.max_local_sort,
            messages,
        })
    }
}

/// Topological order of the gather tree: leaves first, master last.
/// Children always appear before their parent, so a sequential walk
/// satisfies every wait-for count exactly.
pub fn gather_wave_order(net: &Ohhc, plans: &[NodePlan]) -> Vec<usize> {
    let n = net.total_processors();
    let mut depth = vec![0usize; n];
    for id in 0..n {
        let mut cur = id;
        let mut d = 0;
        while let Some(parent) = plans[cur].last().send_to {
            cur = net.id(parent);
            d += 1;
        }
        depth[id] = d;
    }
    let mut order: Vec<usize> = (0..n).collect();
    // Deeper nodes (farther from the master) act first.
    order.sort_by_key(|&id| std::cmp::Reverse(depth[id]));
    order
}

/// Terminate the gather: validate that the master's descriptors cover
/// every bucket segment exactly, then hand back the arena — which, in
/// bucket-rank order, is the globally sorted array (zero key copies).
pub fn finish_gather(
    mut subarrays: Vec<SubArray>,
    buckets: FlatBuckets,
    total_len: usize,
) -> Result<Vec<i32>> {
    if subarrays.len() != buckets.num_buckets() {
        return Err(Error::Invariant(format!(
            "payload loss: master holds {} of {} sub-arrays",
            subarrays.len(),
            buckets.num_buckets()
        )));
    }
    subarrays.sort_by_key(|s| s.bucket);
    let mut covered = 0usize;
    for (b, s) in subarrays.iter().enumerate() {
        if s.bucket as usize != b || s.range != buckets.range(b) {
            return Err(Error::Invariant(format!(
                "gather descriptor mismatch at bucket {b}: got bucket {} range {:?}",
                s.bucket, s.range
            )));
        }
        covered += s.range.len();
    }
    if covered != total_len {
        return Err(Error::Invariant(format!(
            "payload loss: descriptors cover {covered} of {total_len} keys"
        )));
    }
    Ok(buckets.into_arena().0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;
    use crate::schedule::gather_plan;
    use crate::sort::is_sorted;
    use crate::workload;

    /// Scatter `data` into per-processor buckets with the step-point rule
    /// (duplicated minimal divide logic; the real one lives in the
    /// coordinator and is tested there).
    fn bucketize(data: &[i32], n: usize) -> FlatBuckets {
        let lo = *data.iter().min().unwrap() as i64;
        let hi = *data.iter().max().unwrap() as i64;
        let sub = (((hi - lo) / n as i64).max(1)) as i64;
        let mut buckets = vec![Vec::new(); n];
        for &v in data {
            let b = (((v as i64 - lo) / sub) as usize).min(n - 1);
            buckets[b].push(v);
        }
        FlatBuckets::from_nested(buckets)
    }

    fn run_mode(d: u32, c: Construction, mode: ThreadMode) {
        let net = Ohhc::new(d, c).unwrap();
        let plans = gather_plan(&net);
        let data = workload::random(20_000, 77);
        let buckets = bucketize(&data, net.total_processors());
        let out = ThreadedSimulator::new(&net, &plans)
            .with_mode(mode)
            .run(buckets, data.len())
            .unwrap();
        assert_eq!(out.sorted.len(), data.len());
        assert!(is_sorted(&out.sorted), "d={d} {c:?} {mode:?}");
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(out.sorted, expect);
        // Every non-master node sends exactly once.
        assert_eq!(out.messages, net.total_processors() - 1);
        assert!(out.counters.comparisons > 0);
    }

    #[test]
    fn direct_mode_sorts_d1_full() {
        run_mode(1, Construction::FullGroup, ThreadMode::Direct);
    }

    #[test]
    fn direct_mode_sorts_d2_half() {
        run_mode(2, Construction::HalfGroup, ThreadMode::Direct);
    }

    #[test]
    fn waves_mode_sorts_d1_full() {
        run_mode(1, Construction::FullGroup, ThreadMode::Waves);
    }

    #[test]
    fn waves_mode_sorts_d3_full() {
        run_mode(3, Construction::FullGroup, ThreadMode::Waves);
    }

    #[test]
    fn waves_mode_matches_direct_counters() {
        let net = Ohhc::new(1, Construction::HalfGroup).unwrap();
        let plans = gather_plan(&net);
        let data = workload::random(10_000, 5);
        let buckets = bucketize(&data, net.total_processors());
        let direct = ThreadedSimulator::new(&net, &plans)
            .with_mode(ThreadMode::Direct)
            .run(buckets.clone(), data.len())
            .unwrap();
        let waves = ThreadedSimulator::new(&net, &plans)
            .with_mode(ThreadMode::Waves)
            .run(buckets, data.len())
            .unwrap();
        assert_eq!(direct.sorted, waves.sorted);
        assert_eq!(direct.counters, waves.counters);
        assert_eq!(direct.messages, waves.messages);
    }

    #[test]
    fn waves_throughput_profile_matches_direct_output() {
        // The tuned service profile (insertion cutoff 24) must produce
        // byte-identical sorted output on the pooled Waves path; only the
        // work counters move (insertion sort replaces the deep recursion
        // tail, so strictly fewer recursion calls on ~550-key buckets).
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let plans = gather_plan(&net);
        let data = workload::random(20_000, 33);
        let buckets = bucketize(&data, net.total_processors());
        let direct = ThreadedSimulator::new(&net, &plans)
            .with_mode(ThreadMode::Direct)
            .run(buckets.clone(), data.len())
            .unwrap();
        let tuned = ThreadedSimulator::new(&net, &plans)
            .with_mode(ThreadMode::Waves)
            .with_sorter(crate::sort::Quicksort::throughput())
            .run(buckets, data.len())
            .unwrap();
        assert_eq!(direct.sorted, tuned.sorted);
        assert!(
            tuned.counters.recursion_calls < direct.counters.recursion_calls,
            "cutoff 24 should shrink the recursion tail: {} vs {}",
            tuned.counters.recursion_calls,
            direct.counters.recursion_calls
        );
    }

    #[test]
    fn both_modes_return_the_arena_allocation() {
        // The zero-copy contract: `sorted` is the divide arena itself,
        // not a reassembled copy — in both execution modes.
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let plans = gather_plan(&net);
        let data = workload::random(15_000, 21);
        for mode in [ThreadMode::Direct, ThreadMode::Waves] {
            let buckets = bucketize(&data, net.total_processors());
            let ptr = buckets.arena().as_ptr();
            let cap = buckets.arena_capacity();
            let out = ThreadedSimulator::new(&net, &plans)
                .with_mode(mode)
                .run(buckets, data.len())
                .unwrap();
            assert_eq!(out.sorted.as_ptr(), ptr, "{mode:?} copied keys");
            assert_eq!(out.sorted.capacity(), cap, "{mode:?} reallocated");
        }
    }

    #[test]
    fn wave_order_parents_after_children() {
        let net = Ohhc::new(2, Construction::FullGroup).unwrap();
        let plans = gather_plan(&net);
        let order = gather_wave_order(&net, &plans);
        let pos: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        for id in 0..net.total_processors() {
            if let Some(parent) = plans[id].last().send_to {
                assert!(pos[&id] < pos[&net.id(parent)], "node {id}");
            }
        }
        assert_eq!(*order.last().unwrap(), 0);
    }

    #[test]
    fn rejects_wrong_bucket_count() {
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let plans = gather_plan(&net);
        let buckets = FlatBuckets::from_nested(vec![Vec::new(); 7]);
        let err = ThreadedSimulator::new(&net, &plans).run(buckets, 0);
        assert!(err.is_err());
    }
}
