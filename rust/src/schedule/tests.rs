//! Schedule validation: the computed wait-for counts must collapse to the
//! paper's closed forms on `G = P`, conserve payloads in both
//! constructions, and form a deadlock-free forwarding tree.

use super::*;
use crate::config::Construction;
use crate::topology::ohhc::{Addr, Ohhc};

fn net(d: u32, c: Construction) -> Ohhc {
    Ohhc::new(d, c).unwrap()
}

#[test]
fn fig_3_1_inner_hhc_rules() {
    // Worker-group cells: 5→0, 3→1, 4→2, {1,2}→0 with waits 1/1/1/2/2/6.
    let n = net(2, Construction::FullGroup);
    let plans = gather_plan(&n);
    let a = |cell, node| Addr {
        group: 3,
        cell,
        node,
    };
    let plan_of = |addr: Addr| &plans[n.id(addr)];

    let p5 = plan_of(a(1, 5));
    assert_eq!(p5.actions[0].wait_for, 1);
    assert_eq!(p5.actions[0].send_to, Some(a(1, 0)));

    let p3 = plan_of(a(1, 3));
    assert_eq!(p3.actions[0].send_to, Some(a(1, 1)));
    let p4 = plan_of(a(1, 4));
    assert_eq!(p4.actions[0].send_to, Some(a(1, 2)));

    for node in [1, 2] {
        let p = plan_of(a(1, node));
        assert_eq!(p.actions[0].wait_for, 2, "node {node}");
        assert_eq!(p.actions[0].send_to, Some(a(1, 0)));
    }
}

#[test]
fn fig_3_2_hypercube_rules() {
    // d=3 → 4 cells per group.  Cell 3 (fsb=1) waits 6, sends to cell 2;
    // cell 2 (fsb=2) waits 12, sends to cell 0; cell 1 waits 6 → cell 0.
    let n = net(3, Construction::FullGroup);
    let plans = gather_plan(&n);
    let head = |cell| Addr {
        group: 2,
        cell,
        node: 0,
    };
    let act = |cell: usize| plans[n.id(head(cell))].actions[0];

    assert_eq!(act(3).wait_for, 6);
    assert_eq!(act(3).send_to, Some(head(2)));
    assert_eq!(act(2).wait_for, 12);
    assert_eq!(act(2).send_to, Some(head(0)));
    assert_eq!(act(1).wait_for, 6);
    assert_eq!(act(1).send_to, Some(head(0)));
    assert_eq!(act(1).phase, Phase::HyperCube);
}

#[test]
fn fig_3_3_otis_rules() {
    // Group heads wait for the whole group (6·2^(d-1)) and forward over
    // the optical transpose to processor g of group 0.
    for d in 1..=4 {
        let n = net(d, Construction::FullGroup);
        let plans = gather_plan(&n);
        for g in 1..n.groups {
            let head = Addr {
                group: g,
                cell: 0,
                node: 0,
            };
            let act = plans[n.id(head)].actions[0];
            assert_eq!(act.wait_for, n.procs_per_group, "d={d} g={g}");
            assert_eq!(act.phase, Phase::Otis);
            let dst = act.send_to.unwrap();
            assert_eq!(dst.group, 0);
            assert_eq!(dst.local(), g, "d={d} g={g}");
            // And that send is a single optical hop (the link exists).
            assert_eq!(n.optical_partner(head), Some(dst));
        }
    }
}

#[test]
fn fig_3_4_group0_closed_forms_full_construction() {
    // Paper Fig 3.4 (G = P): normal = G·?…  With GetHHCGroupsNumber(d)·6
    // = P processors per group, normal = P + 1.
    for d in 1..=4 {
        let n = net(d, Construction::FullGroup);
        let plans = gather_plan(&n);
        let p = n.procs_per_group;
        let normal = p + 1;
        let a = |cell, node| Addr {
            group: 0,
            cell,
            node,
        };

        // Nodes 3/4/5 of every cell wait exactly their own load.
        for cell in 0..n.cells_per_group() {
            for node in [3, 4, 5] {
                let act = plans[n.id(a(cell, node))].actions[0];
                let expected = if a(cell, node).local() < n.groups {
                    normal // holds an optical batch
                } else {
                    1
                };
                assert_eq!(act.wait_for, expected, "d={d} cell={cell} node={node}");
            }
            // Aggregation nodes 1/2: own + feeder = 2·normal when both
            // hold optical batches (always true in G = P: local < G).
            for node in [1, 2] {
                let act = plans[n.id(a(cell, node))].actions[0];
                let self_load = if a(cell, node).local() < n.groups {
                    normal
                } else {
                    1
                };
                let feeder_load = if a(cell, node + 2).local() < n.groups {
                    normal
                } else {
                    1
                };
                assert_eq!(act.wait_for, self_load + feeder_load);
            }
        }

        // In G = P every group-0 processor except the master holds an
        // optical batch, so cell 0's aggregate inflow at the master is
        // 5·normal + 1 — the paper's masterHHCHeadNodeWaitFor — and the
        // machine total is G·P.
        let master = plans[n.id(a(0, 0))].actions[0];
        assert_eq!(master.wait_for, n.groups * p, "d={d}");
        assert_eq!(master.send_to, None);
        if d == 1 {
            // Single cell: the master's terminal wait IS Fig 3.4's value.
            assert_eq!(master.wait_for, 5 * normal + 1);
        }
    }
}

#[test]
fn fig_3_5_group0_hypercube_closed_form() {
    // Cell heads of group 0 wait 6·normal·2^(fsb-1) in G = P (all six
    // nodes of each cell hold normal = P+1... except cells whose locals
    // exceed G — impossible in full construction).
    for d in 2..=4 {
        let n = net(d, Construction::FullGroup);
        let plans = gather_plan(&n);
        let normal = n.procs_per_group + 1;
        for cell in 1..n.cells_per_group() {
            let head = Addr {
                group: 0,
                cell,
                node: 0,
            };
            let act = plans[n.id(head)].actions[0];
            let fsb = cell.trailing_zeros() + 1;
            let expected = 6 * normal * (1usize << (fsb - 1));
            assert_eq!(act.wait_for, expected, "d={d} cell={cell}");
            assert_eq!(act.phase, Phase::MasterHyperCube);
        }
    }
}

#[test]
fn conservation_both_constructions() {
    // The master's terminal wait equals the total number of sub-arrays,
    // and every non-master node forwards exactly once.
    for d in 1..=4 {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let n = net(d, c);
            let plans = gather_plan(&n);
            let total = n.groups * n.procs_per_group;
            let master = &plans[0];
            assert_eq!(master.last().wait_for, total, "d={d} {c:?}");
            assert_eq!(master.last().send_to, None);
            let senders = plans.iter().filter(|p| p.last().send_to.is_some()).count();
            assert_eq!(senders, total - 1, "d={d} {c:?}");
        }
    }
}

#[test]
fn forwarding_tree_is_acyclic_and_rooted_at_master() {
    for d in 1..=4 {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let n = net(d, c);
            let plans = gather_plan(&n);
            let parents = scatter_order(&plans);
            for id in 0..n.total_processors() {
                let mut cur = id;
                let mut hops = 0;
                while let Some(parent) = parents[cur] {
                    cur = n.id(parent);
                    hops += 1;
                    assert!(hops <= n.total_processors(), "cycle at {id} (d={d} {c:?})");
                }
                assert_eq!(cur, 0, "node {id} does not drain to the master");
            }
        }
    }
}

#[test]
fn wait_counts_are_satisfiable() {
    // Every node's wait must equal its own initial load plus the loads of
    // the children that send to it — otherwise the gather deadlocks.
    // Simulate the counting abstractly (no payloads, just counts).
    for d in 1..=4 {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let n = net(d, c);
            let plans = gather_plan(&n);
            let total = n.total_processors();
            // initial loads: 1 everywhere + P for group-0 locals 1..G
            // (delivered by the OTIS sends, which we replay like messages).
            let mut held: Vec<usize> = vec![1; total];
            let mut done = vec![false; total];
            let mut progressed = true;
            while progressed {
                progressed = false;
                for id in 0..total {
                    if done[id] {
                        continue;
                    }
                    let act = plans[id].last();
                    if held[id] >= act.wait_for {
                        assert_eq!(
                            held[id], act.wait_for,
                            "node {id} over-accumulated (d={d} {c:?})"
                        );
                        if let Some(dst) = act.send_to {
                            held[n.id(dst)] += held[id];
                            held[id] = 0;
                        }
                        done[id] = true;
                        progressed = true;
                    }
                }
            }
            assert!(done.iter().all(|&x| x), "gather deadlocked (d={d} {c:?})");
            assert_eq!(held[0], n.groups * n.procs_per_group);
        }
    }
}

#[test]
fn gather_subtrees_partition_the_machine() {
    let n = net(2, Construction::HalfGroup);
    let plans = gather_plan(&n);
    // The master's subtree is everything.
    assert_eq!(gather_subtree(&n, &plans, 0).len(), n.total_processors());
    // A worker-group head's subtree is its whole group.
    let head = n.id(Addr {
        group: 1,
        cell: 0,
        node: 0,
    });
    let sub = gather_subtree(&n, &plans, head);
    assert_eq!(sub.len(), n.procs_per_group);
    assert!(sub.iter().all(|&p| n.addr(p).group == 1));
    // A leaf's subtree is itself.
    let leaf = n.id(Addr {
        group: 1,
        cell: 0,
        node: 3,
    });
    assert_eq!(gather_subtree(&n, &plans, leaf), vec![leaf]);
}
