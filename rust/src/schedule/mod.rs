//! Static gather/scatter schedules — the paper's coordination contribution.
//!
//! The OHHC Quick Sort never negotiates at runtime: every processor knows,
//! **statically from its position**, how many sub-arrays it must accumulate
//! before forwarding and where to forward them (paper §3.2 and Figs
//! 3.1–3.5).  This module computes those wait-for/send rules for any
//! dimension and both constructions, generalizing the paper's full-group
//! pseudocode; the tests verify that on `G = P` the computed counts
//! collapse to the paper's closed forms
//! (`normal = P+1`, `aggregate = 2·normal`, `head = 6·normal`,
//! `master = 5·normal + 1`).
//!
//! Gather proceeds in conceptual phases — (a) inner-HHC, (b) hypercube,
//! (c) OTIS optical, then (d)+(e) repeat (a)+(b) inside group 0 — but no
//! barrier exists between them: the cumulative wait counts alone enforce
//! the ordering, exactly as in the paper.

mod bundle;
mod plan;

pub use bundle::TopologyBundle;
pub use plan::{gather_plan, gather_subtree, scatter_order, GatherAction, NodePlan, Phase};

#[cfg(test)]
mod tests;
