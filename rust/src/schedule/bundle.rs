//! A topology plus its gather plans, bundled for reuse.
//!
//! Building an [`Ohhc`] and computing every processor's [`NodePlan`] is
//! pure function of `(dimension, construction)` — yet the pre-campaign
//! coordinator rebuilt both on every `OhhcSorter::new`.  The bundle makes
//! that construction explicit and shareable: sorters borrow an
//! `Arc<TopologyBundle>`, so a sweep touching the same topology hundreds
//! of times builds it exactly once (see [`crate::campaign::PlanCache`]).

use crate::config::Construction;
use crate::error::Result;
use crate::schedule::{gather_plan, NodePlan};
use crate::topology::ohhc::Ohhc;

/// An OHHC topology and the static gather plans derived from it.
#[derive(Debug, Clone)]
pub struct TopologyBundle {
    /// The network.
    pub net: Ohhc,
    /// Per-processor gather plans, indexed by flat node id.
    pub plans: Vec<NodePlan>,
}

impl TopologyBundle {
    /// Build the topology and its plans for one `(dimension, construction)`.
    pub fn build(dimension: u32, construction: Construction) -> Result<Self> {
        let net = Ohhc::new(dimension, construction)?;
        let plans = gather_plan(&net);
        Ok(TopologyBundle { net, plans })
    }

    /// Cache key this bundle was built for.
    pub fn key(&self) -> (u32, Construction) {
        (self.net.dimension, self.net.construction)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundle_matches_direct_construction() {
        let bundle = TopologyBundle::build(2, Construction::HalfGroup).unwrap();
        let net = Ohhc::new(2, Construction::HalfGroup).unwrap();
        assert_eq!(bundle.net.total_processors(), net.total_processors());
        assert_eq!(bundle.plans, gather_plan(&net));
        assert_eq!(bundle.key(), (2, Construction::HalfGroup));
    }

    #[test]
    fn bundle_rejects_bad_dimension() {
        assert!(TopologyBundle::build(0, Construction::FullGroup).is_err());
        assert!(TopologyBundle::build(9, Construction::FullGroup).is_err());
    }
}
