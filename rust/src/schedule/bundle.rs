//! A topology plus its gather plans, bundled for reuse.
//!
//! Building an [`Ohhc`] and computing every processor's [`NodePlan`] is
//! pure function of `(dimension, construction)` — yet the pre-campaign
//! coordinator rebuilt both on every `OhhcSorter::new`.  The bundle makes
//! that construction explicit and shareable: sorters borrow an
//! `Arc<TopologyBundle>`, so a sweep touching the same topology hundreds
//! of times builds it exactly once (see [`crate::campaign::PlanCache`]).

use crate::config::Construction;
use crate::error::Result;
use crate::schedule::{gather_plan, NodePlan};
use crate::topology::fault::{route_avoiding, FaultSet, RouteOutcome};
use crate::topology::ohhc::Ohhc;
use crate::topology::routing;

/// An OHHC topology and the static gather plans derived from it.
#[derive(Debug, Clone)]
pub struct TopologyBundle {
    /// The network.
    pub net: Ohhc,
    /// Per-processor gather plans, indexed by flat node id.
    pub plans: Vec<NodePlan>,
}

impl TopologyBundle {
    /// Build the topology and its plans for one `(dimension, construction)`.
    pub fn build(dimension: u32, construction: Construction) -> Result<Self> {
        let net = Ohhc::new(dimension, construction)?;
        let plans = gather_plan(&net);
        Ok(TopologyBundle { net, plans })
    }

    /// Cache key this bundle was built for.
    pub fn key(&self) -> (u32, Construction) {
        (self.net.dimension, self.net.construction)
    }

    /// Route between two processors under a fault set.
    ///
    /// Healthy network: the deterministic OTIS router
    /// ([`routing::route`]), which is what the schedule assumes.  Under
    /// faults: a hop-shortest detour on the surviving subgraph through
    /// whatever redundancy remains (hexa-cell edges, hypercube
    /// dimensions, the optical transpose), or
    /// [`RouteOutcome::Unreachable`] when the pair is partitioned.
    pub fn route(&self, src: usize, dst: usize, faults: &FaultSet) -> RouteOutcome {
        if faults.is_empty() {
            return RouteOutcome::Path(routing::route(
                &self.net,
                self.net.addr(src),
                self.net.addr(dst),
            ));
        }
        route_avoiding(self.net.graph(), faults, src, dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundle_matches_direct_construction() {
        let bundle = TopologyBundle::build(2, Construction::HalfGroup).unwrap();
        let net = Ohhc::new(2, Construction::HalfGroup).unwrap();
        assert_eq!(bundle.net.total_processors(), net.total_processors());
        assert_eq!(bundle.plans, gather_plan(&net));
        assert_eq!(bundle.key(), (2, Construction::HalfGroup));
    }

    #[test]
    fn bundle_rejects_bad_dimension() {
        assert!(TopologyBundle::build(0, Construction::FullGroup).is_err());
        assert!(TopologyBundle::build(9, Construction::FullGroup).is_err());
    }

    #[test]
    fn bundle_routes_around_faults() {
        let bundle = TopologyBundle::build(1, Construction::FullGroup).unwrap();
        // Healthy: the deterministic router.
        let healthy = bundle.route(0, 7, &FaultSet::new());
        let direct = crate::topology::routing::route(
            &bundle.net,
            bundle.net.addr(0),
            bundle.net.addr(7),
        );
        assert_eq!(healthy.path().unwrap(), &direct[..]);
        // Fail every link of the healthy route: a detour must appear
        // that avoids them all (hexa-cell redundancy guarantees one).
        let mut faults = FaultSet::new();
        for w in direct.windows(2) {
            faults.fail_link(w[0], w[1]);
        }
        match bundle.route(0, 7, &faults) {
            RouteOutcome::Path(p) => {
                assert_eq!((p[0], *p.last().unwrap()), (0, 7));
                for w in p.windows(2) {
                    assert!(faults.allows(w[0], w[1]));
                    assert!(bundle.net.graph().has_edge(w[0], w[1]));
                }
            }
            RouteOutcome::Unreachable => panic!("OHHC redundancy should survive this"),
        }
        // A dead destination is unreachable.
        let mut faults = FaultSet::new();
        faults.fail_node(7);
        assert!(bundle.route(0, 7, &faults).is_unreachable());
    }
}
