//! Gather-plan computation (Figs 3.1–3.5, generalized).

use crate::topology::hypercube::first_set_bit;
use crate::topology::ohhc::{Addr, Ohhc};

/// Which algorithm phase an action belongs to (for traces and figures).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Fig 3.1 — inner hexa-cell accumulation (electrical).
    InnerHhc,
    /// Fig 3.2 — hypercube accumulation across cells (electrical).
    HyperCube,
    /// Fig 3.3 — optical transpose hop to group 0.
    Otis,
    /// Fig 3.4 — inner hexa-cell accumulation inside group 0.
    MasterInnerHhc,
    /// Fig 3.5 — hypercube accumulation inside group 0.
    MasterHyperCube,
}

/// One step of a node's gather role: accumulate until `wait_for`
/// sub-arrays are held (own payload included), then forward everything to
/// `send_to` (`None` marks the master's terminal wait).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatherAction {
    /// Phase label.
    pub phase: Phase,
    /// Cumulative sub-array count that must be held before acting.
    pub wait_for: usize,
    /// Destination, or `None` when this node is the final sink.
    pub send_to: Option<Addr>,
}

/// A processor's complete static role in the gather.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodePlan {
    /// Who this plan belongs to.
    pub addr: Addr,
    /// Ordered actions; empty only for pure leaf senders (never — every
    /// node at least sends or terminally waits).
    pub actions: Vec<GatherAction>,
}

impl NodePlan {
    /// Final action of the node (the send that ends its participation, or
    /// the master's terminal wait).
    pub fn last(&self) -> &GatherAction {
        self.actions.last().expect("plans are never empty")
    }
}

/// Sub-arrays *initially* held by a node once the OTIS phase has delivered
/// (1 own everywhere; group-0 processors `1..G` additionally receive one
/// whole group's accumulation of `P` sub-arrays over their optical link).
fn initial_load(net: &Ohhc, a: Addr) -> usize {
    let l = a.local();
    if a.group == 0 && l >= 1 && l < net.groups {
        1 + net.procs_per_group
    } else {
        1
    }
}

/// Sum of initial loads over one hexa-cell of group 0.
fn cell_load(net: &Ohhc, cell: usize) -> usize {
    (0..6)
        .map(|n| {
            initial_load(
                net,
                Addr {
                    group: 0,
                    cell,
                    node: n,
                },
            )
        })
        .sum()
}

/// Compute every processor's gather plan, indexed by flat node id.
pub fn gather_plan(net: &Ohhc) -> Vec<NodePlan> {
    let mut plans = Vec::with_capacity(net.total_processors());
    for id in 0..net.total_processors() {
        let a = net.addr(id);
        plans.push(if a.group == 0 {
            group0_plan(net, a)
        } else {
            worker_group_plan(net, a)
        });
    }
    plans
}

/// Plan for a node in a non-zero group (Figs 3.1–3.3).
fn worker_group_plan(net: &Ohhc, a: Addr) -> NodePlan {
    let g = a.group;
    let at = |cell, node| Addr {
        group: g,
        cell,
        node,
    };
    let mut actions = Vec::new();
    match a.node {
        // Fig 3.1: triangle-B nodes forward over the matching.
        3 => actions.push(GatherAction {
            phase: Phase::InnerHhc,
            wait_for: 1,
            send_to: Some(at(a.cell, 1)),
        }),
        4 => actions.push(GatherAction {
            phase: Phase::InnerHhc,
            wait_for: 1,
            send_to: Some(at(a.cell, 2)),
        }),
        5 => actions.push(GatherAction {
            phase: Phase::InnerHhc,
            wait_for: 1,
            send_to: Some(at(a.cell, 0)),
        }),
        // Fig 3.1: aggregation nodes 1 and 2 wait for their matched feeder.
        1 | 2 => actions.push(GatherAction {
            phase: Phase::InnerHhc,
            wait_for: 2,
            send_to: Some(at(a.cell, 0)),
        }),
        // Cell heads.
        0 => {
            if a.cell == 0 {
                // Group head: Fig 3.3 — wait for the whole group, then one
                // optical hop to processor `g` of group 0.
                actions.push(GatherAction {
                    phase: Phase::Otis,
                    wait_for: net.procs_per_group,
                    send_to: Some({
                        let (cell, node) = (g / 6, g % 6);
                        Addr {
                            group: 0,
                            cell,
                            node,
                        }
                    }),
                });
            } else {
                // Fig 3.2: wait for the reduction subtree (6·2^(fsb-1)),
                // then clear the lowest set bit.
                let fsb = first_set_bit(a.cell);
                let subtree = 6 * (1usize << (fsb - 1));
                let parent = a.cell & (a.cell - 1);
                actions.push(GatherAction {
                    phase: Phase::HyperCube,
                    wait_for: subtree,
                    send_to: Some(at(parent, 0)),
                });
            }
        }
        _ => unreachable!("hexa-cell node ids are 0..6"),
    }
    NodePlan { addr: a, actions }
}

/// Plan for a node of group 0 (Figs 3.4 / 3.5): identical flow, but wait
/// amounts account for the optical payloads its processors already hold.
fn group0_plan(net: &Ohhc, a: Addr) -> NodePlan {
    let at = |cell, node| Addr {
        group: 0,
        cell,
        node,
    };
    let own = initial_load(net, a);
    let load_of = |cell, node| initial_load(net, at(cell, node));
    let mut actions = Vec::new();
    match a.node {
        3 => actions.push(GatherAction {
            phase: Phase::MasterInnerHhc,
            wait_for: own,
            send_to: Some(at(a.cell, 1)),
        }),
        4 => actions.push(GatherAction {
            phase: Phase::MasterInnerHhc,
            wait_for: own,
            send_to: Some(at(a.cell, 2)),
        }),
        5 => actions.push(GatherAction {
            phase: Phase::MasterInnerHhc,
            wait_for: own,
            send_to: Some(at(a.cell, 0)),
        }),
        1 | 2 => {
            // Wait for own load plus the matched feeder's (3→1, 4→2).
            let feeder = a.node + 2;
            actions.push(GatherAction {
                phase: Phase::MasterInnerHhc,
                wait_for: own + load_of(a.cell, feeder),
                send_to: Some(at(a.cell, 0)),
            });
        }
        0 => {
            if a.cell == 0 {
                // The master: terminal wait for every sub-array in the
                // machine (paper: masterHHCHeadNodeWaitFor, then the
                // hypercube waits of Fig 3.5 subsume into the total).
                actions.push(GatherAction {
                    phase: Phase::MasterHyperCube,
                    wait_for: net.groups * net.procs_per_group,
                    send_to: None,
                });
            } else {
                // Cell head: subtree sum of cell loads (Fig 3.5's
                // `normalHHCHeadNodeWaitFor · 2^(bit-1)` generalized).
                let fsb = first_set_bit(a.cell);
                let subtree_cells = 1usize << (fsb - 1);
                let wait: usize = (a.cell..a.cell + subtree_cells)
                    .map(|c| cell_load(net, c))
                    .sum();
                let parent = a.cell & (a.cell - 1);
                actions.push(GatherAction {
                    phase: Phase::MasterHyperCube,
                    wait_for: wait,
                    send_to: Some(at(parent, 0)),
                });
            }
        }
        _ => unreachable!(),
    }
    NodePlan { addr: a, actions }
}

/// Scatter order: the reverse of the gather tree.  Returns, for every node,
/// the gather destination (= scatter source), with the master mapped to
/// `None`.  The distribution phase walks this tree root-to-leaves; the
/// threaded backend hands payloads over directly (shared memory, as the
/// paper's C++ threads do) while the DES charges store-and-forward costs
/// per tree edge.
pub fn scatter_order(plans: &[NodePlan]) -> Vec<Option<Addr>> {
    plans.iter().map(|p| p.last().send_to).collect()
}

/// The subtree of processors whose gather payloads flow through `root`
/// (including `root` itself) — used by the DES scatter phase to size the
/// forwarded batches.
pub fn gather_subtree(net: &Ohhc, plans: &[NodePlan], root: usize) -> Vec<usize> {
    let parents = scatter_order(plans);
    (0..net.total_processors())
        .filter(|&p| {
            let mut cur = p;
            loop {
                if cur == root {
                    return true;
                }
                match parents[cur] {
                    Some(next) => cur = net.id(next),
                    None => return false,
                }
            }
        })
        .collect()
}
