//! Figure harness: regenerates **every** table and figure of the paper's
//! evaluation (§6) from live runs — Table 1.1, Table 4.1, Figs 6.1–6.24.
//!
//! Cells of the paper's 216-run sweep (dimension × construction ×
//! distribution × size) are executed once and cached; every figure then
//! projects the cells it needs.  `scale` shrinks the paper's 10–60 MB
//! sizes so the full sweep fits a session budget (ratios — speedup,
//! efficiency, counter shapes — are scale-robust; EXPERIMENTS.md reports
//! both scaled and spot-checked paper-scale cells).

use std::collections::HashMap;

use crate::analysis::validate;
use crate::config::{Backend, Construction, Distribution, ExperimentConfig};
use crate::coordinator::OhhcSorter;
use crate::error::{Error, Result};
use crate::metrics::{Figure, Series, Summary};
use crate::sort::SortCounters;
use crate::workload::Workload;

/// All regenerable figure/table ids, in paper order.
pub const ALL_IDS: &[&str] = &[
    "table_1_1", "table_4_1", "fig_6_1", "fig_6_2", "fig_6_3", "fig_6_4", "fig_6_5",
    "fig_6_6", "fig_6_7", "fig_6_8", "fig_6_9", "fig_6_10", "fig_6_11", "fig_6_12",
    "fig_6_13", "fig_6_14", "fig_6_15", "fig_6_16", "fig_6_17", "fig_6_18", "fig_6_19",
    "fig_6_20", "fig_6_21", "fig_6_22", "fig_6_23", "fig_6_24",
];

const DIMS: [u32; 4] = [1, 2, 3, 4];

/// One cached sweep cell.
#[derive(Debug, Clone)]
struct Cell {
    seq_secs: f64,
    par_secs: f64,
    processors: usize,
    counters: SortCounters,
    seq_counters: SortCounters,
}

/// The harness: configuration + cell cache.
pub struct FigureHarness {
    /// Scale factor on the paper's 10–60 MB sizes (1.0 = paper scale).
    pub scale: f64,
    /// Repetitions per timing cell (median taken).
    pub repetitions: usize,
    /// `0` = paper-faithful one-thread-per-processor; otherwise waves.
    pub workers: usize,
    /// Workload seed.
    pub seed: u64,
    cache: HashMap<(u32, Construction, Distribution, usize), Cell>,
}

impl FigureHarness {
    /// New harness at a given scale.
    pub fn new(scale: f64) -> Self {
        FigureHarness {
            scale,
            repetitions: 1,
            workers: num_workers(),
            seed: 0x0511C0DE,
            cache: HashMap::new(),
        }
    }

    /// The six paper sizes, scaled, in keys.
    pub fn sizes(&self) -> Vec<usize> {
        ExperimentConfig::paper_sizes(self.scale)
    }

    /// Size axis in (unscaled) paper MB labels: 10..60.
    fn mb_labels() -> [f64; 6] {
        [10.0, 20.0, 30.0, 40.0, 50.0, 60.0]
    }

    /// Run (or fetch) one sweep cell.
    fn cell(&mut self, d: u32, c: Construction, dist: Distribution, n: usize) -> Result<Cell> {
        let key = (d, c, dist, n);
        if let Some(cell) = self.cache.get(&key) {
            return Ok(cell.clone());
        }
        let cfg = ExperimentConfig {
            dimension: d,
            construction: c,
            distribution: dist,
            elements: n,
            backend: Backend::Threaded,
            workers: self.workers,
            seed: self.seed,
            ..Default::default()
        };
        let sorter = OhhcSorter::new(&cfg)?;
        let workload = Workload::new(dist, n, self.seed);
        let mut seq = Vec::with_capacity(self.repetitions);
        let mut par = Vec::with_capacity(self.repetitions);
        let mut cell = None;
        for _ in 0..self.repetitions.max(1) {
            let r = sorter.run_on(&workload)?;
            seq.push(r.sequential_time.as_secs_f64());
            par.push(r.parallel_time.as_secs_f64());
            cell = Some(Cell {
                seq_secs: 0.0,
                par_secs: 0.0,
                processors: r.processors,
                counters: r.counters,
                seq_counters: r.sequential_counters,
            });
        }
        let mut cell = cell.expect("at least one repetition");
        cell.seq_secs = Summary::of(&seq).median;
        cell.par_secs = Summary::of(&par).median;
        self.cache.insert(key, cell.clone());
        Ok(cell)
    }

    /// Generate one figure by paper id.
    pub fn generate(&mut self, id: &str) -> Result<Figure> {
        match id {
            "table_1_1" => self.table_1_1(),
            "table_4_1" => self.table_4_1(),
            "fig_6_1" => self.fig_6_1(),
            "fig_6_2" => self.fig_6_2(),
            "fig_6_3" => self.fig_6_3(),
            "fig_6_4" => self.speedup_fig("fig_6_4", Construction::FullGroup, Distribution::Random),
            "fig_6_5" => self.speedup_fig("fig_6_5", Construction::FullGroup, Distribution::Sorted),
            "fig_6_6" => self.speedup_fig(
                "fig_6_6",
                Construction::FullGroup,
                Distribution::ReverseSorted,
            ),
            "fig_6_7" => self.speedup_fig("fig_6_7", Construction::FullGroup, Distribution::Local),
            "fig_6_8" => self.speedup_fig("fig_6_8", Construction::HalfGroup, Distribution::Random),
            "fig_6_9" => self.speedup_fig("fig_6_9", Construction::HalfGroup, Distribution::Sorted),
            "fig_6_10" => self.speedup_fig(
                "fig_6_10",
                Construction::HalfGroup,
                Distribution::ReverseSorted,
            ),
            "fig_6_11" => self.speedup_fig(
                "fig_6_11",
                Construction::HalfGroup,
                Distribution::Local,
            ),
            "fig_6_12" => self.efficiency_fig(
                "fig_6_12",
                Construction::FullGroup,
                Distribution::Random,
            ),
            "fig_6_13" => self.efficiency_fig(
                "fig_6_13",
                Construction::FullGroup,
                Distribution::Sorted,
            ),
            "fig_6_14" => self.efficiency_fig(
                "fig_6_14",
                Construction::FullGroup,
                Distribution::ReverseSorted,
            ),
            "fig_6_15" => self.efficiency_fig(
                "fig_6_15",
                Construction::FullGroup,
                Distribution::Local,
            ),
            "fig_6_16" => self.efficiency_fig(
                "fig_6_16",
                Construction::HalfGroup,
                Distribution::Random,
            ),
            "fig_6_17" => self.efficiency_fig(
                "fig_6_17",
                Construction::HalfGroup,
                Distribution::Sorted,
            ),
            "fig_6_18" => self.efficiency_fig(
                "fig_6_18",
                Construction::HalfGroup,
                Distribution::ReverseSorted,
            ),
            "fig_6_19" => self.efficiency_fig(
                "fig_6_19",
                Construction::HalfGroup,
                Distribution::Local,
            ),
            "fig_6_20" => self.counter_fig("fig_6_20", Distribution::Random),
            "fig_6_21" => self.counter_fig("fig_6_21", Distribution::Sorted),
            "fig_6_22" => self.fig_6_22(),
            "fig_6_23" => self.fig_6_23(),
            "fig_6_24" => self.fig_6_24(),
            other => Err(Error::Config(format!("unknown figure id `{other}`"))),
        }
    }

    // ---- Tables ---------------------------------------------------------

    fn table_1_1(&mut self) -> Result<Figure> {
        let mut g_full = Vec::new();
        let mut p_full = Vec::new();
        let mut g_half = Vec::new();
        let mut p_half = Vec::new();
        for d in DIMS {
            let full = crate::topology::ohhc::Ohhc::new(d, Construction::FullGroup)?;
            let half = crate::topology::ohhc::Ohhc::new(d, Construction::HalfGroup)?;
            g_full.push((d as f64, full.groups as f64));
            p_full.push((d as f64, full.total_processors() as f64));
            g_half.push((d as f64, half.groups as f64));
            p_half.push((d as f64, half.total_processors() as f64));
        }
        Ok(Figure {
            id: "table_1_1".into(),
            title: "OHHC dimensions and processor counts".into(),
            x_label: "dimension".into(),
            y_label: "count".into(),
            series: vec![
                Series {
                    label: "groups(G=P)".into(),
                    points: g_full,
                },
                Series {
                    label: "procs(G=P)".into(),
                    points: p_full,
                },
                Series {
                    label: "groups(G=P/2)".into(),
                    points: g_half,
                },
                Series {
                    label: "procs(G=P/2)".into(),
                    points: p_half,
                },
            ],
        })
    }

    fn table_4_1(&mut self) -> Result<Figure> {
        // Analytical assessment, evaluated + checked against the DES.
        let mut paper = Vec::new();
        let mut exact = Vec::new();
        let mut measured = Vec::new();
        let mut optical = Vec::new();
        for d in DIMS {
            let chk = validate::theorem3(d, Construction::FullGroup);
            paper.push((d as f64, chk.paper_form as f64));
            exact.push((d as f64, chk.exact_form as f64));
            measured.push((d as f64, chk.measured as f64));
            optical.push((d as f64, chk.measured_optical as f64));
        }
        Ok(Figure {
            id: "table_4_1".into(),
            title: "Theorem 3 communication steps: paper form vs exact vs DES".into(),
            x_label: "dimension".into(),
            y_label: "steps".into(),
            series: vec![
                Series {
                    label: "paper(12Gd-2)".into(),
                    points: paper,
                },
                Series {
                    label: "exact(2(GP-1))".into(),
                    points: exact,
                },
                Series {
                    label: "DES-measured".into(),
                    points: measured,
                },
                Series {
                    label: "DES-optical".into(),
                    points: optical,
                },
            ],
        })
    }

    // ---- Execution-time figures ------------------------------------------

    fn fig_6_1(&mut self) -> Result<Figure> {
        let sizes = self.sizes();
        let mut series = Vec::new();
        for dist in Distribution::ALL {
            let mut pts = Vec::new();
            for (i, &n) in sizes.iter().enumerate() {
                // Dimension is irrelevant for the sequential baseline;
                // reuse d=1 cells.
                let cell = self.cell(1, Construction::FullGroup, dist, n)?;
                pts.push((Self::mb_labels()[i], cell.seq_secs));
            }
            series.push(Series {
                label: dist.label().into(),
                points: pts,
            });
        }
        Ok(Figure {
            id: "fig_6_1".into(),
            title: "Sequential Quick Sort over array types and sizes".into(),
            x_label: "MB".into(),
            y_label: "seconds".into(),
            series,
        })
    }

    fn fig_6_2(&mut self) -> Result<Figure> {
        let sizes = self.sizes();
        let mut series = Vec::new();
        for d in DIMS {
            let mut pts = Vec::new();
            for (i, &n) in sizes.iter().enumerate() {
                let cell = self.cell(d, Construction::FullGroup, Distribution::Random, n)?;
                pts.push((Self::mb_labels()[i], cell.par_secs));
            }
            series.push(Series {
                label: format!("d={d}"),
                points: pts,
            });
        }
        Ok(Figure {
            id: "fig_6_2".into(),
            title: "Parallel run time, random distribution, G=P".into(),
            x_label: "MB".into(),
            y_label: "seconds".into(),
            series,
        })
    }

    fn fig_6_3(&mut self) -> Result<Figure> {
        let sizes = self.sizes();
        let mut series = Vec::new();
        for dist in Distribution::ALL {
            let mut pts = Vec::new();
            for (i, &n) in sizes.iter().enumerate() {
                let cell = self.cell(4, Construction::FullGroup, dist, n)?;
                pts.push((Self::mb_labels()[i], cell.par_secs));
            }
            series.push(Series {
                label: dist.label().into(),
                points: pts,
            });
        }
        Ok(Figure {
            id: "fig_6_3".into(),
            title: "4-D OHHC parallel run time over array types and sizes".into(),
            x_label: "MB".into(),
            y_label: "seconds".into(),
            series,
        })
    }

    // ---- Speedup / efficiency families -----------------------------------

    fn speedup_fig(&mut self, id: &str, c: Construction, dist: Distribution) -> Result<Figure> {
        let sizes = self.sizes();
        let mut series = Vec::new();
        for d in DIMS {
            let mut pts = Vec::new();
            for (i, &n) in sizes.iter().enumerate() {
                let cell = self.cell(d, c, dist, n)?;
                let pct = (cell.seq_secs - cell.par_secs) / cell.seq_secs * 100.0;
                pts.push((Self::mb_labels()[i], pct));
            }
            series.push(Series {
                label: format!("d={d}"),
                points: pts,
            });
        }
        Ok(Figure {
            id: id.into(),
            title: format!(
                "Relative speedup (%), {} distribution, {}",
                dist.label(),
                c.label()
            ),
            x_label: "MB".into(),
            y_label: "speedup %".into(),
            series,
        })
    }

    fn efficiency_fig(&mut self, id: &str, c: Construction, dist: Distribution) -> Result<Figure> {
        let sizes = self.sizes();
        let mut series = Vec::new();
        for d in DIMS {
            let mut pts = Vec::new();
            for (i, &n) in sizes.iter().enumerate() {
                let cell = self.cell(d, c, dist, n)?;
                let e = cell.seq_secs / (cell.processors as f64 * cell.par_secs) * 100.0;
                pts.push((Self::mb_labels()[i], e));
            }
            series.push(Series {
                label: format!("d={d}"),
                points: pts,
            });
        }
        Ok(Figure {
            id: id.into(),
            title: format!(
                "Efficiency ratio (%), {} distribution, {}",
                dist.label(),
                c.label()
            ),
            x_label: "MB".into(),
            y_label: "efficiency %".into(),
            series,
        })
    }

    // ---- Counter figures (6.20–6.24) --------------------------------------

    /// The paper's "30 MB" column: third size.
    fn thirty_mb(&self) -> usize {
        self.sizes()[2]
    }

    fn counter_fig(&mut self, id: &str, dist: Distribution) -> Result<Figure> {
        let n = self.thirty_mb();
        let mut rec = Vec::new();
        let mut iters = Vec::new();
        let mut swaps = Vec::new();
        // x = 0 is the sequential (undivided) baseline, showing how much
        // the division procedure alone reshapes the work.
        let seq = self.cell(1, Construction::FullGroup, dist, n)?.seq_counters;
        rec.push((0.0, seq.recursion_calls as f64));
        iters.push((0.0, seq.iterations as f64));
        swaps.push((0.0, seq.swaps as f64));
        for d in DIMS {
            let cell = self.cell(d, Construction::FullGroup, dist, n)?;
            rec.push((d as f64, cell.counters.recursion_calls as f64));
            iters.push((d as f64, cell.counters.iterations as f64));
            swaps.push((d as f64, cell.counters.swaps as f64));
        }
        Ok(Figure {
            id: id.into(),
            title: format!(
                "Recursions/iterations/swaps vs dimension, 30 MB {}",
                dist.label()
            ),
            x_label: "dimension".into(),
            y_label: "count".into(),
            series: vec![
                Series {
                    label: "recursion_calls".into(),
                    points: rec,
                },
                Series {
                    label: "iterations".into(),
                    points: iters,
                },
                Series {
                    label: "swaps".into(),
                    points: swaps,
                },
            ],
        })
    }

    fn fig_6_22(&mut self) -> Result<Figure> {
        let n = self.thirty_mb();
        let mut srt = Vec::new();
        let mut rnd = Vec::new();
        for d in DIMS {
            let cs = self.cell(d, Construction::FullGroup, Distribution::Sorted, n)?;
            let cr = self.cell(d, Construction::FullGroup, Distribution::Random, n)?;
            srt.push((d as f64, cs.counters.swaps as f64));
            rnd.push((d as f64, cr.counters.swaps as f64));
        }
        Ok(Figure {
            id: "fig_6_22".into(),
            title: "Swaps: sorted vs random, 30 MB".into(),
            x_label: "dimension".into(),
            y_label: "swaps".into(),
            series: vec![
                Series {
                    label: "sorted".into(),
                    points: srt,
                },
                Series {
                    label: "random".into(),
                    points: rnd,
                },
            ],
        })
    }

    fn fig_6_23(&mut self) -> Result<Figure> {
        let n = self.thirty_mb();
        let mut pts = Vec::new();
        for d in DIMS {
            let cell = self.cell(d, Construction::FullGroup, Distribution::Sorted, n)?;
            pts.push((d as f64, cell.counters.comparisons as f64));
        }
        Ok(Figure {
            id: "fig_6_23".into(),
            title: "Comparison steps vs dimension (sorted input)".into(),
            x_label: "dimension".into(),
            y_label: "comparisons".into(),
            series: vec![Series {
                label: "comparisons".into(),
                points: pts,
            }],
        })
    }

    fn fig_6_24(&mut self) -> Result<Figure> {
        let n = self.thirty_mb();
        let mut pts = Vec::new();
        for d in DIMS {
            let cell = self.cell(d, Construction::FullGroup, Distribution::Sorted, n)?;
            pts.push((d as f64, cell.counters.swaps as f64));
        }
        Ok(Figure {
            id: "fig_6_24".into(),
            title: "Swaps vs dimension (sorted input)".into(),
            x_label: "dimension".into(),
            y_label: "swaps".into(),
            series: vec![Series {
                label: "swaps".into(),
                points: pts,
            }],
        })
    }
}

/// Worker-count default: the host's parallelism (waves mode).
fn num_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn harness() -> FigureHarness {
        // Tiny scale keeps the test fast while exercising every code path.
        let mut h = FigureHarness::new(0.004); // ~10k–63k keys
        h.workers = 4;
        h
    }

    #[test]
    fn table_1_1_matches_paper() {
        let fig = harness().generate("table_1_1").unwrap();
        let procs_full = &fig.series[1].points;
        assert_eq!(
            procs_full.iter().map(|p| p.1 as usize).collect::<Vec<_>>(),
            vec![36, 144, 576, 2304]
        );
        let procs_half = &fig.series[3].points;
        assert_eq!(
            procs_half.iter().map(|p| p.1 as usize).collect::<Vec<_>>(),
            vec![18, 72, 288, 1152]
        );
    }

    #[test]
    fn table_4_1_measured_equals_exact() {
        let fig = harness().generate("table_4_1").unwrap();
        let exact = &fig.series[1].points;
        let measured = &fig.series[2].points;
        assert_eq!(exact, measured);
    }

    #[test]
    fn fig_6_1_has_four_series_six_sizes() {
        let fig = harness().generate("fig_6_1").unwrap();
        assert_eq!(fig.series.len(), 4);
        for s in &fig.series {
            assert_eq!(s.points.len(), 6);
            assert!(s.points.iter().all(|p| p.1 > 0.0));
        }
    }

    #[test]
    fn counter_figures_show_iteration_decay() {
        // The paper's Fig 6.20 claim: iterations fall sharply with d while
        // recursions stay ~flat.
        let mut h = harness();
        let fig = h.generate("fig_6_20").unwrap();
        // Points are x = 0 (sequential), 1, 2, 3, 4.
        let iters = &fig.series[1].points;
        assert_eq!(iters.len(), 5);
        assert!(
            iters[1].1 > 1.5 * iters[4].1,
            "iterations {} → {}",
            iters[1].1,
            iters[4].1
        );
        let rec = &fig.series[0].points;
        let ratio = rec[1].1 / rec[4].1;
        assert!((0.5..2.0).contains(&ratio), "recursions moved {ratio}x");
    }

    #[test]
    fn fig_6_22_sorted_swaps_far_below_random() {
        let fig = harness().generate("fig_6_22").unwrap();
        let sorted = &fig.series[0].points;
        let random = &fig.series[1].points;
        for (s, r) in sorted.iter().zip(random) {
            assert!(s.1 * 10.0 < r.1, "sorted {} vs random {}", s.1, r.1);
        }
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(harness().generate("fig_9_9").is_err());
    }

    #[test]
    fn all_ids_generate() {
        // Smoke: every advertised id produces a figure (cells cached, so
        // this is one sweep at tiny scale).
        let mut h = harness();
        for id in ALL_IDS {
            let fig = h.generate(id).unwrap();
            assert_eq!(&fig.id, id);
            assert!(!fig.series.is_empty(), "{id}");
        }
    }
}
