//! Seeded fault injection for the sort service: the chaos dial.
//!
//! A [`FaultPlan`] describes *how unreliable* the serving environment
//! should pretend to be — worker panics, failed links, dead processors —
//! without saying *which* job gets hit when.  Every concrete draw is a
//! pure function of `(plan seed, job id, attempt)`, so
//!
//! * the same plan replays the same failures run after run (chaos tests
//!   are deterministic), and
//! * a **retry is a fresh draw**: transient faults that hit attempt 0
//!   usually miss attempt 1, which is what makes the service's bounded
//!   retry budget worth having.
//!
//! The pool applies the plan in two places: [`FaultPlan::injects_panic`]
//! decides whether the worker thread executing a batch panics mid-sort
//! (exercising the catch-unwind / requeue path), and
//! [`FaultPlan::fault_set_for`] builds the [`FaultSet`] the pipeline
//! session routes around (exercising detours and
//! [`StageError`](crate::error::StageError) surfacing).

use crate::topology::fault::{splitmix64, FaultSet};
use crate::topology::ohhc::Ohhc;

/// Domain-separation constants so the panic draw, the link draw and the
/// node draw never reuse one another's randomness.
const PANIC_STREAM: u64 = 0x50A1_C0DE;
const LINK_STREAM: u64 = 0x11F0_11ED;
const NODE_STREAM: u64 = 0xDEAD_0000;

/// A seeded description of the faults to inject into the service.
///
/// The default plan is [`FaultPlan::none`]: fully healthy, zero
/// overhead on the job path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Base seed; all per-(job, attempt) draws derive from it.
    pub seed: u64,
    /// Probability in `[0, 1]` that a given (job, attempt) panics the
    /// worker mid-execution.
    pub worker_panic_rate: f64,
    /// Per-mille of network links failed for a given (job, attempt),
    /// drawn connectivity-preserving via [`FaultSet::seeded_links`].
    pub link_fail_permille: u32,
    /// Number of processors killed for a given (job, attempt), drawn
    /// via [`FaultSet::seeded_nodes`] (never the master, node 0).
    pub node_failures: usize,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The healthy plan: nothing ever fails.
    pub fn none() -> Self {
        FaultPlan {
            seed: 0xFA11,
            worker_panic_rate: 0.0,
            link_fail_permille: 0,
            node_failures: 0,
        }
    }

    /// Does this plan inject anything at all?  When `false` the pool
    /// skips the fault machinery entirely.
    pub fn is_active(&self) -> bool {
        self.worker_panic_rate > 0.0 || self.link_fail_permille > 0 || self.node_failures > 0
    }

    /// Per-(job, attempt) stream seed with domain separation.
    fn draw(&self, stream: u64, job_id: u64, attempt: u32) -> u64 {
        splitmix64(self.seed ^ splitmix64(stream ^ job_id) ^ ((attempt as u64) << 48))
    }

    /// Should the worker executing `(job_id, attempt)` panic?
    /// Deterministic in the plan seed; independent draws per attempt.
    pub fn injects_panic(&self, job_id: u64, attempt: u32) -> bool {
        if self.worker_panic_rate <= 0.0 {
            return false;
        }
        // Top 53 bits -> uniform f64 in [0, 1).
        let unit = (self.draw(PANIC_STREAM, job_id, attempt) >> 11) as f64 / (1u64 << 53) as f64;
        unit < self.worker_panic_rate
    }

    /// The network fault set for `(job_id, attempt)`, or `None` when the
    /// plan injects no network faults (the session then skips its
    /// pre-flight route check entirely).
    pub fn fault_set_for(&self, net: &Ohhc, job_id: u64, attempt: u32) -> Option<FaultSet> {
        if self.link_fail_permille == 0 && self.node_failures == 0 {
            return None;
        }
        let mut set = FaultSet::seeded_links(
            net.graph(),
            self.link_fail_permille,
            self.draw(LINK_STREAM, job_id, attempt),
        );
        set.extend(&FaultSet::seeded_nodes(
            net.total_processors(),
            self.node_failures,
            self.draw(NODE_STREAM, job_id, attempt),
        ));
        Some(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;

    #[test]
    fn inactive_plan_draws_nothing() {
        let plan = FaultPlan::none();
        assert!(!plan.is_active());
        assert!(!plan.injects_panic(1, 0));
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        assert!(plan.fault_set_for(&net, 1, 0).is_none());
    }

    #[test]
    fn panic_draws_are_deterministic_and_rate_shaped() {
        let plan = FaultPlan {
            worker_panic_rate: 0.5,
            ..FaultPlan::none()
        };
        let hits: Vec<bool> = (0..1000).map(|id| plan.injects_panic(id, 0)).collect();
        let again: Vec<bool> = (0..1000).map(|id| plan.injects_panic(id, 0)).collect();
        assert_eq!(hits, again, "same plan, same draws");
        let rate = hits.iter().filter(|&&h| h).count();
        assert!(
            (300..700).contains(&rate),
            "~half of 1000 jobs should draw a panic, got {rate}"
        );
        // Retries redraw: a job that panicked on attempt 0 is not doomed.
        let doomed = (0..1000)
            .filter(|&id| (0..4).all(|a| plan.injects_panic(id, a)))
            .count();
        assert!(doomed < 200, "attempt draws must be independent, {doomed} doomed");
    }

    #[test]
    fn fault_sets_vary_by_attempt_but_replay_by_seed() {
        let plan = FaultPlan {
            link_fail_permille: 100,
            node_failures: 1,
            ..FaultPlan::none()
        };
        assert!(plan.is_active());
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let a0 = plan.fault_set_for(&net, 7, 0).unwrap();
        let a0_again = plan.fault_set_for(&net, 7, 0).unwrap();
        assert_eq!(a0, a0_again, "deterministic per (job, attempt)");
        assert!(a0.num_failed_links() > 0);
        assert_eq!(a0.num_failed_nodes(), 1);
        assert!(!a0.is_node_failed(0), "the master survives every plan");
        let a1 = plan.fault_set_for(&net, 7, 1).unwrap();
        assert_ne!(a0, a1, "a retry redraws the fault set");
    }
}
