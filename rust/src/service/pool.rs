//! The sorter pool: the in-process, multi-tenant OHHC sort service.
//!
//! [`SortService::start`] spawns a fixed pool of worker threads.  Each
//! worker pops jobs from the shared bounded [`JobQueue`], leases the
//! job's `(dimension, construction)` [`TopologyBundle`] from a shared
//! campaign [`PlanCache`] (built once, shared by every worker that
//! needs it), and drives the existing pipeline end to end:
//! `divide_native` → [`FlatBuckets`] arena → [`ThreadedSimulator`]
//! local-sort + gather.  Small jobs coalesce through the
//! [`crate::service::batcher`] so one pipeline pass serves many
//! tenants.  Every job's output is verified (sorted + multiset
//! conservation) before the result ships; per-job queue/sort/total
//! latencies land in the shared [`ServiceStats`] histograms.
//!
//! The workers here are the *control plane* only — long-lived threads
//! spawned once at [`SortService::start`].  All per-job parallel compute
//! (divide waves, Waves local sorts) is submitted to the shared
//! persistent executor ([`crate::runtime::Executor::global`]), so a
//! burst of small jobs pays zero thread-spawn cost no matter how many
//! jobs it contains.  Waves-mode jobs use the tuned
//! [`Quicksort::throughput`] profile (insertion cutoff 24); the
//! paper-faithful `paper_threads` mode keeps the paper-default sorter.
//!
//! [`TopologyBundle`]: crate::schedule::TopologyBundle
//! [`FlatBuckets`]: crate::dataplane::FlatBuckets

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::campaign::{BundleLease, PlanCache};
use crate::config::Construction;
use crate::coordinator::divide_native;
use crate::error::Result;
use crate::service::admission::AdmissionControl;
use crate::service::batcher::coalesce;
use crate::service::job::{fnv1a, multiset_fingerprint, JobResult, JobSpec};
use crate::service::queue::{JobQueue, RejectReason, Submit};
use crate::service::stats::{ServiceSnapshot, ServiceStats};
use crate::sim::threaded::{ThreadMode, ThreadedSimulator};
use crate::sort::{is_sorted, Quicksort};
use crate::util::par;

/// Service knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Sorter-pool worker threads.
    pub workers: usize,
    /// Bounded submission-queue capacity.
    pub queue_capacity: usize,
    /// Token-bucket admit rate in jobs/second (`None` = unlimited).
    pub rate: Option<f64>,
    /// Token-bucket burst.
    pub burst: f64,
    /// Shed submissions once the queue depth reaches this
    /// (`usize::MAX` disables shedding).
    pub shed_depth: usize,
    /// Coalesce up to this many small jobs into one pipeline pass
    /// (`<= 1` disables batching).
    pub batch_max_jobs: usize,
    /// A batch never exceeds this many keys in total.
    pub batch_max_keys: usize,
    /// Jobs at or below this many keys are batchable.
    pub small_job_threshold: usize,
    /// Run the paper-faithful one-thread-per-processor simulator mode
    /// instead of the pooled waves mode.
    pub paper_threads: bool,
    /// Attach the sorted keys to every [`JobResult`] (tests; costly for
    /// large jobs).
    pub retain_output: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: par::available_workers().clamp(1, 8),
            queue_capacity: 256,
            rate: None,
            burst: 16.0,
            shed_depth: usize::MAX,
            batch_max_jobs: 8,
            batch_max_keys: 1 << 20,
            small_job_threshold: 4096,
            paper_threads: false,
            retain_output: false,
        }
    }
}

/// A job that made it past admission, stamped for queue-latency
/// accounting.
#[derive(Debug)]
struct QueuedJob {
    spec: JobSpec,
    accepted_at: Instant,
}

#[derive(Debug)]
struct Shared {
    cfg: ServiceConfig,
    queue: JobQueue<QueuedJob>,
    admission: AdmissionControl,
    stats: ServiceStats,
    cache: PlanCache,
}

/// The running service: submit jobs, receive results, shut down.
pub struct SortService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    results: Receiver<JobResult>,
}

impl SortService {
    /// Spawn the worker pool and start serving.
    pub fn start(cfg: ServiceConfig) -> SortService {
        let shared = Arc::new(Shared {
            queue: JobQueue::bounded(cfg.queue_capacity),
            admission: AdmissionControl::new(cfg.rate, cfg.burst, cfg.shed_depth),
            stats: ServiceStats::new(),
            cache: PlanCache::new(),
            cfg,
        });
        let (tx, rx) = std::sync::mpsc::channel();
        let workers = (0..shared.cfg.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                let tx = tx.clone();
                std::thread::Builder::new()
                    .name(format!("ohhc-svc-{i}"))
                    .spawn(move || worker_loop(&shared, &tx))
                    .expect("spawn service worker")
            })
            .collect();
        SortService {
            shared,
            workers,
            results: rx,
        }
    }

    /// Submit one job: validated, admission-checked, then offered to the
    /// bounded queue.  Never blocks; every path reports an explicit
    /// [`Submit`] outcome.
    pub fn submit(&self, spec: JobSpec) -> Submit {
        let outcome = if let Err(e) = spec.validate() {
            Submit::Rejected {
                reason: RejectReason::Invalid {
                    detail: e.to_string(),
                },
            }
        } else if let Err(reason) = self.shared.admission.admit(self.shared.queue.depth()) {
            Submit::Rejected { reason }
        } else {
            self.shared.queue.offer(QueuedJob {
                spec,
                accepted_at: Instant::now(),
            })
        };
        self.shared.stats.on_submit(outcome.is_accepted());
        outcome
    }

    /// A finished job, if one is ready.
    pub fn try_recv(&self) -> Option<JobResult> {
        self.results.try_recv().ok()
    }

    /// Wait up to `timeout` for a finished job.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<JobResult> {
        self.results.recv_timeout(timeout).ok()
    }

    /// Live queue depth.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Live stats (counters + histograms).
    pub fn stats(&self) -> &ServiceStats {
        &self.shared.stats
    }

    /// The shared topology cache (builds / hits / active leases).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.shared.cache
    }

    /// Graceful shutdown: close the queue (backlog still executes),
    /// join the pool, and return the final snapshot plus any results
    /// the caller had not yet received.
    pub fn shutdown(self) -> (ServiceSnapshot, Vec<JobResult>) {
        self.shared.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
        let rest: Vec<JobResult> = self.results.try_iter().collect();
        (self.shared.stats.snapshot(), rest)
    }
}

fn worker_loop(shared: &Shared, tx: &Sender<JobResult>) {
    // One lease per (dimension, construction) this worker has served —
    // held for the worker's lifetime, shared through the PlanCache.
    let mut leases: HashMap<(u32, Construction), BundleLease> = HashMap::new();
    while let Some(first) = shared.queue.pop() {
        let cfg = &shared.cfg;
        let key = (first.spec.dimension, first.spec.construction);
        let lease = match leases.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => match shared.cache.lease(key.0, key.1) {
                Ok(l) => v.insert(l),
                Err(e) => {
                    fail_batch(shared, &[first], Instant::now(), &e.to_string(), tx);
                    continue;
                }
            },
        };
        let mut batch = vec![first];
        // A coalesced pass cannot hold more jobs than the topology has
        // buckets (each job needs ≥ 1), so cap the claim at the leased
        // bundle's processor count.
        let max_batch = cfg.batch_max_jobs.min(lease.net.total_processors());
        if max_batch > 1 && batch[0].spec.elements <= cfg.small_job_threshold {
            let mut keys = batch[0].spec.elements;
            let more = shared.queue.drain_matching(max_batch - 1, |j| {
                let fits = j.spec.elements <= cfg.small_job_threshold
                    && (j.spec.dimension, j.spec.construction) == key
                    && keys + j.spec.elements <= cfg.batch_max_keys;
                if fits {
                    keys += j.spec.elements;
                }
                fits
            });
            batch.extend(more);
        }
        execute(shared, lease, batch, tx);
    }
}

fn execute(shared: &Shared, lease: &BundleLease, batch: Vec<QueuedJob>, tx: &Sender<JobResult>) {
    let started = Instant::now();
    shared.stats.on_batch(batch.len());
    let p = lease.net.total_processors();

    // Inputs are deterministic in the specs; the multiset fingerprints
    // are the conservation half of the per-job verification.
    let inputs: Vec<Vec<i32>> = batch.iter().map(|j| j.spec.generate()).collect();
    let fingerprints: Vec<u64> = inputs.iter().map(|d| multiset_fingerprint(d)).collect();
    let total: usize = inputs.iter().map(Vec::len).sum();

    // Waves jobs run as tasks on the shared executor with the tuned
    // throughput sorter; `paper_threads` keeps the paper's one thread
    // per processor and its default cutoff-0 sorter.
    let sim = if shared.cfg.paper_threads {
        ThreadedSimulator::new(&lease.net, &lease.plans).with_mode(ThreadMode::Direct)
    } else {
        ThreadedSimulator::new(&lease.net, &lease.plans)
            .with_mode(ThreadMode::Waves)
            .with_sorter(Quicksort::throughput())
    };

    let run = || -> Result<(Vec<i32>, Vec<Range<usize>>)> {
        if inputs.len() == 1 {
            let divided = divide_native(&inputs[0], p)?;
            let out = sim.run(divided.buckets, total)?;
            Ok((out.sorted, vec![0..total]))
        } else {
            let refs: Vec<&[i32]> = inputs.iter().map(Vec::as_slice).collect();
            let coalesced = coalesce(&refs, p)?;
            let ranges: Vec<Range<usize>> =
                (0..coalesced.num_jobs()).map(|j| coalesced.job_range(j)).collect();
            let out = sim.run(coalesced.buckets, total)?;
            Ok((out.sorted, ranges))
        }
    };

    match run() {
        Ok((sorted, ranges)) => {
            let sort_latency = started.elapsed();
            let batched = batch.len() > 1;
            for ((job, range), fp) in batch.iter().zip(&ranges).zip(&fingerprints) {
                let out = &sorted[range.clone()];
                let sorted_ok = is_sorted(out) && multiset_fingerprint(out) == *fp;
                let queue_latency = started.duration_since(job.accepted_at);
                let total_latency = queue_latency + sort_latency;
                let result = JobResult {
                    id: job.spec.id,
                    elements: job.spec.elements,
                    dimension: job.spec.dimension,
                    batched,
                    queue_latency,
                    sort_latency,
                    total_latency,
                    deadline: job.spec.deadline,
                    deadline_met: job.spec.deadline.map(|d| total_latency <= d),
                    sorted_ok,
                    checksum: fnv1a(out),
                    error: None,
                    output: shared.cfg.retain_output.then(|| out.to_vec()),
                };
                shared.stats.on_result(&result);
                tx.send(result).ok();
            }
        }
        Err(e) => fail_batch(shared, &batch, started, &e.to_string(), tx),
    }
}

/// Ship an explicit failure result for every job of a batch — jobs are
/// never dropped silently, even when the pipeline errors.
fn fail_batch(
    shared: &Shared,
    batch: &[QueuedJob],
    started: Instant,
    error: &str,
    tx: &Sender<JobResult>,
) {
    let sort_latency = started.elapsed();
    for job in batch {
        let queue_latency = started.duration_since(job.accepted_at);
        let total_latency = queue_latency + sort_latency;
        let result = JobResult {
            id: job.spec.id,
            elements: job.spec.elements,
            dimension: job.spec.dimension,
            batched: batch.len() > 1,
            queue_latency,
            sort_latency,
            total_latency,
            deadline: job.spec.deadline,
            deadline_met: job.spec.deadline.map(|d| total_latency <= d),
            sorted_ok: false,
            checksum: 0,
            error: Some(error.to_string()),
            output: None,
        };
        shared.stats.on_result(&result);
        tx.send(result).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::sort::quicksort;

    fn spec(id: u64, dist: Distribution, elements: usize, dimension: u32) -> JobSpec {
        JobSpec {
            id,
            distribution: dist,
            elements,
            seed: 1000 + id,
            dimension,
            construction: Construction::FullGroup,
            deadline: None,
        }
    }

    #[test]
    fn serves_jobs_across_dimensions_and_verifies() {
        let service = SortService::start(ServiceConfig {
            workers: 2,
            retain_output: true,
            ..Default::default()
        });
        for (id, d) in [(0u64, 1u32), (1, 2), (2, 1)] {
            assert!(service.submit(spec(id, Distribution::Random, 8_000, d)).is_accepted());
        }
        let mut results = Vec::new();
        while results.len() < 3 {
            results.push(service.recv_timeout(Duration::from_secs(30)).expect("stalled"));
        }
        let (snapshot, rest) = service.shutdown();
        assert!(rest.is_empty());
        assert_eq!(snapshot.accepted, 3);
        assert_eq!(snapshot.completed, 3);
        assert_eq!(snapshot.failed, 0);
        results.sort_by_key(|r| r.id);
        for r in &results {
            assert!(r.sorted_ok, "job {} failed verification", r.id);
            assert!(r.sort_latency > Duration::ZERO);
            assert!(r.total_latency >= r.sort_latency);
            // The retained output equals an independent sequential sort.
            let job = spec(r.id, Distribution::Random, 8_000, r.dimension);
            let mut expect = job.generate();
            quicksort(&mut expect);
            assert_eq!(r.output.as_deref(), Some(expect.as_slice()));
            assert_eq!(r.checksum, fnv1a(&expect));
        }
        assert!(snapshot.total.p50 > Duration::ZERO);
    }

    #[test]
    fn invalid_specs_are_rejected_not_enqueued() {
        let service = SortService::start(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let bad = JobSpec {
            elements: 0,
            ..spec(9, Distribution::Sorted, 1, 1)
        };
        match service.submit(bad) {
            Submit::Rejected {
                reason: RejectReason::Invalid { detail },
            } => assert!(detail.contains("elements")),
            other => panic!("expected Invalid rejection, got {other:?}"),
        }
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.rejected, 1);
        assert_eq!(snapshot.accepted, 0);
    }

    #[test]
    fn small_jobs_coalesce_behind_a_large_one() {
        // One worker, busy for a long while on a 2M-key job; the five
        // small jobs queued meanwhile must ride a coalesced batch.
        let service = SortService::start(ServiceConfig {
            workers: 1,
            batch_max_jobs: 8,
            small_job_threshold: 2_000,
            ..Default::default()
        });
        assert!(service.submit(spec(0, Distribution::Random, 2_000_000, 1)).is_accepted());
        for id in 1..=5 {
            assert!(service.submit(spec(id, Distribution::Random, 1_000, 1)).is_accepted());
        }
        let mut results = Vec::new();
        while results.len() < 6 {
            results.push(service.recv_timeout(Duration::from_secs(60)).expect("stalled"));
        }
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.completed, 6);
        assert!(
            snapshot.batched_jobs >= 2,
            "expected coalescing, got {} batched jobs",
            snapshot.batched_jobs
        );
        for r in results.iter().filter(|r| r.id > 0) {
            assert!(r.sorted_ok);
        }
    }

    #[test]
    fn pool_leases_topologies_through_the_shared_cache() {
        let service = SortService::start(ServiceConfig {
            workers: 3,
            ..Default::default()
        });
        for id in 0..9 {
            assert!(service.submit(spec(id, Distribution::Local, 6_000, 1)).is_accepted());
        }
        let mut seen = 0;
        while seen < 9 {
            service.recv_timeout(Duration::from_secs(30)).expect("stalled");
            seen += 1;
        }
        // All workers served d=1: one build, leases outstanding until
        // shutdown drops the workers.
        assert_eq!(service.plan_cache().builds(), 1);
        assert!(service.plan_cache().active_leases() >= 1);
        let shared = Arc::clone(&service.shared);
        service.shutdown();
        assert_eq!(shared.cache.active_leases(), 0, "leases returned on shutdown");
    }
}
