//! The sorter pool: the in-process, multi-tenant OHHC sort service.
//!
//! [`SortService::start`] spawns a fixed pool of worker threads.  Each
//! worker pops jobs from the shared bounded [`JobQueue`], leases the
//! job's `(dimension, construction)` [`TopologyBundle`] from a shared
//! campaign [`PlanCache`] (built once, shared by every worker that
//! needs it), and drives the one pipeline behind every driver — a
//! typestate [`Session`](crate::pipeline::Session) — **stage by
//! stage**: divide, local sort, gather.  Each stage is a wave of tasks
//! on the shared persistent executor, so the pool naturally
//! interleaves stages of different jobs instead of blocking a worker
//! inside one monolithic `run()`.  Small jobs coalesce through the
//! [`crate::service::batcher`] into one multi-span
//! [`Session::batched`](crate::pipeline::Session::batched) pass,
//! deadline-tightest first.  Every job's output is verified (sorted +
//! multiset conservation) before the result ships; per-job
//! queue/sort/total latencies land in the shared [`ServiceStats`]
//! histograms, and the stats also observe every session's stage
//! boundaries ([`crate::pipeline::Observer`]).
//!
//! The front door is per-job: [`SortService::submit`] returns a
//! [`Submission`] carrying a [`JobTicket`] backed by a private
//! completion slot — poll it, wait on it with a timeout, or cancel the
//! job before a worker claims it.  There is **no** shared result
//! channel; [`SortService::next_completion`] drains finished jobs
//! whose results nobody has taken yet (the compatibility path for
//! callers that drop their tickets).
//!
//! Faults are first-class: the configured [`FaultPlan`] can panic a
//! worker mid-pipeline or hand the session a seeded network
//! [`FaultSet`](crate::topology::FaultSet) to route around.  The pool
//! contains both — panics are caught, [`StageError`](crate::error::StageError)s
//! counted — and requeues the affected jobs (front of the queue,
//! capacity-exempt) with fresh fault draws, up to the configured
//! `retry_budget`; after that the job fails **explicitly**.  An
//! accepted job therefore always ends in exactly one published
//! [`JobResult`] or an observed cancellation, faults or not.
//!
//! The workers here are the *control plane* only — long-lived threads
//! spawned once at [`SortService::start`].  All per-job parallel
//! compute is submitted to the shared persistent executor
//! ([`crate::runtime::Executor::global`]), so a burst of small jobs
//! pays zero thread-spawn cost no matter how many jobs it contains.
//! Waves-mode jobs use the tuned [`Quicksort::throughput`] profile
//! (insertion cutoff 24); the paper-faithful `paper_threads` mode
//! keeps the paper-default sorter.
//!
//! [`TopologyBundle`]: crate::schedule::TopologyBundle

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::campaign::{BundleLease, PlanCache};
use crate::config::{Construction, DivideStrategy};
use crate::error::{Error, Result};
use crate::pipeline::{Engine, Outcome, Session};
use crate::service::admission::AdmissionControl;
use crate::service::batcher::order_by_deadline;
use crate::service::faults::FaultPlan;
use crate::service::job::{fnv1a, multiset_fingerprint, JobResult, JobSpec};
use crate::service::queue::{JobQueue, RejectReason, Submit};
use crate::service::stats::{ServiceSnapshot, ServiceStats};
use crate::service::ticket::{JobTicket, Slot, Submission};
use crate::sort::{is_sorted, Quicksort};
use crate::util::par;

/// Service knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Sorter-pool worker threads.
    pub workers: usize,
    /// Bounded submission-queue capacity.
    pub queue_capacity: usize,
    /// Token-bucket admit rate in jobs/second (`None` = unlimited).
    pub rate: Option<f64>,
    /// Token-bucket burst.
    pub burst: f64,
    /// Shed submissions once the queue depth reaches this
    /// (`usize::MAX` disables shedding).
    pub shed_depth: usize,
    /// Coalesce up to this many small jobs into one pipeline pass
    /// (`<= 1` disables batching).
    pub batch_max_jobs: usize,
    /// A batch never exceeds this many keys in total.
    pub batch_max_keys: usize,
    /// Jobs at or below this many keys are batchable.
    pub small_job_threshold: usize,
    /// Run the paper-faithful one-thread-per-processor simulator mode
    /// instead of the pooled waves mode.
    pub paper_threads: bool,
    /// Attach the sorted keys to every [`JobResult`] (tests; costly for
    /// large jobs).
    pub retain_output: bool,
    /// Seeded fault injection (worker panics, link/node failures);
    /// [`FaultPlan::none`] serves healthy with zero overhead.
    pub faults: FaultPlan,
    /// How many times a job hit by an injected fault is requeued before
    /// it fails explicitly (0 = fail on the first fault).
    pub retry_budget: u32,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: par::available_workers().clamp(1, 8),
            queue_capacity: 256,
            rate: None,
            burst: 16.0,
            shed_depth: usize::MAX,
            batch_max_jobs: 8,
            batch_max_keys: 1 << 20,
            small_job_threshold: 4096,
            paper_threads: false,
            retain_output: false,
            faults: FaultPlan::none(),
            retry_budget: 2,
        }
    }
}

/// A job that made it past admission, stamped for queue-latency
/// accounting and carrying its completion slot.
#[derive(Debug)]
struct QueuedJob {
    spec: JobSpec,
    accepted_at: Instant,
    slot: Arc<Slot>,
    /// 0 on first execution; incremented each time a fault requeues the
    /// job.  Feeds the per-(job, attempt) fault draws and the result's
    /// `retries` field.
    attempt: u32,
}

/// The completion drain's backing store.  Tenants that consume results
/// through their [`JobTicket`]s leave `Taken` slots behind here;
/// `push` compacts those away once they outnumber a geometric
/// watermark, so a long-running service whose tenants never drain
/// stays bounded by its live (untaken) results, not its job count.
#[derive(Debug, Default)]
struct CompletedQueue {
    slots: VecDeque<Arc<Slot>>,
    compact_at: usize,
}

impl CompletedQueue {
    const MIN_COMPACT: usize = 64;

    fn push(&mut self, slot: Arc<Slot>) {
        self.slots.push_back(slot);
        if self.slots.len() >= self.compact_at.max(Self::MIN_COMPACT) {
            self.slots.retain(|s| !s.is_taken());
            // Geometric growth keeps the retain amortized O(1) even
            // when every slot is live.
            self.compact_at = (self.slots.len() * 2).max(Self::MIN_COMPACT);
        }
    }
}

#[derive(Debug)]
struct Shared {
    cfg: ServiceConfig,
    queue: JobQueue<QueuedJob>,
    admission: AdmissionControl,
    stats: ServiceStats,
    cache: PlanCache,
    /// Finished slots whose results may not have been taken yet — what
    /// the completion drain (and the deprecated recv shims) serve from.
    completed: Mutex<CompletedQueue>,
    completed_cv: Condvar,
}

impl Shared {
    /// Record and publish one finished job: stats, the job's own slot,
    /// and the completion drain.
    fn publish(&self, slot: &Arc<Slot>, result: JobResult) {
        self.stats.on_result(&result);
        slot.complete(result);
        self.completed.lock().unwrap().push(Arc::clone(slot));
        self.completed_cv.notify_one();
    }
}

/// The running service: submit jobs (per-job tickets), await results,
/// shut down.
pub struct SortService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl SortService {
    /// Spawn the worker pool and start serving.
    pub fn start(cfg: ServiceConfig) -> SortService {
        let shared = Arc::new(Shared {
            queue: JobQueue::bounded(cfg.queue_capacity),
            admission: AdmissionControl::new(cfg.rate, cfg.burst, cfg.shed_depth),
            stats: ServiceStats::new(),
            cache: PlanCache::new(),
            completed: Mutex::new(CompletedQueue::default()),
            completed_cv: Condvar::new(),
            cfg,
        });
        let workers = (0..shared.cfg.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ohhc-svc-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn service worker")
            })
            .collect();
        SortService { shared, workers }
    }

    /// Submit one job: validated, admission-checked, then offered to
    /// the bounded queue.  Never blocks; every path reports an explicit
    /// [`Submission`] outcome, and an accepted job hands back its
    /// [`JobTicket`].  A ticket cancelled before a worker claims the
    /// job keeps its queue slot until the worker pops (and skips) it.
    pub fn submit(&self, spec: JobSpec) -> Submission {
        let outcome = if let Err(e) = spec.validate() {
            Submission::Rejected {
                reason: RejectReason::Invalid {
                    detail: e.to_string(),
                },
            }
        } else if let Err(reason) = self.shared.admission.admit(self.shared.queue.depth()) {
            Submission::Rejected { reason }
        } else {
            let slot = Slot::new(spec.id);
            let queued = QueuedJob {
                spec,
                accepted_at: Instant::now(),
                slot: Arc::clone(&slot),
                attempt: 0,
            };
            match self.shared.queue.offer(queued) {
                Submit::Accepted { depth } => Submission::Accepted {
                    depth,
                    ticket: JobTicket::new(slot),
                },
                Submit::Rejected { reason } => Submission::Rejected { reason },
            }
        };
        self.shared.stats.on_submit(outcome.is_accepted());
        outcome
    }

    /// Wait up to `timeout` for any finished job whose result has not
    /// been taken through its ticket yet, and take it.  This is the
    /// drain for callers that do not hold tickets; mixing it with
    /// per-ticket waits on the *same* jobs is first-taker-wins.  A
    /// `timeout` too large to represent as a deadline (e.g.
    /// `Duration::MAX`) waits indefinitely.
    pub fn next_completion(&self, timeout: Duration) -> Option<JobResult> {
        let deadline = Instant::now().checked_add(timeout);
        let mut q = self.shared.completed.lock().unwrap();
        loop {
            while let Some(slot) = q.slots.pop_front() {
                if let Some(r) = slot.take() {
                    return Some(r);
                }
                // Already taken through its ticket — keep draining.
            }
            q = match deadline {
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    self.shared
                        .completed_cv
                        .wait_timeout(q, deadline - now)
                        .unwrap()
                        .0
                }
                None => self.shared.completed_cv.wait(q).unwrap(),
            };
        }
    }

    /// Non-blocking [`Self::next_completion`].
    pub fn try_next_completion(&self) -> Option<JobResult> {
        self.next_completion(Duration::ZERO)
    }

    /// Live queue depth (cancelled-but-not-yet-skipped jobs included).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Live stats (counters + histograms).
    pub fn stats(&self) -> &ServiceStats {
        &self.shared.stats
    }

    /// The shared topology cache (builds / hits / active leases).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.shared.cache
    }

    /// Graceful shutdown: close the queue (backlog still executes),
    /// join the pool, and return the final snapshot plus every result
    /// nobody took through a ticket or the drain.
    pub fn shutdown(self) -> (ServiceSnapshot, Vec<JobResult>) {
        self.shared.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
        let mut rest = Vec::new();
        let mut q = self.shared.completed.lock().unwrap();
        while let Some(slot) = q.slots.pop_front() {
            if let Some(r) = slot.take() {
                rest.push(r);
            }
        }
        drop(q);
        (self.shared.stats.snapshot(), rest)
    }
}

fn worker_loop(shared: &Shared) {
    // One lease per (dimension, construction) this worker has served —
    // held for the worker's lifetime, shared through the PlanCache.
    let mut leases: HashMap<(u32, Construction), BundleLease> = HashMap::new();
    while let Some(first) = shared.queue.pop() {
        // Claim the job; a tenant that cancelled first wins and the
        // job is skipped without executing.
        if !first.slot.claim() {
            shared.stats.on_cancelled();
            continue;
        }
        let cfg = &shared.cfg;
        let key = (first.spec.dimension, first.spec.construction);
        let lease = match leases.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => match shared.cache.lease(key.0, key.1) {
                Ok(l) => v.insert(l),
                Err(e) => {
                    fail_batch(shared, &[first], Instant::now(), &e.to_string());
                    continue;
                }
            },
        };
        let mut batch = vec![first];
        // A coalesced pass cannot hold more jobs than the topology has
        // buckets (each job needs ≥ 1), so cap the claim at the leased
        // bundle's processor count.
        let max_batch = cfg.batch_max_jobs.min(lease.net.total_processors());
        if max_batch > 1 && batch[0].spec.elements <= cfg.small_job_threshold {
            let mut keys = batch[0].spec.elements;
            // Batches are strategy-uniform: a coalesced pass divides
            // once with the leader's strategy, so a job asking for a
            // different divide must not ride along.
            let strategy = batch[0].spec.strategy;
            let more = shared.queue.drain_matching(max_batch - 1, |j| {
                let fits = j.spec.elements <= cfg.small_job_threshold
                    && (j.spec.dimension, j.spec.construction) == key
                    && j.spec.strategy == strategy
                    && keys + j.spec.elements <= cfg.batch_max_keys;
                if fits {
                    keys += j.spec.elements;
                }
                fits
            });
            for job in more {
                if job.slot.claim() {
                    batch.push(job);
                } else {
                    shared.stats.on_cancelled();
                }
            }
            // Deadline-aware coalescing: least remaining slack (the
            // job's absolute deadline minus now, so time already spent
            // queued counts) lands earliest in the shared arena and is
            // split back / published first; deadline-free jobs ride
            // last, FIFO among ties.  Overdue jobs saturate to zero
            // slack and stay FIFO among themselves.
            let now = Instant::now();
            order_by_deadline(&mut batch, |j| {
                j.spec
                    .deadline
                    .and_then(|d| j.accepted_at.checked_add(d))
                    .map(|deadline| deadline.saturating_duration_since(now))
            });
        }
        execute(shared, lease, batch);
    }
}

fn execute(shared: &Shared, lease: &BundleLease, batch: Vec<QueuedJob>) {
    let started = Instant::now();
    shared.stats.on_batch(batch.len());

    // Inputs are deterministic in the specs; the multiset fingerprints
    // are the conservation half of the per-job verification.
    let inputs: Vec<Vec<i32>> = batch.iter().map(|j| j.spec.generate()).collect();
    let fingerprints: Vec<u64> = inputs.iter().map(|d| multiset_fingerprint(d)).collect();

    // Fault injection, decided before the pipeline runs: the batch
    // leader's (id, attempt) seeds the network fault set (one modeled
    // network per pipeline pass), and any member's draw can panic the
    // worker.  Retries redraw — see `FaultPlan`.
    let plan = &shared.cfg.faults;
    let leader = &batch[0];
    let fault_set = plan.fault_set_for(&lease.net, leader.spec.id, leader.attempt);
    let inject_panic = plan.worker_panic_rate > 0.0
        && batch.iter().any(|j| plan.injects_panic(j.spec.id, j.attempt));

    // Waves jobs run as pooled session stages with the tuned throughput
    // sorter; `paper_threads` keeps the paper's one thread per
    // processor and its default cutoff-0 sorter.
    let (engine, sorter) = if shared.cfg.paper_threads {
        (Engine::DirectThreads, Quicksort::default())
    } else {
        (Engine::Pooled, Quicksort::throughput())
    };

    let run = || -> Result<Outcome> {
        let refs: Vec<&[i32]> = inputs.iter().map(Vec::as_slice).collect();
        let session = if refs.len() == 1 {
            Session::single(&lease.net, &lease.plans, refs[0])
        } else {
            Session::batched(&lease.net, &lease.plans, &refs)
        };
        // Stage-by-stage drive: each transition is its own executor
        // wave, so concurrent jobs interleave at stage boundaries, and
        // the shared stats observe every boundary.
        let mut session = session
            .with_engine(engine)
            .with_divide_strategy(leader.spec.strategy)
            .with_sorter(sorter)
            .with_observer(&shared.stats);
        if let Some(f) = &fault_set {
            session = session.with_faults(f);
        }
        let divided = session.divide()?;
        if inject_panic {
            panic!(
                "injected fault: worker panic (job {}, attempt {})",
                leader.spec.id, leader.attempt
            );
        }
        divided.local_sort()?.gather()
    };

    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(run)) {
        Ok(Ok(outcome)) => {
            let sort_latency = started.elapsed();
            let batched = batch.len() > 1;
            for ((job, span), fp) in batch.iter().zip(&outcome.spans).zip(&fingerprints) {
                let out = &outcome.sorted[span.clone()];
                let sorted_ok = is_sorted(out) && multiset_fingerprint(out) == *fp;
                let queue_latency = started.duration_since(job.accepted_at);
                let total_latency = queue_latency + sort_latency;
                let result = JobResult {
                    id: job.spec.id,
                    elements: job.spec.elements,
                    dimension: job.spec.dimension,
                    batched,
                    queue_latency,
                    sort_latency,
                    total_latency,
                    deadline: job.spec.deadline,
                    deadline_met: job.spec.deadline.map(|d| total_latency <= d),
                    sorted_ok,
                    checksum: fnv1a(out),
                    imbalance: outcome.imbalance,
                    skew_redivides: outcome.skew_redivides,
                    retries: job.attempt,
                    error: None,
                    output: shared.cfg.retain_output.then(|| out.to_vec()),
                };
                shared.publish(&job.slot, result);
            }
        }
        // A fault the session surfaced (no surviving route / dead
        // processor): count it, then retry within budget.
        Ok(Err(e @ Error::Stage(_))) => {
            shared.stats.on_link_failure();
            retry_or_fail(shared, batch, started, &e.to_string());
        }
        // Any other pipeline error is a bug, not an injected fault —
        // retrying would just repeat it deterministically.
        Ok(Err(e)) => fail_batch(shared, &batch, started, &e.to_string()),
        // The worker panicked mid-pipeline (injected or real): the
        // unwind is contained here, the jobs retry within budget.
        Err(panic) => {
            shared.stats.on_worker_panic();
            let msg = panic_message(&panic);
            retry_or_fail(shared, batch, started, &format!("worker panicked: {msg}"));
        }
    }
}

/// Best-effort text out of a caught panic payload.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Requeue every job of a faulted batch that still has retry budget
/// (fresh fault draws next attempt), and fail the rest explicitly.
/// Nothing is ever dropped: each job ends up either back in the queue
/// or published with an error.
fn retry_or_fail(shared: &Shared, batch: Vec<QueuedJob>, started: Instant, error: &str) {
    let budget = shared.cfg.retry_budget;
    for mut job in batch {
        if job.attempt >= budget {
            shared.stats.on_retry_exhausted();
            let msg = format!("{error} (retry budget {budget} exhausted)");
            fail_batch(shared, std::slice::from_ref(&job), started, &msg);
            continue;
        }
        job.attempt += 1;
        // Claimed -> Queued on the slot first, then back into the queue
        // (capacity-exempt: the job already paid admission once).
        job.slot.requeue();
        shared.stats.on_retry();
        if let Err(job) = shared.queue.requeue(job) {
            // Shutdown raced the retry: fail explicitly instead.  The
            // reclaim can only lose to a tenant cancelling right now.
            if job.slot.claim() {
                let msg = format!("{error} (retry abandoned: service shutting down)");
                fail_batch(shared, std::slice::from_ref(&job), started, &msg);
            } else {
                shared.stats.on_cancelled();
            }
        }
    }
}

/// Ship an explicit failure result for every job of a batch — jobs are
/// never dropped silently, even when the pipeline errors.
fn fail_batch(shared: &Shared, batch: &[QueuedJob], started: Instant, error: &str) {
    let sort_latency = started.elapsed();
    for job in batch {
        let queue_latency = started.duration_since(job.accepted_at);
        let total_latency = queue_latency + sort_latency;
        let result = JobResult {
            id: job.spec.id,
            elements: job.spec.elements,
            dimension: job.spec.dimension,
            batched: batch.len() > 1,
            queue_latency,
            sort_latency,
            total_latency,
            deadline: job.spec.deadline,
            deadline_met: job.spec.deadline.map(|d| total_latency <= d),
            sorted_ok: false,
            checksum: 0,
            imbalance: 0.0,
            skew_redivides: 0,
            retries: job.attempt,
            error: Some(error.to_string()),
            output: None,
        };
        shared.publish(&job.slot, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::service::ticket::TicketStatus;
    use crate::sort::quicksort;

    fn spec(id: u64, dist: Distribution, elements: usize, dimension: u32) -> JobSpec {
        JobSpec {
            id,
            distribution: dist,
            elements,
            seed: 1000 + id,
            dimension,
            construction: Construction::FullGroup,
            strategy: DivideStrategy::PaperFixed,
            deadline: None,
        }
    }

    #[test]
    fn serves_jobs_across_dimensions_and_verifies() {
        let service = SortService::start(ServiceConfig {
            workers: 2,
            retain_output: true,
            ..Default::default()
        });
        let mut tickets = Vec::new();
        for (id, d) in [(0u64, 1u32), (1, 2), (2, 1)] {
            let submission = service.submit(spec(id, Distribution::Random, 8_000, d));
            tickets.push(submission.ticket().expect("accepted"));
        }
        // Results arrive through the per-job tickets, not a shared
        // channel — each ticket waits on its own completion slot.
        let mut results: Vec<JobResult> = tickets
            .iter()
            .map(|t| t.wait_timeout(Duration::from_secs(30)).expect("stalled"))
            .collect();
        for t in &tickets {
            assert_eq!(t.poll(), TicketStatus::Taken);
        }
        let (snapshot, rest) = service.shutdown();
        assert!(rest.is_empty(), "tickets already took every result");
        assert_eq!(snapshot.accepted, 3);
        assert_eq!(snapshot.completed, 3);
        assert_eq!(snapshot.failed, 0);
        results.sort_by_key(|r| r.id);
        for r in &results {
            assert!(r.sorted_ok, "job {} failed verification", r.id);
            assert!(r.sort_latency > Duration::ZERO);
            assert!(r.total_latency >= r.sort_latency);
            // The retained output equals an independent sequential sort.
            let job = spec(r.id, Distribution::Random, 8_000, r.dimension);
            let mut expect = job.generate();
            quicksort(&mut expect);
            assert_eq!(r.output.as_deref(), Some(expect.as_slice()));
            assert_eq!(r.checksum, fnv1a(&expect));
        }
        assert!(snapshot.total.p50 > Duration::ZERO);
        // Every session reported its three stage boundaries to the
        // shared stats observer.
        assert_eq!(snapshot.stage_sort.count, 3);
        assert!(snapshot.stage_sort.p50 > Duration::ZERO);
    }

    #[test]
    fn invalid_specs_are_rejected_not_enqueued() {
        let service = SortService::start(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let bad = JobSpec {
            elements: 0,
            ..spec(9, Distribution::Sorted, 1, 1)
        };
        match service.submit(bad) {
            Submission::Rejected {
                reason: RejectReason::Invalid { detail },
            } => assert!(detail.contains("elements")),
            other => panic!("expected Invalid rejection, got {other:?}"),
        }
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.rejected, 1);
        assert_eq!(snapshot.accepted, 0);
    }

    #[test]
    fn small_jobs_coalesce_behind_a_large_one() {
        // One worker, busy for a long while on a 2M-key job; the five
        // small jobs queued meanwhile must ride a coalesced batch.
        let service = SortService::start(ServiceConfig {
            workers: 1,
            batch_max_jobs: 8,
            small_job_threshold: 2_000,
            ..Default::default()
        });
        assert!(service.submit(spec(0, Distribution::Random, 2_000_000, 1)).is_accepted());
        for id in 1..=5 {
            assert!(service.submit(spec(id, Distribution::Random, 1_000, 1)).is_accepted());
        }
        let mut results = Vec::new();
        while results.len() < 6 {
            results.push(service.next_completion(Duration::from_secs(60)).expect("stalled"));
        }
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.completed, 6);
        assert!(
            snapshot.batched_jobs >= 2,
            "expected coalescing, got {} batched jobs",
            snapshot.batched_jobs
        );
        for r in results.iter().filter(|r| r.id > 0) {
            assert!(r.sorted_ok);
        }
    }

    #[test]
    fn pool_leases_topologies_through_the_shared_cache() {
        let service = SortService::start(ServiceConfig {
            workers: 3,
            ..Default::default()
        });
        for id in 0..9 {
            assert!(service.submit(spec(id, Distribution::Local, 6_000, 1)).is_accepted());
        }
        let mut seen = 0;
        while seen < 9 {
            service.next_completion(Duration::from_secs(30)).expect("stalled");
            seen += 1;
        }
        // All workers served d=1: one build, leases outstanding until
        // shutdown drops the workers.
        assert_eq!(service.plan_cache().builds(), 1);
        assert!(service.plan_cache().active_leases() >= 1);
        let shared = Arc::clone(&service.shared);
        service.shutdown();
        assert_eq!(shared.cache.active_leases(), 0, "leases returned on shutdown");
    }

    #[test]
    fn injected_panics_retry_to_checksum_identical_results() {
        // Half the (job, attempt) draws panic the worker.  Every ticket
        // must still resolve (retry within budget or explicit failure —
        // never a hang or a silent drop), and every job that completes,
        // retried or not, must equal an independent sequential sort.
        let service = SortService::start(ServiceConfig {
            workers: 2,
            retain_output: true,
            faults: FaultPlan {
                worker_panic_rate: 0.5,
                ..FaultPlan::none()
            },
            retry_budget: 6,
            ..Default::default()
        });
        let tickets: Vec<_> = (0..12)
            .map(|id| {
                service
                    .submit(spec(id, Distribution::Random, 5_000, 1))
                    .ticket()
                    .expect("accepted")
            })
            .collect();
        let results: Vec<JobResult> = tickets
            .iter()
            .map(|t| t.wait_timeout(Duration::from_secs(60)).expect("job dropped"))
            .collect();
        let (snapshot, _) = service.shutdown();
        let retried = results.iter().filter(|r| r.retries > 0).count();
        assert!(retried > 0, "rate 0.5 over 12 jobs should hit someone");
        let mut completed_after_retry = 0;
        for r in &results {
            if r.error.is_some() {
                continue; // explicit failure: budget exhausted, still no drop
            }
            assert!(r.sorted_ok, "job {} (retries {})", r.id, r.retries);
            let mut expect = spec(r.id, Distribution::Random, 5_000, 1).generate();
            quicksort(&mut expect);
            assert_eq!(r.checksum, fnv1a(&expect), "job {} checksum drifted", r.id);
            completed_after_retry += (r.retries > 0) as usize;
        }
        assert!(
            completed_after_retry > 0,
            "some retried job must complete with a verified checksum"
        );
        assert_eq!(snapshot.completed + snapshot.failed, 12);
        assert!(snapshot.worker_panics > 0);
        // Jobs never coalesce here (5000 > small_job_threshold), so
        // every caught panic ends in exactly one requeue or exhaustion.
        assert_eq!(
            snapshot.worker_panics,
            snapshot.retries + snapshot.retries_exhausted
        );
        assert_eq!(snapshot.degraded_jobs as usize, retried);
        assert!(snapshot.degraded_total.count > 0);
    }

    #[test]
    fn exhausted_retry_budget_fails_explicitly() {
        // Every draw panics and the budget is zero: each job must come
        // back once, immediately, as an explicit failure.
        let service = SortService::start(ServiceConfig {
            workers: 1,
            faults: FaultPlan {
                worker_panic_rate: 1.0,
                ..FaultPlan::none()
            },
            retry_budget: 0,
            ..Default::default()
        });
        let t = service
            .submit(spec(0, Distribution::Sorted, 1_000, 1))
            .ticket()
            .expect("accepted");
        let r = t.wait_timeout(Duration::from_secs(30)).expect("job dropped");
        assert!(!r.sorted_ok);
        let err = r.error.expect("explicit error");
        assert!(err.contains("retry budget 0 exhausted"), "{err}");
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.failed, 1);
        assert_eq!(snapshot.retries_exhausted, 1);
        assert_eq!(snapshot.retries, 0);
    }

    #[test]
    fn link_faults_degrade_but_jobs_still_verify() {
        // Seeded link failures are connectivity-preserving, so every
        // session routes around them and still completes — the jobs
        // must all verify despite a heavily degraded network.
        let service = SortService::start(ServiceConfig {
            workers: 2,
            retain_output: true,
            faults: FaultPlan {
                link_fail_permille: 300,
                ..FaultPlan::none()
            },
            ..Default::default()
        });
        let tickets: Vec<_> = (0..8)
            .map(|id| {
                service
                    .submit(spec(id, Distribution::Random, 3_000, 1))
                    .ticket()
                    .expect("accepted")
            })
            .collect();
        for t in &tickets {
            let r = t.wait_timeout(Duration::from_secs(60)).expect("job dropped");
            assert!(r.sorted_ok, "job {}: {:?}", r.id, r.error);
            let mut expect = spec(r.id, Distribution::Random, 3_000, 1).generate();
            quicksort(&mut expect);
            assert_eq!(r.checksum, fnv1a(&expect));
        }
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.completed, 8);
        assert_eq!(snapshot.failed, 0);
    }

    #[test]
    fn dead_processors_surface_stage_errors_and_fail_explicitly() {
        // A dead processor cannot run its bucket, so every attempt
        // fails the session pre-flight with a StageError; the budget
        // burns down and every job ends in an explicit error — never a
        // hang, never a silent drop.
        let service = SortService::start(ServiceConfig {
            workers: 2,
            faults: FaultPlan {
                node_failures: 2,
                ..FaultPlan::none()
            },
            retry_budget: 2,
            ..Default::default()
        });
        let tickets: Vec<_> = (0..6)
            .map(|id| {
                service
                    .submit(spec(id, Distribution::Random, 2_000, 1))
                    .ticket()
                    .expect("accepted")
            })
            .collect();
        for t in &tickets {
            let r = t.wait_timeout(Duration::from_secs(60)).expect("job dropped");
            assert!(!r.sorted_ok);
            let err = r.error.expect("explicit error");
            assert!(
                err.contains("node failed") && err.contains("exhausted"),
                "{err}"
            );
        }
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.failed, 6);
        assert_eq!(snapshot.completed, 0);
        assert!(snapshot.link_failures > 0, "StageErrors must be counted");
        assert!(snapshot.retries > 0, "attempts within budget must requeue");
        assert_eq!(snapshot.retries_exhausted, 6);
    }

    #[test]
    fn adaptive_strategy_flows_through_the_service() {
        // An anti-pivot job under the paper's fixed divide collapses
        // onto bucket 0; the same job submitted with the adaptive
        // strategy must re-divide once and come back balanced, with
        // both witnesses visible in the result and the snapshot.
        let service = SortService::start(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let adaptive = JobSpec {
            strategy: DivideStrategy::Adaptive,
            ..spec(0, Distribution::AntiPivot, 6_000, 1)
        };
        let t = service.submit(adaptive).ticket().expect("accepted");
        let r = t.wait_timeout(Duration::from_secs(30)).expect("stalled");
        assert!(r.sorted_ok, "{:?}", r.error);
        assert_eq!(r.skew_redivides, 1, "guardrail must fire on anti_pivot");
        assert!(r.imbalance <= 2.0, "re-divide must balance, got {}", r.imbalance);
        let (snapshot, _) = service.shutdown();
        assert_eq!(snapshot.skew_redivides, 1);
        assert!(snapshot.max_imbalance <= 2.0, "{}", snapshot.max_imbalance);
        assert!(snapshot.max_imbalance >= 1.0);
    }

    #[test]
    fn dropped_tickets_do_not_leak_results_or_slots() {
        let service = SortService::start(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        // Drop the tickets immediately: the workers still complete the
        // slots and the completion drain serves the results.
        for id in 0..4 {
            let submission = service.submit(spec(id, Distribution::Sorted, 3_000, 1));
            drop(submission.ticket().expect("accepted"));
        }
        let mut got = 0;
        while got < 4 {
            let r = service.next_completion(Duration::from_secs(30)).expect("stalled");
            assert!(r.sorted_ok);
            got += 1;
        }
        let (snapshot, rest) = service.shutdown();
        assert_eq!(snapshot.completed, 4);
        assert!(rest.is_empty(), "drain already served every slot");
    }
}
