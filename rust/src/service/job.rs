//! Job specifications and results — the service's tenant-facing types.

use std::time::Duration;

use crate::config::{Construction, Distribution, DivideStrategy};
use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::workload;

/// One sort job: what to sort (a seeded synthetic workload) and on which
/// topology, plus an optional per-job latency SLO.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// Caller-assigned id, echoed in the result.
    pub id: u64,
    /// Input distribution.
    pub distribution: Distribution,
    /// Keys to sort.
    pub elements: usize,
    /// Workload seed — `(distribution, elements, seed)` fully determines
    /// the input, so results are reproducible job by job.
    pub seed: u64,
    /// OHHC dimension of the topology the job runs on.
    pub dimension: u32,
    /// Construction rule.
    pub construction: Construction,
    /// How the divide picks bucket boundaries for this job (tenants
    /// sending hostile arrays opt into `sampling`/`adaptive`).
    pub strategy: DivideStrategy,
    /// Latency SLO: total (queue + sort) time budget, if any.
    pub deadline: Option<Duration>,
}

impl JobSpec {
    /// Sanity-check the spec before it enters the queue.
    pub fn validate(&self) -> Result<()> {
        if self.elements == 0 {
            return Err(Error::Config(format!("job {}: elements must be > 0", self.id)));
        }
        if !(1..=6).contains(&self.dimension) {
            return Err(Error::Config(format!(
                "job {}: dimension must be 1..=6, got {}",
                self.id, self.dimension
            )));
        }
        Ok(())
    }

    /// Generate the job's input keys (deterministic in the spec).
    pub fn generate(&self) -> Vec<i32> {
        workload::generate(self.distribution, self.elements, self.seed)
    }

    /// Parse a jobfile line:
    /// `distribution,elements,seed[,dimension[,deadline_ms[,strategy]]]`
    /// (whitespace around fields ignored).  `id` is assigned by the
    /// caller, typically the line number.  Distribution names resolve
    /// through [`workload::parse`] — the adversarial suite is accepted
    /// here too.
    pub fn parse_line(line: &str, id: u64) -> Result<JobSpec> {
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if !(3..=6).contains(&fields.len()) {
            return Err(Error::Config(format!(
                "job line needs `dist,elements,seed[,dimension[,deadline_ms[,strategy]]]`, \
                 got `{line}`"
            )));
        }
        let bad = |what: &str, v: &str| Error::Config(format!("job {id}: bad {what} `{v}`"));
        let spec = JobSpec {
            id,
            distribution: workload::parse(fields[0])?,
            elements: fields[1].parse().map_err(|_| bad("elements", fields[1]))?,
            seed: fields[2].parse().map_err(|_| bad("seed", fields[2]))?,
            dimension: match fields.get(3) {
                Some(v) => v.parse().map_err(|_| bad("dimension", v))?,
                None => 1,
            },
            construction: Construction::FullGroup,
            strategy: match fields.get(5) {
                Some(v) => DivideStrategy::parse(v)?,
                None => DivideStrategy::PaperFixed,
            },
            deadline: match fields.get(4) {
                Some(v) => Some(Duration::from_millis(
                    v.parse().map_err(|_| bad("deadline_ms", v))?,
                )),
                None => None,
            },
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// What the service hands back for one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// The spec's id.
    pub id: u64,
    /// Keys sorted.
    pub elements: usize,
    /// Topology dimension the job ran on.
    pub dimension: u32,
    /// Did the job execute as part of a coalesced batch?
    pub batched: bool,
    /// Time from accept to execution start.
    pub queue_latency: Duration,
    /// Time in the divide → sort → gather pipeline (a batched job
    /// reports its batch's pipeline time).
    pub sort_latency: Duration,
    /// Queue + sort.
    pub total_latency: Duration,
    /// The SLO the spec carried, if any.
    pub deadline: Option<Duration>,
    /// `total_latency <= deadline`, when a deadline was set.
    pub deadline_met: Option<bool>,
    /// Output verified sorted **and** a multiset-permutation of the
    /// input (checked on every job, never assumed).
    pub sorted_ok: bool,
    /// Order-sensitive FNV-1a checksum of the sorted output — the
    /// determinism witness loadgen compares across runs.
    pub checksum: u64,
    /// Divide load-imbalance factor the job's pipeline observed (a
    /// batched job reports its batch's figure) — the per-job witness
    /// that a strategy held the skew guardrail.
    pub imbalance: f64,
    /// Skew-guardrail re-divides the job's divide performed (0 unless
    /// the adaptive strategy fired).
    pub skew_redivides: u32,
    /// How many times the job was requeued after an injected fault
    /// before this result was produced (0 = clean first attempt).
    pub retries: u32,
    /// Execution error, if the pipeline failed.
    pub error: Option<String>,
    /// The sorted keys (only when the service retains outputs).
    pub output: Option<Vec<i32>>,
}

impl JobResult {
    /// The result as a JSON object (output keys omitted).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("batched", Json::Bool(self.batched)),
            ("checksum", Json::str(format!("{:016x}", self.checksum))),
            ("deadline_met", self.deadline_met.map_or(Json::Null, Json::Bool)),
            ("dimension", Json::int(self.dimension as usize)),
            ("elements", Json::int(self.elements)),
            ("error", self.error.as_deref().map_or(Json::Null, Json::str)),
            ("id", Json::int(self.id as usize)),
            ("imbalance", Json::num(self.imbalance)),
            ("queue_ns", Json::num(self.queue_latency.as_nanos() as f64)),
            ("retries", Json::int(self.retries as usize)),
            ("skew_redivides", Json::int(self.skew_redivides as usize)),
            ("sort_ns", Json::num(self.sort_latency.as_nanos() as f64)),
            ("sorted_ok", Json::Bool(self.sorted_ok)),
            ("total_ns", Json::num(self.total_latency.as_nanos() as f64)),
        ])
    }
}

/// Order-sensitive FNV-1a over a byte stream.
pub fn fnv1a_bytes(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Order-sensitive FNV-1a over the key bytes — equal exactly when two
/// runs produced byte-identical outputs in the same order.
pub fn fnv1a(keys: &[i32]) -> u64 {
    fnv1a_bytes(keys.iter().flat_map(|&k| (k as u32).to_le_bytes()))
}

/// Order-insensitive multiset fingerprint: sum of per-key SplitMix64
/// hashes.  Two arrays agree iff (up to astronomically unlikely
/// collisions) they hold the same keys with the same multiplicities —
/// the conservation half of the per-job verification, checkable without
/// a reference sort.
pub fn multiset_fingerprint(keys: &[i32]) -> u64 {
    let mut acc: u64 = keys.len() as u64;
    for &k in keys {
        let mut z = (k as u32 as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        acc = acc.wrapping_add(z ^ (z >> 31));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_line_full_and_minimal() {
        let j = JobSpec::parse_line("random, 10000, 42, 2, 250", 7).unwrap();
        assert_eq!(j.id, 7);
        assert_eq!(j.distribution, Distribution::Random);
        assert_eq!(j.elements, 10_000);
        assert_eq!(j.seed, 42);
        assert_eq!(j.dimension, 2);
        assert_eq!(j.deadline, Some(Duration::from_millis(250)));

        let j = JobSpec::parse_line("sorted,500,1", 0).unwrap();
        assert_eq!(j.dimension, 1);
        assert_eq!(j.deadline, None);
        assert_eq!(j.strategy, DivideStrategy::PaperFixed);
    }

    #[test]
    fn parse_line_accepts_strategy_and_adversarial_names() {
        let j = JobSpec::parse_line("anti_pivot, 10000, 3, 2, 250, adaptive", 9).unwrap();
        assert_eq!(j.distribution, Distribution::AntiPivot);
        assert_eq!(j.strategy, DivideStrategy::Adaptive);
        assert_eq!(j.deadline, Some(Duration::from_millis(250)));
        let j = JobSpec::parse_line("zipf,5000,1,1,10,sampling", 0).unwrap();
        assert_eq!(j.strategy, DivideStrategy::RegularSampling);
    }

    #[test]
    fn parse_line_rejects_malformed_input() {
        for bad in [
            "random,10000",             // too few fields
            "random,10000,1,2,5,9",     // sixth field is not a strategy
            "random,10000,1,2,5,pap,x", // too many fields
            "nosuch,10000,1",           // unknown distribution
            "random,zero,1",            // non-numeric elements
            "random,0,1",               // empty job
            "random,100,1,9",           // dimension out of range
        ] {
            assert!(JobSpec::parse_line(bad, 0).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn generate_is_deterministic_in_the_spec() {
        let spec = JobSpec::parse_line("reverse,2000,99", 1).unwrap();
        assert_eq!(spec.generate(), spec.generate());
        let other = JobSpec {
            seed: 100,
            ..spec.clone()
        };
        assert_ne!(spec.generate(), other.generate());
    }

    #[test]
    fn checksums_distinguish_order_and_content() {
        let a = [3, 1, 2];
        let b = [1, 2, 3];
        let c = [1, 2, 4];
        assert_ne!(fnv1a(&a), fnv1a(&b), "fnv is order-sensitive");
        assert_eq!(
            multiset_fingerprint(&a),
            multiset_fingerprint(&b),
            "multiset fingerprint is order-insensitive"
        );
        assert_ne!(multiset_fingerprint(&b), multiset_fingerprint(&c));
        assert_ne!(
            multiset_fingerprint(&[1, 1, 2]),
            multiset_fingerprint(&[1, 2, 2]),
            "multiplicities count"
        );
    }

    #[test]
    fn result_json_carries_the_slo_fields() {
        let r = JobResult {
            id: 3,
            elements: 100,
            dimension: 1,
            batched: true,
            queue_latency: Duration::from_micros(50),
            sort_latency: Duration::from_micros(450),
            total_latency: Duration::from_micros(500),
            deadline: Some(Duration::from_millis(1)),
            deadline_met: Some(true),
            sorted_ok: true,
            checksum: 0xabcd,
            imbalance: 1.25,
            skew_redivides: 1,
            retries: 1,
            error: None,
            output: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("retries").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("deadline_met").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("sorted_ok").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("total_ns").unwrap().as_f64(), Some(500_000.0));
        assert_eq!(j.get("imbalance").unwrap().as_f64(), Some(1.25));
        assert_eq!(j.get("skew_redivides").unwrap().as_usize(), Some(1));
    }
}
