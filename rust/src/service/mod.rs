//! The multi-tenant OHHC sort service: online serving on top of the
//! offline pipeline.
//!
//! The paper evaluates one sort at a time; the campaign engine runs an
//! offline grid.  This module opens the **online** workload — many
//! concurrent, heterogeneous sort jobs multiplexed over a pool of
//! prebuilt OHHC topologies — following the observation of Fasha's
//! comparative Quick Sort study (arXiv:2109.01719) that the interesting
//! behavior emerges under mixed execution modes and workloads:
//!
//! * [`job`] — [`JobSpec`] (per-job distribution / size / seed /
//!   topology / deadline) and the verified [`JobResult`];
//! * [`queue`] — bounded MPMC submission queue with explicit
//!   backpressure ([`Submit::Accepted`] / [`Submit::Rejected`], never
//!   unbounded buffering);
//! * [`admission`] — token-bucket rate limiting plus queue-depth
//!   shedding, decided before a job touches the queue;
//! * [`faults`] — the seeded [`FaultPlan`] chaos dial: deterministic
//!   per-(job, attempt) worker panics and network fault sets; the pool
//!   catches the fallout and requeues within a bounded retry budget,
//!   so every accepted job still completes or fails **explicitly**;
//! * [`ticket`] — the per-job front door: [`Submission`] /
//!   [`JobTicket`] completion handles (see the lifecycle below);
//! * [`pool`] — the [`SortService`] worker pool; each worker leases
//!   [`TopologyBundle`]s from a shared campaign
//!   [`PlanCache`](crate::campaign::PlanCache) and drives a typestate
//!   [`Session`](crate::pipeline::Session) stage by stage per job (or
//!   per coalesced batch);
//! * [`batcher`] — coalesces small jobs, tightest deadline first, into
//!   one arena-backed multi-span divide and splits results back per
//!   job on the offset table;
//! * [`stats`] — per-job queue/sort/total latency plus per-stage
//!   session times (the stats are a pipeline
//!   [`Observer`](crate::pipeline::Observer)) in shared fixed-bucket
//!   histograms with p50/p95/p99;
//! * [`loadgen`] — deterministic seeded open/closed-loop generators
//!   and the throughput/latency [`LoadReport`].
//!
//! # Ticket lifecycle
//!
//! [`SortService::submit`] validates and admission-checks the job, then
//! returns a [`Submission`]: `Rejected { reason }` (nothing was
//! enqueued), or `Accepted { depth, ticket }` where the [`JobTicket`]
//! is the tenant's private handle to that one job:
//!
//! ```text
//!   submit ─► Queued ──worker claims──► Running ──► Done ──take──► Taken
//!                │
//!                └──ticket.try_cancel()──► Cancelled   (no result, ever)
//! ```
//!
//! * [`JobTicket::poll`] — non-blocking status;
//! * [`JobTicket::wait_timeout`] / [`JobTicket::try_result`] — take
//!   the result, exactly once; waiting after completion returns
//!   immediately;
//! * [`JobTicket::try_cancel`] — succeeds at most once, and only
//!   before a worker claims the job (claim and cancel race; the
//!   winner decides);
//! * a **dropped** ticket leaks nothing: the worker still completes
//!   the job's slot and [`SortService::next_completion`] hands the
//!   result to whoever drains completions.
//!
//! Served by the `serve` and `loadgen` CLI subcommands.  The
//! [`crate::cluster`] layer is the first scaling layer built on this
//! seam: it fronts N independent `SortService` shards with a
//! deterministic router and forwards the same per-job tickets.
//!
//! [`TopologyBundle`]: crate::schedule::TopologyBundle

pub mod admission;
pub mod batcher;
pub mod faults;
pub mod job;
pub mod loadgen;
pub mod pool;
pub mod queue;
pub mod stats;
pub mod ticket;

pub use admission::{AdmissionControl, TokenBucket};
pub use batcher::{allot_buckets, coalesce, order_by_deadline, CoalescedBatch};
pub use faults::FaultPlan;
pub use job::{fnv1a, fnv1a_bytes, multiset_fingerprint, JobResult, JobSpec};
pub use loadgen::{schedule, JobSink, LoadGenConfig, LoadMode, LoadReport};
pub use pool::{ServiceConfig, SortService};
pub use queue::{JobQueue, RejectReason, Submit};
pub use stats::{LatencySummary, ServiceSnapshot, ServiceStats};
pub use ticket::{JobTicket, Submission, TicketStatus};
