//! The multi-tenant OHHC sort service: online serving on top of the
//! offline pipeline.
//!
//! The paper evaluates one sort at a time; the campaign engine runs an
//! offline grid.  This module opens the **online** workload — many
//! concurrent, heterogeneous sort jobs multiplexed over a pool of
//! prebuilt OHHC topologies — following the observation of Fasha's
//! comparative Quick Sort study (arXiv:2109.01719) that the interesting
//! behavior emerges under mixed execution modes and workloads:
//!
//! * [`job`] — [`JobSpec`] (per-job distribution / size / seed /
//!   topology / deadline) and the verified [`JobResult`];
//! * [`queue`] — bounded MPMC submission queue with explicit
//!   backpressure ([`Submit::Accepted`] / [`Submit::Rejected`], never
//!   unbounded buffering);
//! * [`admission`] — token-bucket rate limiting plus queue-depth
//!   shedding, decided before a job touches the queue;
//! * [`pool`] — the [`SortService`] worker pool; each worker leases
//!   [`TopologyBundle`]s from a shared campaign
//!   [`PlanCache`](crate::campaign::PlanCache) and drives
//!   `divide_native` → `FlatBuckets` → `ThreadedSimulator` end to end;
//! * [`batcher`] — coalesces small jobs into one arena-backed divide
//!   and splits results back per job on the offset table;
//! * [`stats`] — per-job queue/sort/total latency into shared
//!   fixed-bucket histograms with p50/p95/p99;
//! * [`loadgen`] — deterministic seeded open-/closed-loop generators
//!   and the throughput/latency [`LoadReport`].
//!
//! Served by the `serve` and `loadgen` CLI subcommands; every future
//! scaling layer (sharding, async backends, multi-cell placement) plugs
//! into this seam.
//!
//! [`TopologyBundle`]: crate::schedule::TopologyBundle

pub mod admission;
pub mod batcher;
pub mod job;
pub mod loadgen;
pub mod pool;
pub mod queue;
pub mod stats;

pub use admission::{AdmissionControl, TokenBucket};
pub use batcher::{allot_buckets, coalesce, CoalescedBatch};
pub use job::{fnv1a, fnv1a_bytes, multiset_fingerprint, JobResult, JobSpec};
pub use loadgen::{schedule, LoadGenConfig, LoadMode, LoadReport};
pub use pool::{ServiceConfig, SortService};
pub use queue::{JobQueue, RejectReason, Submit};
pub use stats::{LatencySummary, ServiceSnapshot, ServiceStats};
