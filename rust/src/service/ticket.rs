//! Per-job tickets: the service's tenant-facing completion handles.
//!
//! Every accepted submission gets its own [`JobTicket`] backed by a
//! private completion slot — no shared result channel, so tenants
//! never see (or steal) each other's results.  The slot walks a small
//! state machine:
//!
//! ```text
//! Queued --claim (worker)--> Running --complete--> Done --take--> Taken
//!    \
//!     +--try_cancel (tenant)--> Cancelled        (claim loses the race)
//! ```
//!
//! * [`JobTicket::poll`] reads the state without consuming anything;
//! * [`JobTicket::wait_timeout`] blocks until the result is ready and
//!   takes it (exactly once — later calls return `None`);
//! * [`JobTicket::try_cancel`] succeeds only while the job is still
//!   queued (a worker that already claimed it wins the race), and
//!   succeeds at most once;
//! * dropping a ticket leaks nothing: the worker still completes the
//!   slot, and the service's completion drain
//!   ([`SortService::next_completion`](crate::service::SortService::next_completion))
//!   can hand the result to whoever is draining.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::service::job::JobResult;
use crate::service::queue::RejectReason;

/// Where a submitted job currently is, as seen through its ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketStatus {
    /// Accepted, waiting in the queue; still cancellable.
    Queued,
    /// A worker claimed it; it will produce exactly one result.
    Running,
    /// The result is ready and unconsumed.
    Done,
    /// The result was consumed (by this ticket or a completion drain).
    Taken,
    /// Cancelled before any worker claimed it; no result will exist.
    Cancelled,
}

#[derive(Debug)]
enum SlotState {
    Queued,
    Claimed,
    Done(Box<JobResult>),
    Taken,
    Cancelled,
}

/// One job's completion slot, shared by its ticket, the worker that
/// executes it, and the service's completion drain.
#[derive(Debug)]
pub(crate) struct Slot {
    id: u64,
    state: Mutex<SlotState>,
    ready: Condvar,
}

impl Slot {
    pub(crate) fn new(id: u64) -> Arc<Slot> {
        Arc::new(Slot {
            id,
            state: Mutex::new(SlotState::Queued),
            ready: Condvar::new(),
        })
    }

    /// Worker-side: claim the job for execution.  Returns `false` when
    /// the tenant cancelled first — the worker must skip the job.
    pub(crate) fn claim(&self) -> bool {
        // The claim side of the cancel-vs-claim race.
        crate::interleave!("ticket/claim");
        let mut st = self.state.lock().unwrap();
        match *st {
            SlotState::Queued => {
                *st = SlotState::Claimed;
                true
            }
            SlotState::Cancelled => false,
            ref other => unreachable!("claim on a {other:?} slot"),
        }
    }

    /// Worker-side: hand a claimed job back to the queue (the pool's
    /// fault-retry path).  The tenant's view returns to `Queued`; a
    /// later [`Self::claim`] picks the job up again.  Cancellation
    /// stays live: a requeued job can still lose the claim race to
    /// [`JobTicket::try_cancel`].
    pub(crate) fn requeue(&self) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(matches!(*st, SlotState::Claimed), "requeue on {st:?}");
        *st = SlotState::Queued;
    }

    /// Worker-side: publish the result and wake every waiter.
    pub(crate) fn complete(&self, result: JobResult) {
        // Publication racing a ticket wait / completion drain.
        crate::interleave!("ticket/complete");
        let mut st = self.state.lock().unwrap();
        debug_assert!(matches!(*st, SlotState::Claimed), "complete on {st:?}");
        *st = SlotState::Done(Box::new(result));
        drop(st);
        self.ready.notify_all();
    }

    /// Take the result out, exactly once.
    pub(crate) fn take(&self) -> Option<JobResult> {
        Self::take_locked(&mut self.state.lock().unwrap())
    }

    /// The Done → Taken transition under an already-held lock — shared
    /// by [`Self::take`] and the ticket's wait loop.
    fn take_locked(st: &mut SlotState) -> Option<JobResult> {
        if matches!(*st, SlotState::Done(_)) {
            match std::mem::replace(st, SlotState::Taken) {
                SlotState::Done(r) => Some(*r),
                _ => unreachable!(),
            }
        } else {
            None
        }
    }

    /// Is the result already consumed?  The completion drain compacts
    /// taken slots away instead of holding them until shutdown.
    pub(crate) fn is_taken(&self) -> bool {
        matches!(*self.state.lock().unwrap(), SlotState::Taken)
    }

    /// Did the tenant cancel the job?  The cluster's failover
    /// supervisor checks this on its outer slots: a cancelled slot
    /// means no result can ever be delivered, so the shard-side work
    /// is cancelled (or its result discarded) instead of failed over.
    pub(crate) fn is_cancelled(&self) -> bool {
        matches!(*self.state.lock().unwrap(), SlotState::Cancelled)
    }

    fn status(&self) -> TicketStatus {
        match *self.state.lock().unwrap() {
            SlotState::Queued => TicketStatus::Queued,
            SlotState::Claimed => TicketStatus::Running,
            SlotState::Done(_) => TicketStatus::Done,
            SlotState::Taken => TicketStatus::Taken,
            SlotState::Cancelled => TicketStatus::Cancelled,
        }
    }
}

/// The tenant's handle to one accepted job.
#[derive(Debug)]
pub struct JobTicket {
    slot: Arc<Slot>,
}

impl JobTicket {
    pub(crate) fn new(slot: Arc<Slot>) -> Self {
        JobTicket { slot }
    }

    /// The job id this ticket tracks.
    pub fn id(&self) -> u64 {
        self.slot.id
    }

    /// Non-blocking status read.
    pub fn poll(&self) -> TicketStatus {
        self.slot.status()
    }

    /// Non-blocking result take: `Some` exactly once, after the job
    /// completed.
    pub fn try_result(&self) -> Option<JobResult> {
        self.slot.take()
    }

    /// Block until the result is ready (or `timeout` passes), then
    /// take it.  Returns `None` on timeout, after the result was
    /// already taken, or for a cancelled job.  Waiting *after*
    /// completion returns immediately — the slot holds the result
    /// until someone takes it.  A `timeout` too large to represent as
    /// a deadline (e.g. `Duration::MAX`) waits indefinitely.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<JobResult> {
        let deadline = Instant::now().checked_add(timeout);
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match *st {
                SlotState::Done(_) => return Slot::take_locked(&mut *st),
                SlotState::Taken | SlotState::Cancelled => return None,
                SlotState::Queued | SlotState::Claimed => {}
            }
            st = match deadline {
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    self.slot.ready.wait_timeout(st, deadline - now).unwrap().0
                }
                None => self.slot.ready.wait(st).unwrap(),
            };
        }
    }

    /// Cancel the job if no worker has claimed it yet.  Returns `true`
    /// exactly once, on the call that actually cancelled; `false` when
    /// the job is already running, finished, or was cancelled before.
    pub fn try_cancel(&self) -> bool {
        // The cancel side of the cancel-vs-claim race.
        crate::interleave!("ticket/cancel");
        let mut st = self.slot.state.lock().unwrap();
        if matches!(*st, SlotState::Queued) {
            *st = SlotState::Cancelled;
            drop(st);
            self.slot.ready.notify_all();
            true
        } else {
            false
        }
    }
}

/// Outcome of one [`SortService::submit`](crate::service::SortService::submit):
/// either a live [`JobTicket`] or an explicit rejection the caller can
/// act on.
#[derive(Debug)]
pub enum Submission {
    /// Enqueued; `depth` is the queue depth right after the push and
    /// `ticket` is the per-job completion handle.
    Accepted {
        /// Queue depth including this job.
        depth: usize,
        /// The job's completion handle.
        ticket: JobTicket,
    },
    /// Turned away — the job was **not** enqueued and no ticket exists.
    Rejected {
        /// Why.
        reason: RejectReason,
    },
}

impl Submission {
    /// Did the job make it into the queue?
    pub fn is_accepted(&self) -> bool {
        matches!(self, Submission::Accepted { .. })
    }

    /// The ticket, consuming the submission (`None` when rejected).
    pub fn ticket(self) -> Option<JobTicket> {
        match self {
            Submission::Accepted { ticket, .. } => Some(ticket),
            Submission::Rejected { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(id: u64) -> JobResult {
        JobResult {
            id,
            elements: 1,
            dimension: 1,
            batched: false,
            queue_latency: Duration::ZERO,
            sort_latency: Duration::ZERO,
            total_latency: Duration::ZERO,
            deadline: None,
            deadline_met: None,
            sorted_ok: true,
            checksum: 0,
            imbalance: 0.0,
            skew_redivides: 0,
            retries: 0,
            error: None,
            output: None,
        }
    }

    #[test]
    fn requeue_returns_a_claimed_slot_to_the_queue() {
        let slot = Slot::new(9);
        let ticket = JobTicket::new(Arc::clone(&slot));
        assert!(slot.claim());
        slot.requeue();
        assert_eq!(ticket.poll(), TicketStatus::Queued);
        // The retry claim works, and cancellation still wins a race
        // against it when it gets there first.
        assert!(slot.claim());
        slot.requeue();
        assert!(ticket.try_cancel(), "requeued jobs are cancellable again");
        assert!(!slot.claim(), "the worker skips the cancelled retry");
    }

    #[test]
    fn slot_walks_queued_claimed_done_taken() {
        let slot = Slot::new(7);
        let ticket = JobTicket::new(Arc::clone(&slot));
        assert_eq!(ticket.poll(), TicketStatus::Queued);
        assert!(slot.claim());
        assert_eq!(ticket.poll(), TicketStatus::Running);
        assert!(ticket.try_result().is_none(), "no result before complete");
        slot.complete(result(7));
        assert_eq!(ticket.poll(), TicketStatus::Done);
        // Waiting after completion returns immediately, exactly once.
        let r = ticket.wait_timeout(Duration::ZERO).expect("result ready");
        assert_eq!(r.id, 7);
        assert_eq!(ticket.poll(), TicketStatus::Taken);
        assert!(ticket.wait_timeout(Duration::ZERO).is_none());
        assert!(ticket.try_result().is_none());
    }

    #[test]
    fn cancel_before_claim_wins_exactly_once() {
        let slot = Slot::new(1);
        let ticket = JobTicket::new(Arc::clone(&slot));
        assert!(ticket.try_cancel(), "first cancel succeeds");
        assert!(!ticket.try_cancel(), "second cancel is a no-op");
        assert_eq!(ticket.poll(), TicketStatus::Cancelled);
        assert!(!slot.claim(), "the worker must skip a cancelled job");
        assert!(ticket.wait_timeout(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn cancel_after_claim_loses_the_race() {
        let slot = Slot::new(2);
        let ticket = JobTicket::new(Arc::clone(&slot));
        assert!(slot.claim());
        assert!(!ticket.try_cancel(), "claimed jobs cannot be cancelled");
        slot.complete(result(2));
        assert_eq!(ticket.wait_timeout(Duration::ZERO).unwrap().id, 2);
    }

    /// Exhaustive model test of the cancel-vs-claim race: run the
    /// *real* slot machine through every merge order of the tenant's
    /// ops `[try_cancel, try_cancel]` and the worker's ops
    /// `[claim, complete-if-claimed]` — all C(4,2) = 6 schedules — and
    /// assert the race has exactly one winner in each.
    #[test]
    fn every_cancel_claim_interleaving_has_exactly_one_winner() {
        let schedules = crate::runtime::check::interleavings(2, 2);
        assert_eq!(schedules.len(), 6);
        for schedule in &schedules {
            let slot = Slot::new(11);
            let ticket = JobTicket::new(Arc::clone(&slot));
            // `true` = next tenant op, `false` = next worker op.
            let cancel_first = *schedule.first().unwrap();
            let mut cancel_wins = 0usize;
            let mut claimed = false;
            let mut tenant_op = 0usize;
            let mut worker_op = 0usize;
            for &is_tenant in schedule {
                if is_tenant {
                    if ticket.try_cancel() {
                        cancel_wins += 1;
                    }
                    tenant_op += 1;
                } else {
                    match worker_op {
                        0 => claimed = slot.claim(),
                        1 => {
                            // The worker only publishes what it claimed;
                            // a lost claim means it skipped the job.
                            if claimed {
                                slot.complete(result(11));
                            }
                        }
                        _ => unreachable!(),
                    }
                    worker_op += 1;
                }
            }
            assert_eq!(tenant_op, 2);
            assert_eq!(worker_op, 2);
            // In every schedule the first tenant op and the first worker
            // op race; whichever ran first wins, and wins exactly once.
            if cancel_first {
                assert_eq!(cancel_wins, 1, "cancel-before-claim must win once: {schedule:?}");
                assert!(!claimed, "a cancelled job must not be claimable: {schedule:?}");
                assert_eq!(ticket.poll(), TicketStatus::Cancelled);
                assert!(ticket.try_result().is_none());
            } else {
                assert_eq!(cancel_wins, 0, "claim-before-cancel must block it: {schedule:?}");
                assert!(claimed, "an uncancelled job must claim: {schedule:?}");
                assert_eq!(ticket.poll(), TicketStatus::Done);
                assert_eq!(ticket.try_result().expect("result published").id, 11);
            }
        }
    }

    #[test]
    fn wait_blocks_until_completion_from_another_thread() {
        let slot = Slot::new(3);
        let ticket = JobTicket::new(Arc::clone(&slot));
        assert!(slot.claim());
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| ticket.wait_timeout(Duration::from_secs(10)));
            std::thread::sleep(Duration::from_millis(20));
            slot.complete(result(3));
            let got = waiter.join().unwrap().expect("completion must wake waiter");
            assert_eq!(got.id, 3);
        });
    }

    /// Slot-level schedule fuzzing (the slot type is crate-private, so
    /// these live here rather than in `tests/schedules.rs`): the real
    /// two-thread races, perturbed per seed through the interleave
    /// points in `claim` / `try_cancel` / `complete`.
    #[cfg(feature = "schedules")]
    mod fuzzed {
        use super::*;
        use crate::runtime::check;
        use std::sync::atomic::{AtomicUsize, Ordering};

        #[test]
        fn cancel_vs_claim_has_one_winner_under_every_seed() {
            for seed in 0..64u64 {
                check::fuzz(seed, || {
                    let slot = Slot::new(seed);
                    let ticket = JobTicket::new(Arc::clone(&slot));
                    let cancel_wins = AtomicUsize::new(0);
                    std::thread::scope(|scope| {
                        scope.spawn(|| {
                            if ticket.try_cancel() {
                                cancel_wins.fetch_add(1, Ordering::SeqCst);
                            }
                        });
                        scope.spawn(|| {
                            if slot.claim() {
                                slot.complete(result(seed));
                            }
                        });
                    });
                    let wins = cancel_wins.load(Ordering::SeqCst);
                    match ticket.poll() {
                        TicketStatus::Cancelled => {
                            assert_eq!(wins, 1, "seed {seed}: cancelled without a cancel win");
                            assert!(ticket.try_result().is_none(), "seed {seed}: ghost result");
                        }
                        TicketStatus::Done => {
                            assert_eq!(wins, 0, "seed {seed}: done despite a cancel win");
                            assert!(ticket.try_result().is_some(), "seed {seed}: result lost");
                        }
                        other => panic!("seed {seed}: non-terminal state {other:?}"),
                    }
                });
            }
        }

        #[test]
        fn completion_wakeup_never_lost_under_any_seed() {
            for seed in 0..64u64 {
                check::fuzz(seed, || {
                    let slot = Slot::new(seed);
                    let ticket = JobTicket::new(Arc::clone(&slot));
                    assert!(slot.claim(), "seed {seed}: fresh claim failed");
                    std::thread::scope(|scope| {
                        let waiter = scope.spawn(|| ticket.wait_timeout(Duration::from_secs(30)));
                        scope.spawn(|| slot.complete(result(seed)));
                        let got = waiter.join().expect("waiter panicked");
                        assert!(got.is_some(), "seed {seed}: completion wakeup lost");
                    });
                });
            }
        }
    }
}
