//! Service observability: counters plus shared latency histograms.
//!
//! Workers record every finished job into one [`ServiceStats`]; a
//! [`ServiceSnapshot`] freezes the counters and the p50/p95/p99 of the
//! queue / sort / total latency distributions for reports and SLO
//! checks.  Histograms are the fixed-bucket [`Histogram`] from
//! [`crate::metrics`], so snapshots are cheap and worker merges are
//! element-wise adds.
//!
//! The stats are also a pipeline [`Observer`]: every worker installs
//! the shared instance on its [`Session`](crate::pipeline::Session),
//! so per-stage (divide / local-sort / gather) wall times stream into
//! their own histograms at stage boundaries instead of being inlined
//! into the worker's timing code.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::metrics::Histogram;
use crate::pipeline::{Observer, Stage, StageTrace};
use crate::service::job::JobResult;
use crate::util::json::Json;

/// Live counters + histograms, shared by every worker and submitter.
#[derive(Debug, Default)]
pub struct ServiceStats {
    submitted: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    deadline_missed: AtomicU64,
    batches: AtomicU64,
    batched_jobs: AtomicU64,
    worker_panics: AtomicU64,
    link_failures: AtomicU64,
    retries: AtomicU64,
    retries_exhausted: AtomicU64,
    degraded_jobs: AtomicU64,
    skew_redivides: AtomicU64,
    // Imbalance is recorded in milli-units (×1000) so the integer
    // nanosecond histogram doubles as a ratio histogram.
    imbalance_milli: Mutex<Histogram>,
    queue_ns: Mutex<Histogram>,
    sort_ns: Mutex<Histogram>,
    total_ns: Mutex<Histogram>,
    stage_divide_ns: Mutex<Histogram>,
    stage_sort_ns: Mutex<Histogram>,
    stage_gather_ns: Mutex<Histogram>,
    degraded_total_ns: Mutex<Histogram>,
}

impl ServiceStats {
    /// Fresh stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one submission **attempt** — a caller that retries a
    /// rejected job counts once per attempt, so `submitted`/`rejected`
    /// measure offered load at the front door, not distinct jobs.
    pub fn on_submit(&self, accepted: bool) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        if accepted {
            self.accepted.fetch_add(1, Ordering::Relaxed);
        } else {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one executed batch of `jobs` coalesced jobs.
    pub fn on_batch(&self, jobs: usize) {
        if jobs > 1 {
            self.batches.fetch_add(1, Ordering::Relaxed);
            self.batched_jobs.fetch_add(jobs as u64, Ordering::Relaxed);
        }
    }

    /// Record one finished job.
    pub fn on_result(&self, r: &JobResult) {
        if r.error.is_some() || !r.sorted_ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.completed.fetch_add(1, Ordering::Relaxed);
        }
        if r.deadline_met == Some(false) {
            self.deadline_missed.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_ns.lock().unwrap().record_duration(r.queue_latency);
        self.sort_ns.lock().unwrap().record_duration(r.sort_latency);
        self.total_ns.lock().unwrap().record_duration(r.total_latency);
        self.imbalance_milli.lock().unwrap().record((r.imbalance * 1000.0) as u64);
        if r.skew_redivides > 0 {
            self.skew_redivides.fetch_add(r.skew_redivides as u64, Ordering::Relaxed);
        }
        if r.retries > 0 {
            // The job survived at least one injected fault — track its
            // latency separately so degraded-mode SLOs are visible.
            self.degraded_jobs.fetch_add(1, Ordering::Relaxed);
            self.degraded_total_ns.lock().unwrap().record_duration(r.total_latency);
        }
    }

    /// Record one worker panic caught by the pool (injected or real).
    pub fn on_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one batch lost to a network fault
    /// ([`StageError`](crate::error::StageError) from the session).
    pub fn on_link_failure(&self) {
        self.link_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one job requeued for another attempt.
    pub fn on_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one job that burned its whole retry budget and failed.
    pub fn on_retry_exhausted(&self) {
        self.retries_exhausted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one job cancelled before any worker claimed it (the job
    /// produced no result; it is neither completed nor failed).
    pub fn on_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Jobs accepted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Jobs rejected so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Jobs completed (verified) so far.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Jobs failed explicitly so far.  The cluster's health board
    /// polls this between scans: the *delta* since the last poll is
    /// the failure signal feeding each shard's breaker, covering
    /// failures the cluster supervisor never observes first-hand.
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// Jobs that coalesced into multi-job batches so far.
    pub fn batched_jobs(&self) -> u64 {
        self.batched_jobs.load(Ordering::Relaxed)
    }

    /// Jobs requeued after an injected fault so far.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Fold `other` into `self`: counters add, histograms merge
    /// element-wise.  This is how the cluster layer rolls N shards up
    /// into one service view — percentiles are computed *after* the
    /// histogram merge (never averaged across shards), and nothing is
    /// lost: `degraded_jobs`, `skew_redivides`, and the imbalance
    /// histogram (hence `max_imbalance`) all carry over.
    pub fn merge(&self, other: &ServiceStats) {
        for (mine, theirs) in [
            (&self.submitted, &other.submitted),
            (&self.accepted, &other.accepted),
            (&self.rejected, &other.rejected),
            (&self.completed, &other.completed),
            (&self.failed, &other.failed),
            (&self.cancelled, &other.cancelled),
            (&self.deadline_missed, &other.deadline_missed),
            (&self.batches, &other.batches),
            (&self.batched_jobs, &other.batched_jobs),
            (&self.worker_panics, &other.worker_panics),
            (&self.link_failures, &other.link_failures),
            (&self.retries, &other.retries),
            (&self.retries_exhausted, &other.retries_exhausted),
            (&self.degraded_jobs, &other.degraded_jobs),
            (&self.skew_redivides, &other.skew_redivides),
        ] {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        for (mine, theirs) in [
            (&self.imbalance_milli, &other.imbalance_milli),
            (&self.queue_ns, &other.queue_ns),
            (&self.sort_ns, &other.sort_ns),
            (&self.total_ns, &other.total_ns),
            (&self.stage_divide_ns, &other.stage_divide_ns),
            (&self.stage_sort_ns, &other.stage_sort_ns),
            (&self.stage_gather_ns, &other.stage_gather_ns),
            (&self.degraded_total_ns, &other.degraded_total_ns),
        ] {
            mine.lock().unwrap().merge(&theirs.lock().unwrap());
        }
    }

    /// Freeze everything into a snapshot.
    pub fn snapshot(&self) -> ServiceSnapshot {
        ServiceSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_jobs: self.batched_jobs.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            link_failures: self.link_failures.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            retries_exhausted: self.retries_exhausted.load(Ordering::Relaxed),
            degraded_jobs: self.degraded_jobs.load(Ordering::Relaxed),
            skew_redivides: self.skew_redivides.load(Ordering::Relaxed),
            max_imbalance: self.imbalance_milli.lock().unwrap().max() as f64 / 1000.0,
            queue: LatencySummary::of(&self.queue_ns.lock().unwrap()),
            sort: LatencySummary::of(&self.sort_ns.lock().unwrap()),
            total: LatencySummary::of(&self.total_ns.lock().unwrap()),
            stage_divide: LatencySummary::of(&self.stage_divide_ns.lock().unwrap()),
            stage_sort: LatencySummary::of(&self.stage_sort_ns.lock().unwrap()),
            stage_gather: LatencySummary::of(&self.stage_gather_ns.lock().unwrap()),
            degraded_total: LatencySummary::of(&self.degraded_total_ns.lock().unwrap()),
        }
    }
}

impl Observer for ServiceStats {
    /// Stage boundaries stream straight into the per-stage histograms —
    /// one sample per session stage, batch or single alike.
    fn on_stage(&self, stage: Stage, elapsed: Duration, _trace: &StageTrace) {
        let hist = match stage {
            Stage::Divide => &self.stage_divide_ns,
            Stage::LocalSort => &self.stage_sort_ns,
            Stage::Gather => &self.stage_gather_ns,
        };
        hist.lock().unwrap().record_duration(elapsed);
    }
}

/// p50/p95/p99/max of one latency distribution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencySummary {
    /// Samples.
    pub count: u64,
    /// Median.
    pub p50: Duration,
    /// 95th percentile.
    pub p95: Duration,
    /// 99th percentile.
    pub p99: Duration,
    /// Worst observed.
    pub max: Duration,
}

impl LatencySummary {
    /// Summarize a histogram of nanosecond samples.
    pub fn of(h: &Histogram) -> Self {
        LatencySummary {
            count: h.count(),
            p50: h.percentile_duration(0.50),
            p95: h.percentile_duration(0.95),
            p99: h.percentile_duration(0.99),
            max: Duration::from_nanos(h.max()),
        }
    }

    /// As a JSON object (ns).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("count", Json::int(self.count as usize)),
            ("max_ns", Json::num(self.max.as_nanos() as f64)),
            ("p50_ns", Json::num(self.p50.as_nanos() as f64)),
            ("p95_ns", Json::num(self.p95.as_nanos() as f64)),
            ("p99_ns", Json::num(self.p99.as_nanos() as f64)),
        ])
    }
}

/// Frozen counters + latency summaries.
#[derive(Debug, Clone)]
pub struct ServiceSnapshot {
    /// Submission attempts.
    pub submitted: u64,
    /// Accepted into the queue.
    pub accepted: u64,
    /// Rejected at the front door (queue full, rate, shed, closed).
    pub rejected: u64,
    /// Finished and verified.
    pub completed: u64,
    /// Finished with a pipeline error or failed verification.
    pub failed: u64,
    /// Cancelled through their ticket before a worker claimed them.
    pub cancelled: u64,
    /// Jobs whose deadline was set and missed.
    pub deadline_missed: u64,
    /// Multi-job batches executed.
    pub batches: u64,
    /// Jobs that rode those batches.
    pub batched_jobs: u64,
    /// Worker panics caught by the pool.
    pub worker_panics: u64,
    /// Batches lost to a network fault (link/node failure).
    pub link_failures: u64,
    /// Jobs requeued for another attempt.
    pub retries: u64,
    /// Jobs that burned the whole retry budget and failed.
    pub retries_exhausted: u64,
    /// Jobs that completed only after at least one retry.
    pub degraded_jobs: u64,
    /// Skew-guardrail re-divides across all jobs (adaptive strategy).
    pub skew_redivides: u64,
    /// Worst divide load-imbalance factor any job observed (0.0 before
    /// the first result) — the service-level skew-guardrail witness.
    pub max_imbalance: f64,
    /// Queue-latency summary.
    pub queue: LatencySummary,
    /// Sort-latency summary.
    pub sort: LatencySummary,
    /// Total-latency summary.
    pub total: LatencySummary,
    /// Divide-stage wall-time summary (one sample per session).
    pub stage_divide: LatencySummary,
    /// Local-sort-stage wall-time summary.
    pub stage_sort: LatencySummary,
    /// Gather-stage wall-time summary.
    pub stage_gather: LatencySummary,
    /// Total-latency summary over degraded jobs only (retries > 0).
    pub degraded_total: LatencySummary,
}

impl ServiceSnapshot {
    /// The snapshot as a JSON object.
    pub fn to_json(&self) -> Json {
        let stages = Json::obj([
            ("divide", self.stage_divide.to_json()),
            ("gather", self.stage_gather.to_json()),
            ("local_sort", self.stage_sort.to_json()),
        ]);
        Json::obj([
            ("accepted", Json::int(self.accepted as usize)),
            ("batched_jobs", Json::int(self.batched_jobs as usize)),
            ("batches", Json::int(self.batches as usize)),
            ("cancelled", Json::int(self.cancelled as usize)),
            ("completed", Json::int(self.completed as usize)),
            ("deadline_missed", Json::int(self.deadline_missed as usize)),
            ("degraded_jobs", Json::int(self.degraded_jobs as usize)),
            ("degraded_total_latency", self.degraded_total.to_json()),
            ("failed", Json::int(self.failed as usize)),
            ("link_failures", Json::int(self.link_failures as usize)),
            ("max_imbalance", Json::num(self.max_imbalance)),
            ("queue_latency", self.queue.to_json()),
            ("rejected", Json::int(self.rejected as usize)),
            ("retries", Json::int(self.retries as usize)),
            ("retries_exhausted", Json::int(self.retries_exhausted as usize)),
            ("skew_redivides", Json::int(self.skew_redivides as usize)),
            ("sort_latency", self.sort.to_json()),
            ("stage_latency", stages),
            ("submitted", Json::int(self.submitted as usize)),
            ("total_latency", self.total.to_json()),
            ("worker_panics", Json::int(self.worker_panics as usize)),
        ])
    }

    /// Human-readable multi-line summary for the CLI.
    pub fn summary_text(&self) -> String {
        format!(
            "service: {} submitted, {} accepted, {} rejected, {} completed, {} failed, \
             {} cancelled\n\
             batching: {} batches covering {} jobs; deadlines missed: {}\n\
             faults: {} worker panics, {} link failures, {} retries ({} exhausted), \
             {} degraded jobs\n\
             divide balance: max imbalance {:.2}x, {} skew re-divides\n\
             queue latency: p50 {:.3?} p95 {:.3?} p99 {:.3?}\n\
             sort  latency: p50 {:.3?} p95 {:.3?} p99 {:.3?}\n\
             total latency: p50 {:.3?} p95 {:.3?} p99 {:.3?} max {:.3?}\n",
            self.submitted,
            self.accepted,
            self.rejected,
            self.completed,
            self.failed,
            self.cancelled,
            self.batches,
            self.batched_jobs,
            self.deadline_missed,
            self.worker_panics,
            self.link_failures,
            self.retries,
            self.retries_exhausted,
            self.degraded_jobs,
            self.max_imbalance,
            self.skew_redivides,
            self.queue.p50,
            self.queue.p95,
            self.queue.p99,
            self.sort.p50,
            self.sort.p95,
            self.sort.p99,
            self.total.p50,
            self.total.p95,
            self.total.p99,
            self.total.max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(queue_us: u64, sort_us: u64, ok: bool, met: Option<bool>) -> JobResult {
        JobResult {
            id: 0,
            elements: 10,
            dimension: 1,
            batched: false,
            queue_latency: Duration::from_micros(queue_us),
            sort_latency: Duration::from_micros(sort_us),
            total_latency: Duration::from_micros(queue_us + sort_us),
            deadline: None,
            deadline_met: met,
            sorted_ok: ok,
            checksum: 0,
            imbalance: 1.0,
            skew_redivides: 0,
            retries: 0,
            error: None,
            output: None,
        }
    }

    #[test]
    fn fault_counters_and_degraded_latency_accumulate() {
        let stats = ServiceStats::new();
        stats.on_worker_panic();
        stats.on_link_failure();
        stats.on_link_failure();
        stats.on_retry();
        stats.on_retry();
        stats.on_retry_exhausted();
        // A job that needed a retry lands in the degraded histogram…
        let mut degraded = result(10, 1000, true, None);
        degraded.retries = 1;
        degraded.imbalance = 2.5;
        degraded.skew_redivides = 1;
        stats.on_result(&degraded);
        // …and a clean job does not.
        stats.on_result(&result(10, 100, true, None));
        let s = stats.snapshot();
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.link_failures, 2);
        assert_eq!(s.retries, 2);
        assert_eq!(s.retries_exhausted, 1);
        assert_eq!(s.degraded_jobs, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.degraded_total.count, 1);
        assert!(s.degraded_total.p50 >= Duration::from_micros(1000));
        assert_eq!(s.skew_redivides, 1);
        assert!((s.max_imbalance - 2.5).abs() < 1e-9, "{}", s.max_imbalance);
        let j = s.to_json();
        assert_eq!(j.get("worker_panics").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("degraded_jobs").unwrap().as_usize(), Some(1));
        assert!(j.get("degraded_total_latency").unwrap().get("count").is_some());
        assert_eq!(j.get("max_imbalance").unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("skew_redivides").unwrap().as_usize(), Some(1));
        assert!(s.summary_text().contains("2 retries (1 exhausted)"));
        assert!(s.summary_text().contains("max imbalance 2.50x, 1 skew re-divides"));
    }

    #[test]
    fn counters_and_percentiles_accumulate() {
        let stats = ServiceStats::new();
        stats.on_submit(true);
        stats.on_submit(true);
        stats.on_submit(false);
        for i in 1..=100u64 {
            stats.on_result(&result(i, 10 * i, true, None));
        }
        stats.on_result(&result(5, 5, false, Some(false)));
        stats.on_batch(4);
        stats.on_batch(1); // singleton "batches" are not batches
        let s = stats.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.accepted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert_eq!(s.deadline_missed, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.batched_jobs, 4);
        assert_eq!(s.total.count, 101);
        // Queue p50 ≈ 50 µs, p99 ≈ 99–100 µs (bucket resolution ≤ 1/8).
        let p50 = s.queue.p50.as_nanos() as f64;
        assert!((45_000.0..=55_000.0).contains(&p50), "{p50}");
        assert!(s.queue.p99 >= s.queue.p50);
        assert!(s.total.max >= s.total.p99);
        assert!(s.sort.p95 > s.queue.p95);
    }

    #[test]
    fn stage_observer_and_cancellations_land_in_the_snapshot() {
        let stats = ServiceStats::new();
        let trace = StageTrace::default();
        for _ in 0..3 {
            stats.on_stage(Stage::Divide, Duration::from_micros(10), &trace);
            stats.on_stage(Stage::LocalSort, Duration::from_micros(100), &trace);
            stats.on_stage(Stage::Gather, Duration::from_micros(1), &trace);
        }
        stats.on_cancelled();
        let s = stats.snapshot();
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.stage_divide.count, 3);
        assert_eq!(s.stage_sort.count, 3);
        assert_eq!(s.stage_gather.count, 3);
        assert!(s.stage_sort.p50 > s.stage_gather.p50);
        let j = s.to_json();
        assert_eq!(j.get("cancelled").unwrap().as_usize(), Some(1));
        let stages = j.get("stage_latency").unwrap();
        assert_eq!(stages.get("local_sort").unwrap().get("count").unwrap().as_usize(), Some(3));
        assert!(stats.snapshot().summary_text().contains("1 cancelled"));
    }

    #[test]
    fn merged_shards_equal_one_service_that_saw_everything() {
        // Two "shards" record disjoint halves of a workload; merging
        // them must be indistinguishable from one service that saw all
        // of it — counters, percentiles, and the fault/skew witnesses.
        let all = ServiceStats::new();
        let a = ServiceStats::new();
        let b = ServiceStats::new();
        for i in 1..=200u64 {
            let shard = if i % 2 == 0 { &a } else { &b };
            let mut r = result(i, 10 * i, true, None);
            if i % 50 == 0 {
                r.retries = 1;
                r.skew_redivides = 2;
                r.imbalance = 1.0 + i as f64 / 100.0;
            }
            shard.on_submit(true);
            shard.on_result(&r);
            all.on_submit(true);
            all.on_result(&r);
        }
        a.on_worker_panic();
        b.on_retry_exhausted();
        all.on_worker_panic();
        all.on_retry_exhausted();
        let merged = ServiceStats::new();
        merged.merge(&a);
        merged.merge(&b);
        let (m, reference) = (merged.snapshot(), all.snapshot());
        assert_eq!(m.submitted, reference.submitted);
        assert_eq!(m.completed, reference.completed);
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.retries_exhausted, 1);
        assert_eq!(m.degraded_jobs, reference.degraded_jobs);
        assert_eq!(m.skew_redivides, reference.skew_redivides);
        assert_eq!(m.max_imbalance, reference.max_imbalance);
        // Histogram-level merge: every percentile matches exactly.
        assert_eq!(m.queue, reference.queue);
        assert_eq!(m.sort, reference.sort);
        assert_eq!(m.total, reference.total);
        assert_eq!(m.degraded_total, reference.degraded_total);
    }

    #[test]
    fn merging_empty_stats_changes_nothing() {
        let stats = ServiceStats::new();
        stats.on_submit(true);
        stats.on_result(&result(10, 100, true, None));
        let before = stats.snapshot();
        stats.merge(&ServiceStats::new());
        let after = stats.snapshot();
        assert_eq!(after.completed, before.completed);
        assert_eq!(after.total, before.total);
        assert_eq!(after.max_imbalance, before.max_imbalance);
    }

    #[test]
    fn snapshot_json_round_trips_through_the_parser() {
        let stats = ServiceStats::new();
        stats.on_submit(true);
        stats.on_result(&result(10, 100, true, Some(true)));
        let j = stats.snapshot().to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("completed").unwrap().as_usize(), Some(1));
        let total = parsed.get("total_latency").unwrap();
        assert!(total.get("p99_ns").unwrap().as_f64().unwrap() > 0.0);
        let text = stats.snapshot().summary_text();
        assert!(text.contains("1 submitted"));
        assert!(text.contains("total latency: p50"));
    }
}
