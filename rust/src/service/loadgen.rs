//! Deterministic load generation: seeded open- and closed-loop job
//! streams with mixed distributions, sizes, and topology dimensions.
//!
//! The schedule is a pure function of [`LoadGenConfig`] — same seed,
//! same jobs, same per-job workloads — so a loadgen run is a
//! reproducible experiment: the determinism test replays a seed and
//! asserts byte-identical sorted outputs (per-job FNV checksums).
//!
//! * **Closed loop** keeps a fixed number of jobs in flight: submit the
//!   next job when one completes.  Offered load adapts to service
//!   capacity; latency reflects service time (queueing is bounded by
//!   the concurrency).
//! * **Open loop** submits on a fixed arrival clock regardless of
//!   completions — the regime where queues grow and admission control
//!   earns its keep.

use std::time::{Duration, Instant};

use crate::config::{Construction, Distribution, DivideStrategy};
use crate::service::job::{fnv1a_bytes, JobResult, JobSpec};
use crate::service::pool::SortService;
use crate::service::stats::ServiceSnapshot;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Anything loadgen can drive: offer a job, drain a completion, freeze
/// a stats snapshot.  [`SortService`] is the single-node sink; the
/// cluster layer ([`crate::cluster::Cluster`]) is the sharded one —
/// the generator itself is identical either way.
pub trait JobSink {
    /// Offer one job; `true` iff it was accepted.
    fn offer(&self, spec: JobSpec) -> bool;
    /// Wait up to `timeout` for any undelivered finished job.
    fn drain_next(&self, timeout: Duration) -> Option<JobResult>;
    /// Freeze the sink's service-level stats.
    fn stats_snapshot(&self) -> ServiceSnapshot;
}

impl JobSink for SortService {
    fn offer(&self, spec: JobSpec) -> bool {
        self.submit(spec).is_accepted()
    }

    fn drain_next(&self, timeout: Duration) -> Option<JobResult> {
        self.next_completion(timeout)
    }

    fn stats_snapshot(&self) -> ServiceSnapshot {
        self.stats().snapshot()
    }
}

/// How jobs are offered to the service.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadMode {
    /// Fixed arrival rate in jobs/second, completions ignored.
    Open {
        /// Arrival rate.
        rate: f64,
    },
    /// Fixed number of jobs in flight.
    Closed {
        /// In-flight ceiling.
        concurrency: usize,
    },
}

/// Generator knobs.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Jobs to generate.
    pub jobs: usize,
    /// Schedule seed (drives every per-job choice).
    pub seed: u64,
    /// Topology dimensions to mix over.
    pub dimensions: Vec<u32>,
    /// Construction rule for every job.
    pub construction: Construction,
    /// Distributions to mix over.
    pub distributions: Vec<Distribution>,
    /// Smallest job, keys.
    pub min_elements: usize,
    /// Largest job, keys (sizes are log-uniform in between).
    pub max_elements: usize,
    /// Divide strategy stamped on every job (adversarial mixes pair
    /// naturally with `Sampling`/`Adaptive`).
    pub strategy: DivideStrategy,
    /// Per-job latency SLO, if any.
    pub deadline: Option<Duration>,
    /// Open or closed loop.
    pub mode: LoadMode,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            jobs: 1_000,
            seed: 7,
            dimensions: vec![1, 2, 3],
            construction: Construction::FullGroup,
            distributions: Distribution::ALL.to_vec(),
            min_elements: 2_000,
            max_elements: 32_000,
            strategy: DivideStrategy::PaperFixed,
            deadline: None,
            mode: LoadMode::Closed { concurrency: 8 },
        }
    }
}

/// Expand the config into its deterministic job schedule.
pub fn schedule(cfg: &LoadGenConfig) -> Vec<JobSpec> {
    assert!(!cfg.dimensions.is_empty(), "loadgen needs at least one dimension");
    assert!(!cfg.distributions.is_empty(), "loadgen needs at least one distribution");
    let mut rng = Rng::new(cfg.seed);
    let lo = cfg.min_elements.max(1) as f64;
    let hi = cfg.max_elements.max(cfg.min_elements).max(1) as f64;
    (0..cfg.jobs)
        .map(|i| {
            let distribution =
                cfg.distributions[rng.below(cfg.distributions.len() as u64) as usize];
            let dimension = cfg.dimensions[rng.below(cfg.dimensions.len() as u64) as usize];
            let elements = (lo * (hi / lo).powf(rng.f64())).round() as usize;
            JobSpec {
                id: i as u64,
                distribution,
                elements,
                seed: rng.next_u64(),
                dimension,
                construction: cfg.construction,
                strategy: cfg.strategy,
                deadline: cfg.deadline,
            }
        })
        .collect()
}

/// What one loadgen run produced.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Jobs in the schedule.
    pub jobs: usize,
    /// Accepted by the service.
    pub accepted: usize,
    /// Rejected at the front door.
    pub rejected: usize,
    /// Results received with verified output.
    pub completed: usize,
    /// Results received that failed verification or errored.
    pub failures: usize,
    /// Deadline misses among received results.
    pub deadline_missed: usize,
    /// Wall time of the whole run.
    pub wall: Duration,
    /// Verified completions per wall second.
    pub throughput_jps: f64,
    /// Service stats frozen at drain time.
    pub snapshot: ServiceSnapshot,
    /// `(job id, output checksum)` sorted by id — the determinism
    /// witness compared across runs.
    pub checksums: Vec<(u64, u64)>,
}

impl LoadReport {
    /// One digest over every `(id, checksum)` pair — equal between two
    /// runs iff every job produced identical output.
    pub fn checksum_digest(&self) -> u64 {
        fnv1a_bytes(self.checksums.iter().flat_map(|&(id, sum)| {
            id.to_le_bytes().into_iter().chain(sum.to_le_bytes())
        }))
    }

    /// The report as one JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("accepted", Json::int(self.accepted)),
            ("checksum_digest", Json::str(format!("{:016x}", self.checksum_digest()))),
            ("completed", Json::int(self.completed)),
            ("deadline_missed", Json::int(self.deadline_missed)),
            ("failures", Json::int(self.failures)),
            ("jobs", Json::int(self.jobs)),
            ("rejected", Json::int(self.rejected)),
            ("service", self.snapshot.to_json()),
            ("throughput_jps", Json::num(self.throughput_jps)),
            ("wall_secs", Json::num(self.wall.as_secs_f64())),
        ])
    }

    /// Human summary for the CLI.
    pub fn summary_text(&self) -> String {
        format!(
            "loadgen: {} jobs → {} accepted, {} rejected, {} completed, {} failures\n\
             wall {:.3?}, throughput {:.1} jobs/s, deadline misses {}\n{}",
            self.jobs,
            self.accepted,
            self.rejected,
            self.completed,
            self.failures,
            self.wall,
            self.throughput_jps,
            self.deadline_missed,
            self.snapshot.summary_text()
        )
    }
}

/// Drive a running [`SortService`] with the config's schedule — see
/// [`run_on`] for the generic version that also drives a
/// [`Cluster`](crate::cluster::Cluster).
pub fn run(service: &SortService, cfg: &LoadGenConfig) -> LoadReport {
    run_on(service, cfg)
}

/// Drive any [`JobSink`] with the config's schedule and collect the
/// report.  Waits (bounded) for every accepted job's result — the
/// sink contract is one result per accepted (and uncancelled) job,
/// so a stall here is a sink bug, surfaced by the timeout rather
/// than a hang.  The generator deliberately drops its tickets and
/// consumes the sink's completion drain: it wants *any* finished job,
/// whichever tenant's it is — exactly the consumer that API exists
/// for.
pub fn run_on<S: JobSink>(service: &S, cfg: &LoadGenConfig) -> LoadReport {
    const STALL: Duration = Duration::from_secs(120);
    let specs = schedule(cfg);
    let t0 = Instant::now();
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    let mut results: Vec<JobResult> = Vec::with_capacity(specs.len());

    match cfg.mode {
        LoadMode::Closed { concurrency } => {
            let target = concurrency.max(1);
            let mut next = 0usize;
            let mut inflight = 0usize;
            loop {
                while next < specs.len() && inflight < target {
                    if service.offer(specs[next].clone()) {
                        accepted += 1;
                        inflight += 1;
                    } else {
                        rejected += 1;
                    }
                    next += 1;
                }
                if inflight == 0 {
                    break;
                }
                match service.drain_next(STALL) {
                    Some(r) => {
                        results.push(r);
                        inflight -= 1;
                    }
                    None => break, // stalled service — report what we have
                }
            }
        }
        LoadMode::Open { rate } => {
            let gap = Duration::from_secs_f64(1.0 / rate.max(1e-9));
            for (i, spec) in specs.iter().enumerate() {
                let due = t0 + gap.mul_f64(i as f64);
                // Drain completions while holding to the arrival clock.
                loop {
                    let now = Instant::now();
                    if now >= due {
                        break;
                    }
                    let wait = (due - now).min(Duration::from_millis(2));
                    if let Some(r) = service.drain_next(wait) {
                        results.push(r);
                    }
                }
                if service.offer(spec.clone()) {
                    accepted += 1;
                } else {
                    rejected += 1;
                }
            }
            while results.len() < accepted {
                match service.drain_next(STALL) {
                    Some(r) => results.push(r),
                    None => break,
                }
            }
        }
    }

    let wall = t0.elapsed();
    let completed = results.iter().filter(|r| r.sorted_ok && r.error.is_none()).count();
    let failures = results.len() - completed;
    let deadline_missed = results.iter().filter(|r| r.deadline_met == Some(false)).count();
    let mut checksums: Vec<(u64, u64)> = results.iter().map(|r| (r.id, r.checksum)).collect();
    checksums.sort_unstable();
    LoadReport {
        jobs: specs.len(),
        accepted,
        rejected,
        completed,
        failures,
        deadline_missed,
        wall,
        throughput_jps: completed as f64 / wall.as_secs_f64().max(1e-9),
        snapshot: service.stats_snapshot(),
        checksums,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_seed_sensitive() {
        let cfg = LoadGenConfig {
            jobs: 64,
            ..Default::default()
        };
        let a = schedule(&cfg);
        let b = schedule(&cfg);
        assert_eq!(a, b);
        let c = schedule(&LoadGenConfig { seed: 8, ..cfg });
        assert_ne!(a, c, "schedule must depend on the seed");
        assert_eq!(a.len(), 64);
        // Ids are the schedule order.
        for (i, s) in a.iter().enumerate() {
            assert_eq!(s.id, i as u64);
        }
    }

    #[test]
    fn schedule_mixes_axes_within_bounds() {
        let cfg = LoadGenConfig {
            jobs: 400,
            min_elements: 1_000,
            max_elements: 16_000,
            ..Default::default()
        };
        let specs = schedule(&cfg);
        let mut dims: Vec<u32> = specs.iter().map(|s| s.dimension).collect();
        dims.sort_unstable();
        dims.dedup();
        assert_eq!(dims, vec![1, 2, 3], "400 draws must hit every dimension");
        let mut dists: Vec<&str> = specs.iter().map(|s| s.distribution.label()).collect();
        dists.sort_unstable();
        dists.dedup();
        assert_eq!(dists.len(), 4, "400 draws must hit every distribution");
        assert!(specs.iter().all(|s| (1_000..=16_000).contains(&s.elements)));
        // Log-uniform sizing: both halves of the range are populated.
        assert!(specs.iter().any(|s| s.elements < 4_000));
        assert!(specs.iter().any(|s| s.elements > 8_000));
    }

    #[test]
    fn report_json_and_digest_reflect_checksums() {
        let snapshot = crate::service::stats::ServiceStats::new().snapshot();
        let mut report = LoadReport {
            jobs: 2,
            accepted: 2,
            rejected: 0,
            completed: 2,
            failures: 0,
            deadline_missed: 0,
            wall: Duration::from_millis(10),
            throughput_jps: 200.0,
            snapshot,
            checksums: vec![(0, 111), (1, 222)],
        };
        let d1 = report.checksum_digest();
        let j = report.to_json();
        assert_eq!(j.get("completed").unwrap().as_usize(), Some(2));
        assert_eq!(
            j.get("checksum_digest").unwrap().as_str(),
            Some(format!("{d1:016x}").as_str())
        );
        report.checksums[1].1 = 333;
        assert_ne!(report.checksum_digest(), d1);
        assert!(report.summary_text().contains("2 accepted"));
    }
}
