//! Bounded MPMC submission queue with explicit backpressure.
//!
//! The service never buffers unboundedly: a submission either lands in
//! the queue ([`Submit::Accepted`]) or is turned away with a reason
//! ([`Submit::Rejected`]) the caller can act on — retry later, shed
//! load, or surface the error to the tenant.  `offer` never blocks;
//! `pop` blocks until work arrives or the queue is closed, so worker
//! shutdown is a `close()` away and cannot deadlock.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Condvar, Mutex};

/// Why a submission was turned away.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue is at capacity.
    QueueFull {
        /// The queue's fixed capacity.
        capacity: usize,
    },
    /// The token bucket is empty (offered rate above the admit rate).
    RateLimited,
    /// Queue-depth shedding tripped before the queue filled.
    Overloaded {
        /// Depth observed at submit time.
        depth: usize,
        /// The shedding threshold.
        shed_depth: usize,
    },
    /// No live shard can take the job: the cluster's health board has
    /// every shard down or drained.  Cluster routing only — a single
    /// service never emits this.
    Unavailable,
    /// The service is shutting down.
    Closed,
    /// The spec failed validation (never enqueued).
    Invalid {
        /// What was wrong.
        detail: String,
    },
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull { capacity } => {
                write!(f, "queue full (capacity {capacity})")
            }
            RejectReason::RateLimited => write!(f, "rate limited"),
            RejectReason::Overloaded { depth, shed_depth } => {
                write!(f, "overloaded (depth {depth} >= shed threshold {shed_depth})")
            }
            RejectReason::Unavailable => {
                write!(f, "no live shard (every shard is down or drained)")
            }
            RejectReason::Closed => write!(f, "service closed"),
            RejectReason::Invalid { detail } => write!(f, "invalid job: {detail}"),
        }
    }
}

/// Outcome of one submission attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Submit {
    /// Enqueued; `depth` is the queue depth right after the push.
    Accepted {
        /// Queue depth including this job.
        depth: usize,
    },
    /// Turned away — the job was **not** enqueued.
    Rejected {
        /// Why.
        reason: RejectReason,
    },
}

impl Submit {
    /// Did the job make it into the queue?
    pub fn is_accepted(&self) -> bool {
        matches!(self, Submit::Accepted { .. })
    }
}

#[derive(Debug)]
struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer FIFO queue.
///
/// Producers call [`JobQueue::offer`] (non-blocking, explicit
/// [`Submit`] outcome); consumers call [`JobQueue::pop`] (blocking) or
/// [`JobQueue::drain_matching`] (the batcher's bulk claim).
#[derive(Debug)]
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    /// Queue with a fixed capacity (≥ 1).
    pub fn bounded(capacity: usize) -> Self {
        JobQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The fixed capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current depth.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Non-blocking submit: enqueue or reject, never wait.
    pub fn offer(&self, item: T) -> Submit {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Submit::Rejected {
                reason: RejectReason::Closed,
            };
        }
        if inner.items.len() >= self.capacity {
            return Submit::Rejected {
                reason: RejectReason::QueueFull {
                    capacity: self.capacity,
                },
            };
        }
        inner.items.push_back(item);
        let depth = inner.items.len();
        drop(inner);
        self.not_empty.notify_one();
        Submit::Accepted { depth }
    }

    /// Put a previously-popped job back at the **front** of the queue,
    /// bypassing the capacity check — the job already paid admission
    /// once, and a fault-retry must never be silently dropped just
    /// because the queue refilled behind it.  Returns the new depth, or
    /// hands the item back when the queue is closed (the caller fails
    /// the job explicitly instead).
    pub fn requeue(&self, item: T) -> std::result::Result<usize, T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(item);
        }
        inner.items.push_front(item);
        let depth = inner.items.len();
        drop(inner);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking consume: the next job, or `None` once the queue is
    /// closed **and** drained (workers exit on `None`).
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Non-blocking consume.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().items.pop_front()
    }

    /// Remove and return up to `max` queued jobs matching `pred`,
    /// scanning front to back (FIFO among matches).  Non-matching jobs
    /// keep their positions — this is how a worker claims a coalescible
    /// batch without starving large jobs behind it.
    pub fn drain_matching(&self, max: usize, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        let mut i = 0;
        while i < inner.items.len() && out.len() < max {
            if pred(&inner.items[i]) {
                out.push(inner.items.remove(i).expect("index in bounds"));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Close the queue: subsequent offers reject with
    /// [`RejectReason::Closed`]; blocked `pop`s drain the backlog then
    /// return `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// Has `close` been called?
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_accept_then_reject_at_capacity() {
        let q = JobQueue::bounded(2);
        assert_eq!(q.offer(1), Submit::Accepted { depth: 1 });
        assert_eq!(q.offer(2), Submit::Accepted { depth: 2 });
        assert_eq!(
            q.offer(3),
            Submit::Rejected { reason: RejectReason::QueueFull { capacity: 2 } }
        );
        assert_eq!(q.depth(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.offer(3).is_accepted(), "a pop frees a slot");
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn requeue_jumps_the_line_and_ignores_capacity() {
        let q = JobQueue::bounded(2);
        q.offer(1);
        q.offer(2);
        // Full queue: offer rejects, requeue does not.
        assert!(!q.offer(3).is_accepted());
        assert_eq!(q.requeue(0), Ok(3));
        assert_eq!(q.try_pop(), Some(0), "retries go to the front");
        assert_eq!(q.try_pop(), Some(1));
        q.close();
        assert_eq!(q.requeue(9), Err(9), "closed queues hand the job back");
    }

    #[test]
    fn close_rejects_offers_and_drains_backlog() {
        let q = JobQueue::bounded(4);
        q.offer(10);
        q.offer(20);
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.offer(30), Submit::Rejected { reason: RejectReason::Closed });
        // The backlog still drains before pop returns None.
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(20));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = JobQueue::<u32>::bounded(1);
        std::thread::scope(|scope| {
            let h = scope.spawn(|| q.pop());
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.close();
            assert_eq!(h.join().unwrap(), None);
        });
    }

    #[test]
    fn drain_matching_claims_fifo_subset() {
        let q = JobQueue::bounded(8);
        for v in [5, 100, 7, 200, 9, 11] {
            q.offer(v);
        }
        let small = q.drain_matching(2, |&v| v < 50);
        assert_eq!(small, vec![5, 7], "at most `max`, FIFO among matches");
        // Non-matches (and the overflow match) keep their order.
        assert_eq!(q.try_pop(), Some(100));
        assert_eq!(q.try_pop(), Some(200));
        assert_eq!(q.try_pop(), Some(9));
        assert_eq!(q.try_pop(), Some(11));
    }

    #[test]
    fn mpmc_under_contention_conserves_items() {
        let q = JobQueue::bounded(16);
        let consumed = AtomicUsize::new(0);
        const PER_PRODUCER: usize = 500;
        std::thread::scope(|scope| {
            let producers: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| {
                        for v in 0..PER_PRODUCER as u32 {
                            // Retry on backpressure: a bounded queue under
                            // contention must reject, never block or drop.
                            while !q.offer(v).is_accepted() {
                                std::thread::yield_now();
                            }
                        }
                    })
                })
                .collect();
            for _ in 0..4 {
                scope.spawn(|| {
                    while q.pop().is_some() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            for p in producers {
                p.join().unwrap();
            }
            q.close();
        });
        assert_eq!(
            consumed.load(Ordering::Relaxed),
            4 * PER_PRODUCER,
            "every accepted item is consumed exactly once"
        );
    }
}
