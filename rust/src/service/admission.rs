//! Admission control: token-bucket rate limiting plus queue-depth
//! shedding.
//!
//! Both checks run **before** a job touches the queue, so an overloaded
//! service answers cheaply at the front door instead of queueing work it
//! will miss deadlines on.  The token bucket is deterministic in the
//! elapsed time it is fed ([`TokenBucket::refill`] takes an explicit
//! duration), which keeps the unit tests clock-free; the wall-clock
//! binding lives in [`AdmissionControl`].

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::service::queue::RejectReason;

/// Deterministic token bucket: `rate` tokens/second accrue up to a
/// `burst` ceiling; each admitted job takes one token.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
}

impl TokenBucket {
    /// Bucket that starts full.
    pub fn new(rate: f64, burst: f64) -> Self {
        let burst = burst.max(1.0);
        TokenBucket {
            rate: rate.max(0.0),
            burst,
            tokens: burst,
        }
    }

    /// Accrue tokens for an elapsed duration (clamped at the burst).
    pub fn refill(&mut self, elapsed: Duration) {
        self.tokens = (self.tokens + self.rate * elapsed.as_secs_f64()).min(self.burst);
    }

    /// Take one token if available.
    pub fn try_take(&mut self) -> bool {
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available.
    pub fn available(&self) -> f64 {
        self.tokens
    }
}

/// Front-door policy: optional rate limit plus a queue-depth shed
/// threshold.  `shed_depth = usize::MAX` disables shedding; `rate =
/// None` disables the bucket.
#[derive(Debug)]
pub struct AdmissionControl {
    bucket: Option<Mutex<(TokenBucket, Instant)>>,
    shed_depth: usize,
}

impl AdmissionControl {
    /// Build the policy.
    pub fn new(rate: Option<f64>, burst: f64, shed_depth: usize) -> Self {
        AdmissionControl {
            bucket: rate.map(|r| Mutex::new((TokenBucket::new(r, burst), Instant::now()))),
            shed_depth,
        }
    }

    /// Policy that admits everything.
    pub fn open() -> Self {
        Self::new(None, 1.0, usize::MAX)
    }

    /// Decide on one submission given the live queue depth.
    pub fn admit(&self, queue_depth: usize) -> Result<(), RejectReason> {
        if queue_depth >= self.shed_depth {
            return Err(RejectReason::Overloaded {
                depth: queue_depth,
                shed_depth: self.shed_depth,
            });
        }
        if let Some(bucket) = &self.bucket {
            let mut guard = bucket.lock().unwrap();
            let now = Instant::now();
            let elapsed = now.duration_since(guard.1);
            guard.1 = now;
            guard.0.refill(elapsed);
            if !guard.0.try_take() {
                return Err(RejectReason::RateLimited);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_burst_then_starve_then_refill() {
        let mut b = TokenBucket::new(10.0, 3.0);
        // Starts full: the burst drains immediately...
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(b.try_take());
        // ...then starves with no elapsed time...
        assert!(!b.try_take());
        // ...and 100 ms at 10 tokens/s buys exactly one more.
        b.refill(Duration::from_millis(100));
        assert!(b.try_take());
        assert!(!b.try_take());
    }

    #[test]
    fn bucket_never_exceeds_burst() {
        let mut b = TokenBucket::new(1000.0, 2.0);
        b.refill(Duration::from_secs(60));
        assert!((b.available() - 2.0).abs() < 1e-9);
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take());
    }

    #[test]
    fn zero_rate_admits_only_the_burst() {
        let mut b = TokenBucket::new(0.0, 2.0);
        assert!(b.try_take());
        assert!(b.try_take());
        b.refill(Duration::from_secs(3600));
        assert!(!b.try_take());
    }

    #[test]
    fn shed_depth_rejects_before_queue_full() {
        let a = AdmissionControl::new(None, 1.0, 4);
        assert!(a.admit(0).is_ok());
        assert!(a.admit(3).is_ok());
        assert_eq!(a.admit(4), Err(RejectReason::Overloaded { depth: 4, shed_depth: 4 }));
        assert_eq!(a.admit(100), Err(RejectReason::Overloaded { depth: 100, shed_depth: 4 }));
    }

    #[test]
    fn open_policy_admits_everything() {
        let a = AdmissionControl::open();
        for depth in [0, 1, 1_000_000] {
            assert!(a.admit(depth).is_ok());
        }
    }

    #[test]
    fn rate_limited_rejections_name_the_reason() {
        // Burst 1, rate ~0: the second immediate admit must rate-limit.
        let a = AdmissionControl::new(Some(1e-9), 1.0, usize::MAX);
        assert!(a.admit(0).is_ok());
        assert_eq!(a.admit(0), Err(RejectReason::RateLimited));
    }
}
