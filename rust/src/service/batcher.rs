//! Job coalescing: many small jobs, one arena, one pipeline pass.
//!
//! A topology with `P` processors sorting a 2,000-key job wastes almost
//! the whole machine.  The batcher instead packs `K` small jobs into
//! **one** [`FlatBuckets`] arena: each job receives a contiguous span of
//! the `P` buckets (proportional to its size, at least one), and its
//! keys are divided by its **own** step point into that span.  Bucket
//! ranks then read `job 0's buckets … job K−1's buckets`, so after the
//! standard local-sort + gather pass the arena holds every job's output
//! sorted and contiguous — splitting results back per job is offset-table
//! arithmetic ([`CoalescedBatch::job_range`]), the same machinery the
//! flat data plane already uses for buckets.
//!
//! Because each job has a private step point and private buckets, jobs
//! never mix keys: correctness per job is exactly the single-job
//! pipeline's (the property test in `tests/service.rs` checks split-back
//! equals a per-job sequential sort for every distribution).

use std::ops::Range;
use std::time::{Duration, Instant};

use crate::coordinator::BucketFn;
use crate::dataplane::{FlatBuckets, FlatSpan};
use crate::error::{Error, Result};

/// The coalesced arena plus the per-job bookkeeping to split it back.
#[derive(Debug, Clone)]
pub struct CoalescedBatch {
    /// One arena with exactly the topology's bucket count.
    pub buckets: FlatBuckets,
    /// Wall time spent in the scatter passes (arena placement writes),
    /// summed over the batch — the multi-span counterpart of
    /// [`crate::coordinator::Divided::scatter_time`].
    pub scatter_time: Duration,
    /// Per-job arena key ranges, in batch order.
    job_ranges: Vec<Range<usize>>,
    /// Per-job bucket spans, in batch order.
    job_buckets: Vec<Range<usize>>,
}

impl CoalescedBatch {
    /// Jobs in the batch.
    pub fn num_jobs(&self) -> usize {
        self.job_ranges.len()
    }

    /// Arena key range of job `j` — where its (sorted) output lives.
    pub fn job_range(&self, j: usize) -> Range<usize> {
        self.job_ranges[j].clone()
    }

    /// Bucket span of job `j`.
    pub fn job_buckets(&self, j: usize) -> Range<usize> {
        self.job_buckets[j].clone()
    }

    /// Job `j` as a borrowed bucket view of the arena.
    pub fn job_span(&self, j: usize) -> FlatSpan<'_> {
        self.buckets.span(self.job_buckets[j].clone())
    }

    /// Split a sorted arena (the pipeline's output, same layout) back
    /// into per-job slices, batch order.
    pub fn split_back<'a>(&self, sorted: &'a [i32]) -> Vec<&'a [i32]> {
        self.job_ranges.iter().map(|r| &sorted[r.clone()]).collect()
    }
}

/// Distribute `total_buckets` over jobs proportionally to their sizes,
/// at least one bucket each, largest-remainder rounding (deterministic,
/// ties to the earlier job).  Requires `sizes.len() <= total_buckets`.
pub fn allot_buckets(sizes: &[usize], total_buckets: usize) -> Result<Vec<usize>> {
    let jobs = sizes.len();
    if jobs == 0 {
        return Err(Error::Config("cannot allot buckets to zero jobs".into()));
    }
    if jobs > total_buckets {
        return Err(Error::Config(format!(
            "{jobs} jobs exceed the topology's {total_buckets} buckets"
        )));
    }
    let total_keys: usize = sizes.iter().sum();
    let spare = total_buckets - jobs; // beyond the 1-per-job floor
    if spare == 0 || total_keys == 0 {
        let mut allot = vec![1usize; jobs];
        // Park any spare buckets on the first job (total must be exact).
        allot[0] += spare;
        return Ok(allot);
    }
    // Floor shares plus largest fractional remainders.
    let mut allot = Vec::with_capacity(jobs);
    let mut remainders: Vec<(usize, usize)> = Vec::with_capacity(jobs); // (rem, job)
    let mut assigned = 0usize;
    for (j, &size) in sizes.iter().enumerate() {
        let exact_num = size * spare; // share = exact_num / total_keys
        let floor = exact_num / total_keys;
        allot.push(1 + floor);
        assigned += floor;
        remainders.push((exact_num % total_keys, j));
    }
    // Hand the leftover buckets to the largest remainders; ties resolve
    // to the earlier job for determinism.
    remainders.sort_by_key(|&(rem, j)| (std::cmp::Reverse(rem), j));
    for &(_, j) in remainders.iter().take(total_buckets - jobs - assigned) {
        allot[j] += 1;
    }
    debug_assert_eq!(allot.iter().sum::<usize>(), total_buckets);
    Ok(allot)
}

/// Coalesce `jobs` (each a key slice) into one arena of exactly
/// `total_buckets` buckets.  Each job is divided by its own step point
/// into its allotted bucket span; keys land directly at their final
/// arena positions (one write per key, no intermediate buckets).
pub fn coalesce(jobs: &[&[i32]], total_buckets: usize) -> Result<CoalescedBatch> {
    for (j, data) in jobs.iter().enumerate() {
        if data.is_empty() {
            return Err(Error::Config(format!("batch job {j} is empty")));
        }
    }
    let sizes: Vec<usize> = jobs.iter().map(|d| d.len()).collect();
    let allot = allot_buckets(&sizes, total_buckets)?;
    let total_keys: usize = sizes.iter().sum();

    let mut arena = vec![0i32; total_keys];
    let mut offsets = Vec::with_capacity(total_buckets + 1);
    offsets.push(0usize);
    let mut job_ranges = Vec::with_capacity(jobs.len());
    let mut job_buckets = Vec::with_capacity(jobs.len());
    let mut arena_base = 0usize;
    let mut bucket_base = 0usize;
    let mut scatter_time = Duration::ZERO;

    for (&data, &buckets_j) in jobs.iter().zip(&allot) {
        // Per-job step point (paper §3.1, scoped to the job's keys).
        let mut lo = data[0];
        let mut hi = data[0];
        for &v in data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let sub = (((hi as i64 - lo as i64) / buckets_j as i64).max(1)) as i32;
        let classify = BucketFn::new(lo, sub, buckets_j);

        // Pass 1: cache ids + histogram (jobs are small by admission —
        // the batcher only sees sub-threshold jobs — so this is serial).
        let mut ids: Vec<u16> = Vec::with_capacity(data.len());
        let mut hist = vec![0usize; buckets_j];
        for &v in data {
            let b = classify.of(v);
            ids.push(b as u16);
            hist[b] += 1;
        }

        // Absolute offset table entries + per-bucket write cursors.
        let mut cursors = Vec::with_capacity(buckets_j);
        let mut acc = arena_base;
        for &h in &hist {
            cursors.push(acc);
            acc += h;
            offsets.push(acc);
        }
        debug_assert_eq!(acc, arena_base + data.len());

        // Pass 2: scatter through the cached ids.
        let scatter_t0 = Instant::now();
        for (&v, &b) in data.iter().zip(&ids) {
            let cursor = &mut cursors[b as usize];
            arena[*cursor] = v;
            *cursor += 1;
        }
        scatter_time += scatter_t0.elapsed();

        job_ranges.push(arena_base..arena_base + data.len());
        job_buckets.push(bucket_base..bucket_base + buckets_j);
        arena_base += data.len();
        bucket_base += buckets_j;
    }
    debug_assert_eq!(bucket_base, total_buckets);
    debug_assert_eq!(offsets.len(), total_buckets + 1);

    Ok(CoalescedBatch {
        buckets: FlatBuckets::from_parts(arena, offsets),
        scatter_time,
        job_ranges,
        job_buckets,
    })
}

/// Order a claimed batch for coalescing: jobs with the smallest
/// deadline key first, deadline-free (`None`) jobs last, FIFO among
/// ties (the sort is stable).  The pool passes each job's *remaining
/// slack* (absolute deadline minus now) as the key, so time already
/// spent queued counts against a job.  Because [`coalesce`] lays jobs
/// out in argument order, SLO-bound jobs land earliest in the shared
/// arena and are the first results verified, split back, and
/// published — the "pool-aware batching priorities" ordering half from
/// the roadmap.  Batch members still share one pipeline pass (and
/// therefore one sort latency), so the win is publish order within the
/// batch; deadline-driven batch *membership* is the roadmap item's
/// remaining half.
pub fn order_by_deadline<T>(jobs: &mut [T], deadline_of: impl Fn(&T) -> Option<Duration>) {
    jobs.sort_by_key(|j| match deadline_of(j) {
        Some(d) => (0u8, d),
        None => (1u8, Duration::MAX),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn allotment_is_proportional_exact_and_floored() {
        assert_eq!(allot_buckets(&[100], 36).unwrap(), vec![36]);
        let a = allot_buckets(&[3000, 1000], 36).unwrap();
        assert_eq!(a.iter().sum::<usize>(), 36);
        assert!(a[0] > a[1], "{a:?}");
        assert!(a[1] >= 1);
        // One bucket per job even for extreme skew.
        let a = allot_buckets(&[1_000_000, 1, 1, 1], 36).unwrap();
        assert_eq!(a.iter().sum::<usize>(), 36);
        assert!(a[1..].iter().all(|&b| b >= 1), "{a:?}");
        // Exactly as many buckets as jobs: 1 each.
        assert_eq!(allot_buckets(&[5, 5, 5], 3).unwrap(), vec![1, 1, 1]);
        // More jobs than buckets is a config error.
        assert!(allot_buckets(&[1, 1, 1], 2).is_err());
        assert!(allot_buckets(&[], 2).is_err());
    }

    #[test]
    fn coalesce_lays_jobs_out_contiguously_in_order() {
        let a = workload::random(2_000, 1);
        let b = workload::sorted(1_000, 2);
        let c = workload::reverse_sorted(500, 3);
        let batch = coalesce(&[&a, &b, &c], 36).unwrap();
        assert_eq!(batch.num_jobs(), 3);
        assert_eq!(batch.buckets.num_buckets(), 36);
        assert_eq!(batch.buckets.total_keys(), 3_500);
        assert_eq!(batch.job_range(0), 0..2_000);
        assert_eq!(batch.job_range(1), 2_000..3_000);
        assert_eq!(batch.job_range(2), 3_000..3_500);
        // Bucket spans tile 0..36.
        assert_eq!(batch.job_buckets(0).start, 0);
        assert_eq!(batch.job_buckets(2).end, 36);
        // Each job's span holds exactly its multiset of keys.
        for (j, data) in [&a, &b, &c].into_iter().enumerate() {
            let span = batch.job_span(j);
            let mut got = span.keys().to_vec();
            let mut expect = data.clone();
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect, "job {j}");
        }
    }

    #[test]
    fn sorted_segments_make_each_job_sorted() {
        let a = workload::random(3_000, 7);
        let b = workload::local_distribution(1_500, 8);
        let mut batch = coalesce(&[&a, &b], 144).unwrap();
        for seg in batch.buckets.segments_mut() {
            seg.sort_unstable();
        }
        let (arena, _) = batch.buckets.clone().into_arena();
        let outs = batch.split_back(&arena);
        for (out, input) in outs.iter().zip([&a, &b]) {
            let mut expect = input.clone();
            expect.sort_unstable();
            assert_eq!(*out, expect.as_slice());
        }
    }

    #[test]
    fn single_job_batch_matches_divide_native() {
        // One job spanning every bucket is exactly the coordinator's
        // divide: same arena layout, same offsets.
        let data = workload::random(5_000, 11);
        let batch = coalesce(&[&data], 36).unwrap();
        let divided = crate::coordinator::divide_native(&data, 36).unwrap();
        assert_eq!(batch.buckets, divided.buckets);
    }

    #[test]
    fn deadline_ordering_is_tightest_first_none_last_fifo_ties() {
        // (id, deadline_ms)
        let mut jobs: Vec<(u32, Option<u64>)> = vec![
            (0, None),
            (1, Some(50)),
            (2, Some(10)),
            (3, None),
            (4, Some(10)),
            (5, Some(5)),
        ];
        order_by_deadline(&mut jobs, |j| j.1.map(Duration::from_millis));
        let ids: Vec<u32> = jobs.iter().map(|j| j.0).collect();
        // Tightest deadline first; equal deadlines keep submission
        // order (2 before 4); deadline-free jobs last, FIFO (0 then 3).
        assert_eq!(ids, vec![5, 2, 4, 1, 0, 3]);
    }

    #[test]
    fn rejects_empty_jobs() {
        let a: Vec<i32> = vec![1, 2];
        let b: Vec<i32> = Vec::new();
        assert!(coalesce(&[&a, &b], 36).is_err());
    }
}
