//! Crate-wide error type.
//!
//! `Display`/`Error` are implemented by hand (no `thiserror`) so the
//! default build stays dependency-free and works fully offline.

use std::fmt;

/// Errors surfaced by the OHHC sort library.
#[derive(Debug)]
pub enum Error {
    /// Invalid experiment / topology configuration.
    Config(String),

    /// An AOT artifact is missing or its signature does not match.
    Artifact(String),

    /// Failure inside the XLA/PJRT runtime.
    Xla(String),

    /// A simulated processor panicked or a channel closed unexpectedly.
    Sim(String),

    /// Payload conservation / sortedness invariant violated.
    Invariant(String),

    /// I/O error (config files, CSV output, artifacts).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Invariant(m) => write!(f, "invariant violated: {m}"),
            // Transparent, as thiserror's #[error(transparent)] renders it.
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::xla::Error> for Error {
    fn from(e: crate::xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert_eq!(
            Error::Config("bad".into()).to_string(),
            "invalid configuration: bad"
        );
        assert_eq!(Error::Artifact("x".into()).to_string(), "artifact error: x");
        assert_eq!(Error::Sim("y".into()).to_string(), "simulation error: y");
        assert_eq!(
            Error::Invariant("z".into()).to_string(),
            "invariant violated: z"
        );
    }

    #[test]
    fn io_errors_are_transparent_with_source() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let text = io.to_string();
        let e = Error::from(io);
        assert_eq!(e.to_string(), text);
        assert!(e.source().is_some());
        assert!(Error::Config("c".into()).source().is_none());
    }
}
