//! Crate-wide error type.
//!
//! `Display`/`Error` are implemented by hand (no `thiserror`) so the
//! default build stays dependency-free and works fully offline.

use std::fmt;

/// A pipeline-stage failure caused by an injected (or modeled) fault.
///
/// Produced by every engine: the DES hits it when a gather/scatter tree
/// edge has no surviving route, the pooled and direct engines when the
/// session's pre-flight link check finds the modeled network partitioned.
/// The service maps it onto its retry budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageError {
    /// No surviving route between two processors — the fault set
    /// partitions the pair.
    LinkFailed {
        /// Sending processor (flat node id).
        src: usize,
        /// Receiving processor (flat node id).
        dst: usize,
    },
    /// A processor on the schedule is itself failed.
    NodeFailed {
        /// The dead processor (flat node id).
        node: usize,
    },
}

impl fmt::Display for StageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StageError::LinkFailed { src, dst } => {
                write!(f, "link failed: no surviving route {src} -> {dst}")
            }
            StageError::NodeFailed { node } => write!(f, "node failed: processor {node} is down"),
        }
    }
}

/// Errors surfaced by the OHHC sort library.
#[derive(Debug)]
pub enum Error {
    /// Invalid experiment / topology configuration.
    Config(String),

    /// An AOT artifact is missing or its signature does not match.
    Artifact(String),

    /// Failure inside the XLA/PJRT runtime.
    Xla(String),

    /// A simulated processor panicked or a channel closed unexpectedly.
    Sim(String),

    /// Payload conservation / sortedness invariant violated.
    Invariant(String),

    /// A pipeline stage failed on an injected/modeled fault.
    Stage(StageError),

    /// I/O error (config files, CSV output, artifacts).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Invariant(m) => write!(f, "invariant violated: {m}"),
            Error::Stage(e) => write!(f, "stage failed: {e}"),
            // Transparent, as thiserror's #[error(transparent)] renders it.
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::xla::Error> for Error {
    fn from(e: crate::xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert_eq!(
            Error::Config("bad".into()).to_string(),
            "invalid configuration: bad"
        );
        assert_eq!(Error::Artifact("x".into()).to_string(), "artifact error: x");
        assert_eq!(Error::Sim("y".into()).to_string(), "simulation error: y");
        assert_eq!(
            Error::Invariant("z".into()).to_string(),
            "invariant violated: z"
        );
        assert_eq!(
            Error::Stage(StageError::LinkFailed { src: 3, dst: 9 }).to_string(),
            "stage failed: link failed: no surviving route 3 -> 9"
        );
        assert_eq!(
            Error::Stage(StageError::NodeFailed { node: 5 }).to_string(),
            "stage failed: node failed: processor 5 is down"
        );
    }

    #[test]
    fn io_errors_are_transparent_with_source() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let text = io.to_string();
        let e = Error::from(io);
        assert_eq!(e.to_string(), text);
        assert!(e.source().is_some());
        assert!(Error::Config("c".into()).source().is_none());
    }
}
