//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the OHHC sort library.
#[derive(Debug, Error)]
pub enum Error {
    /// Invalid experiment / topology configuration.
    #[error("invalid configuration: {0}")]
    Config(String),

    /// An AOT artifact is missing or its signature does not match.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Failure inside the XLA/PJRT runtime.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// A simulated processor panicked or a channel closed unexpectedly.
    #[error("simulation error: {0}")]
    Sim(String),

    /// Payload conservation / sortedness invariant violated.
    #[error("invariant violated: {0}")]
    Invariant(String),

    /// I/O error (config files, CSV output, artifacts).
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
