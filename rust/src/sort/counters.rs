//! Instrumentation counters for the sequential Quick Sort.
//!
//! The paper's "number of key comparisons" section splits the work into
//! three metrics — *recursion calls*, *iterations* (partition-loop trips)
//! and *swaps* (Figs 6.20–6.22) — plus *comparison steps* (Fig 6.23).

use std::ops::{Add, AddAssign};

/// Work counters accumulated by one (or a sum over many) Quick Sort runs.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SortCounters {
    /// Recursive calls entered (including the top-level call).
    pub recursion_calls: u64,
    /// Partition inner-loop iterations — the paper's "iterations".
    pub iterations: u64,
    /// Element swaps performed.
    pub swaps: u64,
    /// Key comparisons — the paper's "comparison steps" (Fig 6.23).
    pub comparisons: u64,
    /// Maximum recursion depth reached.
    pub max_depth: u64,
}

impl SortCounters {
    /// A zeroed counter set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total work proxy used by the DES compute-cost model.
    pub fn work(&self) -> u64 {
        self.comparisons + self.swaps
    }
}

impl Add for SortCounters {
    type Output = SortCounters;
    fn add(self, o: SortCounters) -> SortCounters {
        SortCounters {
            recursion_calls: self.recursion_calls + o.recursion_calls,
            iterations: self.iterations + o.iterations,
            swaps: self.swaps + o.swaps,
            comparisons: self.comparisons + o.comparisons,
            max_depth: self.max_depth.max(o.max_depth),
        }
    }
}

impl AddAssign for SortCounters {
    fn add_assign(&mut self, o: SortCounters) {
        *self = *self + o;
    }
}

impl std::iter::Sum for SortCounters {
    fn sum<I: Iterator<Item = SortCounters>>(iter: I) -> Self {
        iter.fold(SortCounters::default(), |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_is_componentwise_with_max_depth() {
        let a = SortCounters {
            recursion_calls: 1,
            iterations: 10,
            swaps: 3,
            comparisons: 12,
            max_depth: 4,
        };
        let b = SortCounters {
            recursion_calls: 2,
            iterations: 20,
            swaps: 5,
            comparisons: 25,
            max_depth: 2,
        };
        let s = a + b;
        assert_eq!(s.recursion_calls, 3);
        assert_eq!(s.iterations, 30);
        assert_eq!(s.swaps, 8);
        assert_eq!(s.comparisons, 37);
        assert_eq!(s.max_depth, 4); // depth does not add
        assert_eq!([a, b].into_iter().sum::<SortCounters>(), s);
    }
}
