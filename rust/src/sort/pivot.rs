//! Pivot selection strategies.
//!
//! The paper observes that sorted / reverse-sorted inputs run *faster* than
//! random ones (Figs 6.1, 6.3) — behaviour consistent with a middle-element
//! pivot (sorted input becomes the best case: perfectly balanced splits,
//! zero swaps).  `Middle` is therefore the default; `Last` (the classic
//! CLRS choice), `MedianOfThree` and `Random` are available for the
//! ablation bench (`benches/seq_sort.rs`).

/// How the partition step picks its pivot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum PivotStrategy {
    /// Middle element — best case on sorted data (paper-consistent default).
    #[default]
    Middle,
    /// Last element (CLRS); worst case `Θ(n²)` on sorted data.
    Last,
    /// Median of first/middle/last keys.
    MedianOfThree,
    /// Pseudo-random index (xorshift over the call counter; deterministic).
    Random,
}

impl PivotStrategy {
    /// Pick the pivot *index* within `data[lo..=hi]`.
    ///
    /// `ticket` is a deterministic per-call counter the sorter threads
    /// through so `Random` stays reproducible.
    #[inline]
    pub fn pick(self, data: &[i32], lo: usize, hi: usize, ticket: u64) -> usize {
        match self {
            PivotStrategy::Middle => lo + (hi - lo) / 2,
            PivotStrategy::Last => hi,
            PivotStrategy::MedianOfThree => {
                let mid = lo + (hi - lo) / 2;
                let (a, b, c) = (data[lo], data[mid], data[hi]);
                // Index of the median of (a, b, c).
                if (a <= b) == (b <= c) {
                    mid
                } else if (b <= a) == (a <= c) {
                    lo
                } else {
                    hi
                }
            }
            PivotStrategy::Random => {
                // xorshift64* on the ticket: cheap, deterministic, good
                // enough to defeat adversarial orders.
                let mut x = ticket.wrapping_add(0x9E37_79B9_7F4A_7C15);
                x ^= x >> 12;
                x ^= x << 25;
                x ^= x >> 27;
                let r = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
                lo + (r as usize) % (hi - lo + 1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn middle_and_last_indices() {
        let d = [5, 4, 3, 2, 1];
        assert_eq!(PivotStrategy::Middle.pick(&d, 0, 4, 0), 2);
        assert_eq!(PivotStrategy::Last.pick(&d, 0, 4, 0), 4);
        assert_eq!(PivotStrategy::Middle.pick(&d, 2, 3, 0), 2);
    }

    #[test]
    fn median_of_three_is_the_median() {
        // All six orderings of three distinct keys.
        for perm in [
            [1, 2, 3],
            [1, 3, 2],
            [2, 1, 3],
            [2, 3, 1],
            [3, 1, 2],
            [3, 2, 1],
        ] {
            let idx = PivotStrategy::MedianOfThree.pick(&perm, 0, 2, 0);
            assert_eq!(perm[idx], 2, "perm {perm:?}");
        }
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let d = [0i32; 100];
        for t in 0..1000u64 {
            let i = PivotStrategy::Random.pick(&d, 10, 90, t);
            assert!((10..=90).contains(&i));
            assert_eq!(i, PivotStrategy::Random.pick(&d, 10, 90, t));
        }
    }
}
