//! Sequential Quick Sort — the per-processor local sort of the paper, with
//! full instrumentation (recursion calls, partition-loop iterations, swaps,
//! key comparisons) backing Figs 6.20–6.24.

mod counters;
mod pivot;
mod quicksort;

pub use counters::SortCounters;
pub use pivot::PivotStrategy;
pub use quicksort::{quicksort, quicksort_with, Quicksort};

/// Convenience: sort ascending with the paper-default configuration
/// (last-element pivot, no cutoff) and return the counters.
pub fn instrumented_sort(data: &mut [i32]) -> SortCounters {
    quicksort(data)
}

/// Check ascending sortedness — used by invariant tests and the
/// coordinator's final verification.
pub fn is_sorted(data: &[i32]) -> bool {
    data.windows(2).all(|w| w[0] <= w[1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::workload;

    #[test]
    fn sorts_all_distributions() {
        for dist in Distribution::ALL {
            let mut v = workload::generate(dist, 10_000, 11);
            let mut expect = v.clone();
            expect.sort_unstable();
            instrumented_sort(&mut v);
            assert_eq!(v, expect, "{dist:?}");
        }
    }

    #[test]
    fn is_sorted_detects_disorder() {
        assert!(is_sorted(&[1, 2, 2, 3]));
        assert!(is_sorted(&[]));
        assert!(is_sorted(&[5]));
        assert!(!is_sorted(&[2, 1]));
    }
}
