//! Instrumented sequential Quick Sort (Hoare 1962, as in the paper §1.2).
//!
//! Divide-and-conquer with an in-place partition; recursion is realized
//! with an explicit stack so adversarial pivot strategies cannot overflow
//! the OS stack at paper-scale inputs (15 M keys).  Every unit of work the
//! paper counts — recursion calls, partition-loop iterations, swaps, key
//! comparisons — is tallied in [`SortCounters`].

use super::counters::SortCounters;
use super::pivot::PivotStrategy;

/// Configurable sorter.  The default configuration reproduces the paper's
/// observed behaviour (middle pivot, recurse to size-1 sub-arrays).
#[derive(Debug, Clone, Copy, Default)]
pub struct Quicksort {
    /// Pivot selection rule.
    pub pivot: PivotStrategy,
    /// Below this length, finish with insertion sort (0 = never; the
    /// paper's algorithm recurses all the way down, so 0 is the default).
    pub insertion_cutoff: usize,
}

impl Quicksort {
    /// Insertion-sort cutoff of the tuned [`Quicksort::throughput`]
    /// profile (a conventional value for 4-byte keys; the delta is
    /// measured per machine by `benches/executor.rs` into
    /// `BENCH_executor.json`).
    pub const THROUGHPUT_CUTOFF: usize = 24;

    /// Tuned profile for the serving paths (Waves-mode service jobs):
    /// middle pivot with sub-arrays at or below
    /// [`Self::THROUGHPUT_CUTOFF`] keys finished by insertion sort.
    /// The paper-default cutoff-0 configuration stays [`Default`], so
    /// the experiment grid and the counter figures (Figs 6.20–6.24)
    /// are untouched — this profile changes wall clock, never output.
    pub fn throughput() -> Quicksort {
        Quicksort {
            insertion_cutoff: Self::THROUGHPUT_CUTOFF,
            ..Default::default()
        }
    }

    /// Sort ascending in place; returns the work counters.
    pub fn sort(&self, data: &mut [i32]) -> SortCounters {
        let mut c = SortCounters::new();
        if data.len() < 2 {
            // A size-0/1 array is already sorted; the paper still counts
            // the (single) call that discovers this.
            c.recursion_calls = 1;
            c.max_depth = 1;
            return c;
        }
        let mut ticket: u64 = 0;
        // Explicit recursion stack of (lo, hi, depth) inclusive ranges.
        let mut stack: Vec<(usize, usize, u64)> = Vec::with_capacity(64);
        stack.push((0, data.len() - 1, 1));
        while let Some((lo, hi, depth)) = stack.pop() {
            c.recursion_calls += 1;
            c.max_depth = c.max_depth.max(depth);
            if lo >= hi {
                continue;
            }
            if self.insertion_cutoff > 1 && hi - lo + 1 <= self.insertion_cutoff {
                insertion_sort(&mut data[lo..=hi], &mut c);
                continue;
            }
            ticket += 1;
            let p = self.partition(data, lo, hi, ticket, &mut c);
            // Push the larger side first so the stack depth stays O(log n).
            let (left, right) = ((lo, p, depth + 1), (p + 1, hi, depth + 1));
            if p - lo >= hi - p {
                stack.push(left);
                stack.push(right);
            } else {
                stack.push(right);
                stack.push(left);
            }
        }
        c
    }

    /// Hoare partition of `data[lo..=hi]`; returns `q` such that
    /// `data[lo..=q] <= pivot <= data[q+1..=hi]` and both sides are
    /// non-empty (CLRS invariant, paper §1.2).
    #[inline]
    fn partition(
        &self,
        data: &mut [i32],
        lo: usize,
        hi: usize,
        ticket: u64,
        c: &mut SortCounters,
    ) -> usize {
        let mut p = self.pivot.pick(data, lo, hi, ticket);
        if p == hi {
            // Hoare's scheme never terminates if the pivot sits at `hi`
            // and is the strict maximum (j would return == hi and the
            // range never shrinks).  Move it out of the way; `Middle`
            // never picks `hi` for lo < hi, so the paper-default path
            // pays nothing here.
            data.swap(hi, lo);
            c.swaps += 1;
            p = lo;
        }
        let pivot = data[p];
        let mut i = lo as isize - 1;
        let mut j = hi as isize + 1;
        loop {
            c.iterations += 1;
            loop {
                i += 1;
                c.comparisons += 1;
                if data[i as usize] >= pivot {
                    break;
                }
            }
            loop {
                j -= 1;
                c.comparisons += 1;
                if data[j as usize] <= pivot {
                    break;
                }
            }
            if i >= j {
                return j as usize;
            }
            data.swap(i as usize, j as usize);
            c.swaps += 1;
        }
    }
}

/// Insertion sort used below the optional cutoff.
fn insertion_sort(data: &mut [i32], c: &mut SortCounters) {
    for i in 1..data.len() {
        let mut j = i;
        while j > 0 {
            c.comparisons += 1;
            c.iterations += 1;
            if data[j - 1] <= data[j] {
                break;
            }
            data.swap(j - 1, j);
            c.swaps += 1;
            j -= 1;
        }
    }
}

/// Sort with the paper-default configuration.
pub fn quicksort(data: &mut [i32]) -> SortCounters {
    Quicksort::default().sort(data)
}

/// Sort with an explicit pivot strategy.
pub fn quicksort_with(data: &mut [i32], pivot: PivotStrategy) -> SortCounters {
    Quicksort {
        pivot,
        ..Default::default()
    }
    .sort(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::workload;

    fn check_sorts(pivot: PivotStrategy, n: usize) {
        for dist in Distribution::ALL {
            let mut v = workload::generate(dist, n, 3);
            let mut expect = v.clone();
            expect.sort_unstable();
            quicksort_with(&mut v, pivot);
            assert_eq!(v, expect, "{pivot:?} {dist:?}");
        }
    }

    #[test]
    fn all_pivots_sort_all_distributions() {
        for pivot in [
            PivotStrategy::Middle,
            PivotStrategy::MedianOfThree,
            PivotStrategy::Random,
        ] {
            check_sorts(pivot, 20_000);
        }
        // `Last` is O(n²) on sorted inputs — keep it small but still test it.
        check_sorts(PivotStrategy::Last, 2_000);
    }

    #[test]
    fn edge_cases() {
        for v in [vec![], vec![1], vec![2, 1], vec![1, 1, 1, 1]] {
            let mut v2 = v.clone();
            quicksort(&mut v2);
            let mut expect = v;
            expect.sort_unstable();
            assert_eq!(v2, expect);
        }
    }

    #[test]
    fn insertion_cutoff_still_sorts() {
        let mut v = workload::random(10_000, 9);
        let mut expect = v.clone();
        expect.sort_unstable();
        let qs = Quicksort {
            insertion_cutoff: 16,
            ..Default::default()
        };
        qs.sort(&mut v);
        assert_eq!(v, expect);
    }

    #[test]
    fn throughput_profile_sorts_identically_with_fewer_calls() {
        for dist in Distribution::ALL {
            let mut tuned = workload::generate(dist, 20_000, 21);
            let mut expect = tuned.clone();
            let paper_counters = quicksort(&mut expect);
            let tuned_counters = Quicksort::throughput().sort(&mut tuned);
            assert_eq!(tuned, expect, "{dist:?}");
            assert!(
                tuned_counters.recursion_calls < paper_counters.recursion_calls,
                "{dist:?}: cutoff 24 should prune the recursion tail"
            );
        }
        assert_eq!(Quicksort::throughput().insertion_cutoff, 24);
        assert_eq!(Quicksort::default().insertion_cutoff, 0, "paper default untouched");
    }

    #[test]
    fn sorted_input_needs_almost_no_swaps_with_middle_pivot() {
        // The paper's key observation (Figs 6.22/6.24): sorted inputs make
        // almost no swaps.  With a middle pivot the only swaps left are
        // between duplicate keys straddling the pivot (no-ops by value),
        // and a distinct-key sorted input needs exactly zero.
        let mut v = workload::sorted(50_000, 4);
        let c = quicksort(&mut v);
        assert!(c.swaps < 500, "swaps {}", c.swaps); // ~duplicate pairs only
        assert!(crate::sort::is_sorted(&v));

        let mut distinct: Vec<i32> = (0..50_000).collect();
        let c = quicksort(&mut distinct);
        assert_eq!(c.swaps, 0);
    }

    #[test]
    fn random_swaps_far_exceed_sorted_swaps() {
        let mut r = workload::random(100_000, 5);
        let mut s = workload::sorted(100_000, 5);
        let cr = quicksort(&mut r);
        let cs = quicksort(&mut s);
        assert!(
            cr.swaps > 100 * (cs.swaps + 1),
            "random {} vs sorted {}",
            cr.swaps,
            cs.swaps
        );
    }

    #[test]
    fn counter_scaling_is_n_log_n_ish() {
        // comparisons(2n) / comparisons(n) should be ~2.1, far below 4
        // (which would indicate quadratic behaviour).
        let mut a = workload::random(1 << 16, 6);
        let mut b = workload::random(1 << 17, 6);
        let ca = quicksort(&mut a);
        let cb = quicksort(&mut b);
        let ratio = cb.comparisons as f64 / ca.comparisons as f64;
        assert!((1.8..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn depth_is_logarithmic_with_middle_pivot_on_sorted() {
        let mut v = workload::sorted(1 << 16, 7);
        let c = quicksort(&mut v);
        assert!(c.max_depth <= 20, "depth {}", c.max_depth);
    }

    #[test]
    fn last_pivot_on_sorted_is_quadratic() {
        // Documents why the paper's timing pattern implies a middle pivot.
        let mut v = workload::sorted(2_000, 8);
        let c = quicksort_with(&mut v, PivotStrategy::Last);
        assert!(c.comparisons > 1_000_000); // ~n²/2
    }
}
