//! Collective communication primitives on the OHHC.
//!
//! The paper's algorithm is one specific collective composition
//! (scatter → compute → gather).  This module provides the standard
//! collective menu on the same topology — broadcast, scatter, gather,
//! reduce, all-reduce — each as a *static schedule* (lists of
//! `(step, src, dst)` link traversals) plus an executor, so alternative
//! sort algorithms (see [`crate::baselines`]) and future OHHC work can
//! reuse them.  Every schedule is validated against the topology (each
//! hop is a physical link) and counted against its analytic bound.
//!
//! Schedules reuse the paper's gather tree (Figs 3.1–3.5): broadcast is
//! the reverse of gather, reduce shares gather's structure with an
//! associative combiner, all-reduce is reduce + broadcast.

mod schedule;

pub use schedule::{
    all_reduce_steps, broadcast_schedule, gather_schedule, reduce, CollectiveStep,
};

#[cfg(test)]
mod tests;
