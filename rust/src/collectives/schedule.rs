//! Collective schedules derived from the gather tree.

use crate::schedule::NodePlan;
use crate::sim::threaded::gather_wave_order;
use crate::topology::graph::LinkKind;
use crate::topology::ohhc::Ohhc;

/// One link traversal of a collective, tagged with its wave index
/// (traversals in the same wave are concurrent on disjoint links).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStep {
    /// Parallel wave this traversal belongs to (0-based).
    pub wave: usize,
    /// Sender flat id.
    pub src: usize,
    /// Receiver flat id.
    pub dst: usize,
    /// Link medium.
    pub kind: LinkKind,
}

/// Depth of every node in the gather tree (master = 0).
fn tree_depths(net: &Ohhc, plans: &[NodePlan]) -> Vec<usize> {
    let n = net.total_processors();
    let mut depth = vec![0usize; n];
    for id in 0..n {
        let mut cur = id;
        let mut d = 0;
        while let Some(parent) = plans[cur].last().send_to {
            cur = net.id(parent);
            d += 1;
        }
        depth[id] = d;
    }
    depth
}

/// Gather schedule: every non-master node sends its (accumulated) payload
/// to its tree parent, deepest nodes first.  Wave `w` holds the nodes at
/// depth `max_depth − w`, so a node's children always fire before it.
pub fn gather_schedule(net: &Ohhc, plans: &[NodePlan]) -> Vec<CollectiveStep> {
    let depth = tree_depths(net, plans);
    let max_depth = depth.iter().copied().max().unwrap_or(0);
    let mut steps = Vec::with_capacity(net.total_processors().saturating_sub(1));
    for id in gather_wave_order(net, plans) {
        if let Some(parent) = plans[id].last().send_to {
            let dst = net.id(parent);
            steps.push(CollectiveStep {
                wave: max_depth - depth[id],
                src: id,
                dst,
                kind: net
                    .graph()
                    .edge_kind(id, dst)
                    .expect("tree edge must be physical"),
            });
        }
    }
    steps
}

/// Broadcast (= scatter) schedule: the gather tree reversed, shallow
/// nodes first.  Identical traversal count, mirrored wave order.
pub fn broadcast_schedule(net: &Ohhc, plans: &[NodePlan]) -> Vec<CollectiveStep> {
    let depth = tree_depths(net, plans);
    let mut steps: Vec<CollectiveStep> = gather_schedule(net, plans)
        .into_iter()
        .map(|s| CollectiveStep {
            wave: depth[s.src] - 1, // parent's depth
            src: s.dst,
            dst: s.src,
            kind: s.kind,
        })
        .collect();
    steps.sort_by_key(|s| s.wave);
    steps
}

/// Execute a reduction over per-node values with combiner `f`, following
/// the gather schedule.  Returns the master's reduced value.
pub fn reduce<T: Clone>(
    net: &Ohhc,
    plans: &[NodePlan],
    values: &[T],
    mut f: impl FnMut(&T, &T) -> T,
) -> T {
    assert_eq!(values.len(), net.total_processors());
    let mut acc: Vec<T> = values.to_vec();
    for step in gather_schedule(net, plans) {
        acc[step.dst] = f(&acc[step.dst], &acc[step.src]);
    }
    acc[0].clone()
}

/// Link-traversal count of an all-reduce (reduce + broadcast) — the
/// quantity Theorem 3 bounds for the sort's scatter+gather pair, reused
/// here: `2·(G·P − 1)`.
pub fn all_reduce_steps(net: &Ohhc) -> usize {
    2 * (net.total_processors() - 1)
}
