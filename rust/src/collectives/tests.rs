//! Collective schedule validation.

use super::*;
use crate::config::Construction;
use crate::schedule::gather_plan;
use crate::topology::ohhc::Ohhc;

fn net(d: u32, c: Construction) -> Ohhc {
    Ohhc::new(d, c).unwrap()
}

#[test]
fn gather_schedule_covers_every_non_master_once() {
    for d in 1..=3 {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let n = net(d, c);
            let plans = gather_plan(&n);
            let steps = gather_schedule(&n, &plans);
            assert_eq!(steps.len(), n.total_processors() - 1, "d={d} {c:?}");
            let mut seen = std::collections::HashSet::new();
            for s in &steps {
                assert!(seen.insert(s.src), "node {} sends twice", s.src);
                assert!(n.graph().has_edge(s.src, s.dst));
            }
            assert!(!seen.contains(&0), "master must not send");
        }
    }
}

#[test]
fn waves_respect_dependencies() {
    // A node's send wave must come strictly after all its children's.
    let n = net(2, Construction::FullGroup);
    let plans = gather_plan(&n);
    let steps = gather_schedule(&n, &plans);
    let wave_of: std::collections::HashMap<usize, usize> =
        steps.iter().map(|s| (s.src, s.wave)).collect();
    for s in &steps {
        if let Some(&parent_wave) = wave_of.get(&s.dst) {
            assert!(
                s.wave < parent_wave,
                "{} (wave {}) not before parent {} (wave {})",
                s.src,
                s.wave,
                s.dst,
                parent_wave
            );
        }
    }
}

#[test]
fn broadcast_is_gather_reversed() {
    let n = net(2, Construction::HalfGroup);
    let plans = gather_plan(&n);
    let g = gather_schedule(&n, &plans);
    let b = broadcast_schedule(&n, &plans);
    assert_eq!(g.len(), b.len());
    let g_edges: std::collections::HashSet<(usize, usize)> =
        g.iter().map(|s| (s.src, s.dst)).collect();
    for s in &b {
        assert!(g_edges.contains(&(s.dst, s.src)), "{s:?} not a reversed edge");
    }
    // Broadcast waves are non-decreasing and start at the master.
    assert_eq!(b[0].src, 0);
    assert!(b.windows(2).all(|w| w[0].wave <= w[1].wave));
}

#[test]
fn broadcast_reaches_every_node() {
    let n = net(3, Construction::FullGroup);
    let plans = gather_plan(&n);
    let mut reached = vec![false; n.total_processors()];
    reached[0] = true;
    for s in broadcast_schedule(&n, &plans) {
        assert!(reached[s.src], "node {} forwards before receiving", s.src);
        reached[s.dst] = true;
    }
    assert!(reached.iter().all(|&r| r));
}

#[test]
fn reduce_computes_sum_and_max() {
    let n = net(1, Construction::FullGroup);
    let plans = gather_plan(&n);
    let values: Vec<u64> = (0..n.total_processors() as u64).collect();
    let sum = reduce(&n, &plans, &values, |a, b| a + b);
    assert_eq!(sum, (0..36).sum::<u64>());
    let max = reduce(&n, &plans, &values, |a, b| *a.max(b));
    assert_eq!(max, 35);
}

#[test]
fn reduce_is_deterministic_for_noncommutative_observation() {
    // Tree reduction fixes the combine order; same inputs → same result
    // even for a non-commutative combiner (string concat length proxy).
    let n = net(1, Construction::HalfGroup);
    let plans = gather_plan(&n);
    let values: Vec<String> = (0..n.total_processors())
        .map(|i| format!("<{i}>"))
        .collect();
    let a = reduce(&n, &plans, &values, |x, y| format!("{x}{y}"));
    let b = reduce(&n, &plans, &values, |x, y| format!("{x}{y}"));
    assert_eq!(a, b);
    // Every node's tag appears exactly once.
    for i in 0..n.total_processors() {
        assert_eq!(a.matches(&format!("<{i}>")).count(), 1, "{a}");
    }
}

#[test]
fn all_reduce_step_bound_matches_theorem3_exact_form() {
    for d in 1..=4 {
        let n = net(d, Construction::FullGroup);
        assert_eq!(
            all_reduce_steps(&n),
            crate::analysis::theorems::exact_tree_steps(n.groups, n.procs_per_group)
        );
    }
}

#[test]
fn optical_steps_in_gather_equal_nonzero_groups() {
    let n = net(2, Construction::FullGroup);
    let plans = gather_plan(&n);
    let optical = gather_schedule(&n, &plans)
        .iter()
        .filter(|s| s.kind == crate::topology::LinkKind::Optical)
        .count();
    assert_eq!(optical, n.groups - 1);
}
