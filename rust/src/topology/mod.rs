//! Interconnection-network topology library.
//!
//! Implements every network the paper depends on, bottom-up:
//!
//! * [`graph`] — generic undirected multigraph with typed (electrical /
//!   optical) links, BFS, and structural property extraction;
//! * [`hhc`] — the 1-D Hyper Hexa-Cell (two fully-connected triangles plus
//!   a perfect matching, Fig 1.1) and its d-dimensional hypercube-of-cells
//!   generalization (Fig 1.2);
//! * [`hypercube`] — the binary hypercube substrate (also a baseline);
//! * [`ohhc`] — the OTIS Hyper Hexa-Cell: `G` HHC groups joined by optical
//!   transpose links, in both `G = P` (Fig 1.3) and `G = P/2` (Fig 1.4)
//!   constructions;
//! * [`ring`], [`mesh`] — classic baselines for the ablation benches;
//! * [`routing`] — deterministic routing (intra-cell, e-cube across cells,
//!   one-hop optical across groups) validated against BFS shortest paths;
//! * [`fault`] — per-node/per-link [`FaultSet`]s with seeded, nested,
//!   connectivity-preserving generation, plus fault-aware detour routing
//!   (hop-shortest and cost-cheapest) per Ghosh et al. (arXiv:1109.1706);
//! * [`properties`] — degree / diameter / average-distance / link-census
//!   reports.

pub mod fault;
pub mod graph;
pub mod hhc;
pub mod hypercube;
pub mod mesh;
pub mod ohhc;
pub mod otis;
pub mod properties;
pub mod ring;
pub mod routing;

pub use fault::{FaultSet, RouteOutcome};
pub use graph::{Graph, LinkKind};
pub use hhc::{hhc_graph, CELL_SIZE};
pub use ohhc::{Addr, Ohhc};
pub use properties::NetworkProperties;
