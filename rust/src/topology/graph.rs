//! Generic undirected graph with typed links.
//!
//! Interconnection networks are undirected graphs `G(V, E)` where nodes are
//! processors and edges are communication channels (paper §1.3).  The OHHC
//! is *optoelectronic*, so every edge carries a [`LinkKind`].

use std::collections::VecDeque;

/// Physical medium of a link (paper §1.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Electronic link — short-distance, intra-group.
    Electrical,
    /// Optical link — long-distance, inter-group (OTIS transpose).
    Optical,
}

/// Undirected graph stored as adjacency lists of `(neighbor, kind)`.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    adj: Vec<Vec<(usize, LinkKind)>>,
    edges: usize,
}

impl Graph {
    /// Empty graph with `n` isolated nodes.
    pub fn with_nodes(n: usize) -> Self {
        Graph {
            adj: vec![Vec::new(); n],
            edges: 0,
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.adj.len()
    }

    /// True when the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.edges
    }

    /// Add an undirected edge; duplicate edges are rejected (panics) since
    /// the constructions in this crate never produce multigraphs.
    pub fn add_edge(&mut self, u: usize, v: usize, kind: LinkKind) {
        assert!(u != v, "self-loop {u}");
        assert!(
            !self.has_edge(u, v),
            "duplicate edge ({u}, {v}) — construction bug"
        );
        self.adj[u].push((v, kind));
        self.adj[v].push((u, kind));
        self.edges += 1;
    }

    /// Whether `(u, v)` is an edge.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].iter().any(|&(w, _)| w == v)
    }

    /// Link kind of edge `(u, v)` if present.
    pub fn edge_kind(&self, u: usize, v: usize) -> Option<LinkKind> {
        self.adj[u].iter().find(|&&(w, _)| w == v).map(|&(_, k)| k)
    }

    /// Neighbors of `u` with link kinds.
    pub fn neighbors(&self, u: usize) -> &[(usize, LinkKind)] {
        &self.adj[u]
    }

    /// Degree of `u`.
    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    /// BFS hop distances from `src` (`u32::MAX` = unreachable).
    pub fn bfs_distances(&self, src: usize) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.len()];
        let mut q = VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            for &(v, _) in &self.adj[u] {
                if dist[v] == u32::MAX {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }

    /// BFS shortest path from `src` to `dst` (inclusive of both ends).
    pub fn shortest_path(&self, src: usize, dst: usize) -> Option<Vec<usize>> {
        let mut prev = vec![usize::MAX; self.len()];
        let mut seen = vec![false; self.len()];
        let mut q = VecDeque::new();
        seen[src] = true;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            if u == dst {
                let mut path = vec![dst];
                let mut cur = dst;
                while cur != src {
                    cur = prev[cur];
                    path.push(cur);
                }
                path.reverse();
                return Some(path);
            }
            for &(v, _) in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    prev[v] = u;
                    q.push_back(v);
                }
            }
        }
        None
    }

    /// True if every node reaches every other.
    pub fn is_connected(&self) -> bool {
        if self.is_empty() {
            return true;
        }
        self.bfs_distances(0).iter().all(|&d| d != u32::MAX)
    }

    /// Census of edges by kind: `(electrical, optical)`.
    pub fn edge_census(&self) -> (usize, usize) {
        let mut e = 0;
        let mut o = 0;
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &(v, k) in nbrs {
                if u < v {
                    match k {
                        LinkKind::Electrical => e += 1,
                        LinkKind::Optical => o += 1,
                    }
                }
            }
        }
        (e, o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        let mut g = Graph::with_nodes(3);
        g.add_edge(0, 1, LinkKind::Electrical);
        g.add_edge(1, 2, LinkKind::Electrical);
        g.add_edge(2, 0, LinkKind::Optical);
        g
    }

    #[test]
    fn basic_structure() {
        let g = triangle();
        assert_eq!(g.len(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 2);
        assert!(g.has_edge(0, 2) && g.has_edge(2, 0));
        assert_eq!(g.edge_kind(0, 2), Some(LinkKind::Optical));
        assert_eq!(g.edge_kind(0, 1), Some(LinkKind::Electrical));
        assert_eq!(g.edge_census(), (2, 1));
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn duplicate_edges_rejected() {
        let mut g = triangle();
        g.add_edge(0, 1, LinkKind::Electrical);
    }

    #[test]
    fn bfs_and_paths() {
        // Path graph 0-1-2-3.
        let mut g = Graph::with_nodes(4);
        g.add_edge(0, 1, LinkKind::Electrical);
        g.add_edge(1, 2, LinkKind::Electrical);
        g.add_edge(2, 3, LinkKind::Electrical);
        assert_eq!(g.bfs_distances(0), vec![0, 1, 2, 3]);
        assert_eq!(g.shortest_path(0, 3).unwrap(), vec![0, 1, 2, 3]);
        assert!(g.is_connected());
    }

    #[test]
    fn disconnected_detected() {
        let mut g = Graph::with_nodes(3);
        g.add_edge(0, 1, LinkKind::Electrical);
        assert!(!g.is_connected());
        assert_eq!(g.shortest_path(0, 2), None);
        assert_eq!(g.bfs_distances(0)[2], u32::MAX);
    }
}
