//! Ring topology — classic baseline (paper §1.3 mentions Ring/Mesh/Hyper
//! Cube as the standard menu); used by the topology ablation bench.

use super::graph::{Graph, LinkKind};

/// Build an `n`-node ring (n >= 3).
pub fn ring_graph(n: usize) -> Graph {
    assert!(n >= 3, "ring needs >= 3 nodes");
    let mut g = Graph::with_nodes(n);
    for u in 0..n {
        g.add_edge(u, (u + 1) % n, LinkKind::Electrical);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_shape() {
        for n in [3, 6, 36, 144] {
            let g = ring_graph(n);
            assert_eq!(g.len(), n);
            assert_eq!(g.num_edges(), n);
            assert!(g.is_connected());
            for u in 0..n {
                assert_eq!(g.degree(u), 2);
            }
        }
    }

    #[test]
    fn ring_diameter_is_half_n() {
        for n in [6usize, 7, 36] {
            let g = ring_graph(n);
            let diam = g.bfs_distances(0).into_iter().max().unwrap();
            assert_eq!(diam as usize, n / 2);
        }
    }
}
