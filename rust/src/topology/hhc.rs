//! The Hyper Hexa-Cell (HHC) — paper §1.4, Figs 1.1 / 1.2.
//!
//! A **1-D HHC** is six processors in two fully-connected triangles,
//! `{0,1,2}` and `{3,4,5}`, plus a perfect matching between the triangles.
//! The matching we use is `(0,5), (1,3), (2,4)` — exactly the links the
//! paper's aggregation rules traverse in one hop (Fig 3.1: node 5 sends
//! *directly* to node 0, node 3 to node 1, node 4 to node 2).
//!
//! A **d-dimensional HHC** replaces every vertex of a `(d-1)`-dimensional
//! hypercube with a 1-D HHC; for each hypercube edge, corresponding nodes
//! of the two cells are joined (node `i` of cell `c` ↔ node `i` of cell
//! `c ⊕ 2^k`).  Node count: `6 · 2^(d-1)`.

use super::graph::{Graph, LinkKind};

/// Nodes per 1-D hexa-cell.
pub const CELL_SIZE: usize = 6;

/// Intra-triangle + matching edges of one hexa-cell, as `(u, v)` offsets.
pub const CELL_EDGES: [(usize, usize); 9] = [
    // triangle A
    (0, 1),
    (0, 2),
    (1, 2),
    // triangle B
    (3, 4),
    (3, 5),
    (4, 5),
    // matching used by the paper's Fig 3.1 one-hop sends
    (0, 5),
    (1, 3),
    (2, 4),
];

/// Number of hexa-cells in a d-dimensional HHC: `2^(d-1)`.
pub fn num_cells(dimension: u32) -> usize {
    assert!(dimension >= 1, "HHC dimension starts at 1");
    1 << (dimension - 1)
}

/// Number of processors in a d-dimensional HHC: `6 · 2^(d-1)` (paper §1.4).
pub fn num_nodes(dimension: u32) -> usize {
    CELL_SIZE * num_cells(dimension)
}

/// Build a d-dimensional HHC graph.  Node index = `cell * 6 + hhc_node`.
pub fn hhc_graph(dimension: u32) -> Graph {
    let cells = num_cells(dimension);
    let mut g = Graph::with_nodes(CELL_SIZE * cells);
    for c in 0..cells {
        let base = c * CELL_SIZE;
        // Hexa-cell internal wiring.
        for &(u, v) in &CELL_EDGES {
            g.add_edge(base + u, base + v, LinkKind::Electrical);
        }
        // Hypercube wiring between cells: connect corresponding nodes of
        // cells differing in one bit (add each edge once: c < partner).
        let cube_dims = dimension - 1;
        for k in 0..cube_dims {
            let partner = c ^ (1 << k);
            if c < partner {
                for i in 0..CELL_SIZE {
                    g.add_edge(base + i, partner * CELL_SIZE + i, LinkKind::Electrical);
                }
            }
        }
    }
    g
}

/// Split an intra-group node index into `(cell, hhc_node)`.
pub fn split(node: usize) -> (usize, usize) {
    (node / CELL_SIZE, node % CELL_SIZE)
}

/// Join `(cell, hhc_node)` into an intra-group node index.
pub fn join(cell: usize, hhc_node: usize) -> usize {
    debug_assert!(hhc_node < CELL_SIZE);
    cell * CELL_SIZE + hhc_node
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_dimensional_hhc_shape() {
        let g = hhc_graph(1);
        assert_eq!(g.len(), 6);
        assert_eq!(g.num_edges(), 9); // 3 + 3 + 3 (Fig 1.1)
        // Every node has degree 3: two triangle peers + one matching peer.
        for u in 0..6 {
            assert_eq!(g.degree(u), 3, "node {u}");
        }
        // The matching the aggregation rules use (Fig 3.1).
        assert!(g.has_edge(0, 5));
        assert!(g.has_edge(1, 3));
        assert!(g.has_edge(2, 4));
        // Triangles are complete.
        assert!(g.has_edge(0, 1) && g.has_edge(0, 2) && g.has_edge(1, 2));
        assert!(g.has_edge(3, 4) && g.has_edge(3, 5) && g.has_edge(4, 5));
        // No triangle-A node links to a non-matched triangle-B node.
        assert!(!g.has_edge(0, 3) && !g.has_edge(0, 4));
    }

    #[test]
    fn node_counts_match_paper() {
        // 6 · 2^(d-1): the per-group column implied by Table 1.1.
        assert_eq!(num_nodes(1), 6);
        assert_eq!(num_nodes(2), 12);
        assert_eq!(num_nodes(3), 24);
        assert_eq!(num_nodes(4), 48);
    }

    #[test]
    fn multi_dimensional_structure() {
        for d in 1..=4 {
            let g = hhc_graph(d);
            assert_eq!(g.len(), num_nodes(d));
            assert!(g.is_connected(), "d={d} disconnected");
            // Edge count: 9 per cell + 6 per hypercube edge.
            let cells = num_cells(d);
            let cube_edges = cells * (d as usize - 1) / 2;
            assert_eq!(g.num_edges(), 9 * cells + 6 * cube_edges, "d={d}");
            // All links inside an HHC group are electrical (paper §1.5).
            assert_eq!(g.edge_census().1, 0, "d={d} has optical links");
        }
    }

    #[test]
    fn degree_is_3_plus_cube_dims() {
        for d in 1..=4u32 {
            let g = hhc_graph(d);
            for u in 0..g.len() {
                assert_eq!(g.degree(u), 3 + (d as usize - 1), "d={d} node {u}");
            }
        }
    }

    #[test]
    fn one_d_hhc_diameter_is_2() {
        let g = hhc_graph(1);
        let diam = (0..6)
            .map(|u| g.bfs_distances(u).into_iter().max().unwrap())
            .max()
            .unwrap();
        assert_eq!(diam, 2);
    }

    #[test]
    fn split_join_round_trip() {
        for node in 0..num_nodes(3) {
            let (c, i) = split(node);
            assert_eq!(join(c, i), node);
            assert!(i < CELL_SIZE);
        }
    }
}
