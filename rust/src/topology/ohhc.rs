//! The OTIS Hyper Hexa-Cell (OHHC) — paper §1.5, Figs 1.3 / 1.4, Table 1.1.
//!
//! `G` copies ("groups") of a d-dimensional HHC are joined by **optical**
//! transpose links while every intra-group link stays **electrical**:
//!
//! * **G = P (full)** — the classic OTIS rule: processor `p` of group `g`
//!   is optically linked to processor `g` of group `p` (for `g ≠ p`;
//!   `g = p` nodes have no optical link, as in OTIS-Mesh et al.).
//! * **G = P/2 (half)** — only half the groups exist.  Processors
//!   `p < G` keep the transpose rule; processors `p ≥ G` are paired by the
//!   involution `(g, p) ↔ (p − G, g + G)` so every processor still owns at
//!   most one optical link and the graph stays symmetric.  (The paper
//!   borrows the construction from Mahafzah et al. \[3\] without spelling
//!   out the high-half wiring; DESIGN.md §3 records this choice.  The
//!   sorting algorithm itself only ever uses the `(g,0) ↔ (0,g)` links,
//!   which exist identically in both constructions.)

use super::graph::{Graph, LinkKind};
use super::hhc;
use crate::config::Construction;
use crate::error::{Error, Result};

/// A processor address inside an OHHC: group, hexa-cell, node-in-cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Addr {
    /// OTIS group index (`0..G`).
    pub group: usize,
    /// Hexa-cell index within the group's HHC (`0..2^(d-1)`) — the paper's
    /// `HyperCubeNodeId` / "HHC group" in Figs 3.2/3.4.
    pub cell: usize,
    /// Node within the hexa-cell (`0..6`) — the paper's `HHCNodeId`.
    pub node: usize,
}

impl Addr {
    /// Processor index within its group (`cell * 6 + node`) — the paper's
    /// `OTISNodeId`.
    pub fn local(&self) -> usize {
        hhc::join(self.cell, self.node)
    }

    /// Head of the whole machine: group 0, cell 0, node 0.
    pub fn is_master(&self) -> bool {
        self.group == 0 && self.cell == 0 && self.node == 0
    }
}

/// An OHHC instance: topology graph + addressing + optical pairing.
#[derive(Debug, Clone)]
pub struct Ohhc {
    /// HHC dimension `d_h`.
    pub dimension: u32,
    /// Construction rule (G = P or G = P/2).
    pub construction: Construction,
    /// Number of groups `G`.
    pub groups: usize,
    /// Processors per group `P`.
    pub procs_per_group: usize,
    graph: Graph,
}

impl Ohhc {
    /// Build the OHHC for a dimension and construction rule.
    pub fn new(dimension: u32, construction: Construction) -> Result<Self> {
        if !(1..=6).contains(&dimension) {
            return Err(Error::Config(format!("bad OHHC dimension {dimension}")));
        }
        let p = hhc::num_nodes(dimension);
        let groups = construction.groups(p);
        let total = groups * p;
        let mut graph = Graph::with_nodes(total);

        // Electrical intra-group wiring: one HHC per group.
        let cell_graph = hhc::hhc_graph(dimension);
        for g in 0..groups {
            let base = g * p;
            for u in 0..p {
                for &(v, kind) in cell_graph.neighbors(u) {
                    if u < v {
                        graph.add_edge(base + u, base + v, kind);
                    }
                }
            }
        }

        // Optical inter-group wiring.
        let ohhc = Ohhc {
            dimension,
            construction,
            groups,
            procs_per_group: p,
            graph,
        };
        let mut graph = ohhc.graph;
        for g in 0..groups {
            for pr in 0..p {
                if let Some((g2, p2)) = optical_partner(g, pr, groups, p) {
                    let a = g * p + pr;
                    let b = g2 * p + p2;
                    if a < b {
                        graph.add_edge(a, b, LinkKind::Optical);
                    }
                }
            }
        }
        Ok(Ohhc { graph, ..ohhc })
    }

    /// Total processors (`G · P`, Table 1.1).
    pub fn total_processors(&self) -> usize {
        self.groups * self.procs_per_group
    }

    /// The underlying graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Flat node id of an address.
    pub fn id(&self, a: Addr) -> usize {
        debug_assert!(a.group < self.groups && a.local() < self.procs_per_group);
        a.group * self.procs_per_group + a.local()
    }

    /// Address of a flat node id.
    pub fn addr(&self, id: usize) -> Addr {
        let group = id / self.procs_per_group;
        let local = id % self.procs_per_group;
        let (cell, node) = hhc::split(local);
        Addr { group, cell, node }
    }

    /// Optical partner of a processor, if it has one.
    pub fn optical_partner(&self, a: Addr) -> Option<Addr> {
        optical_partner(a.group, a.local(), self.groups, self.procs_per_group).map(
            |(g, p)| {
                let (cell, node) = hhc::split(p);
                Addr {
                    group: g,
                    cell,
                    node,
                }
            },
        )
    }

    /// Number of hexa-cells per group.
    pub fn cells_per_group(&self) -> usize {
        hhc::num_cells(self.dimension)
    }
}

/// The optical pairing rule; returns the partner `(group, processor)`.
fn optical_partner(g: usize, p: usize, groups: usize, procs: usize) -> Option<(usize, usize)> {
    if groups == procs {
        // Full OTIS transpose: (g, p) <-> (p, g), fixed points excluded.
        if g == p {
            None
        } else {
            Some((p, g))
        }
    } else {
        // Half construction, G = P/2.
        debug_assert_eq!(groups * 2, procs);
        if p < groups {
            if g == p {
                None
            } else {
                Some((p, g))
            }
        } else {
            // High-half involution: (g, p) <-> (p - G, g + G).
            let (g2, p2) = (p - groups, g + groups);
            if (g2, p2) == (g, p) {
                None
            } else {
                Some((g2, p2))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_1_1_processor_counts() {
        for (d, total_full, total_half) in
            [(1, 36, 18), (2, 144, 72), (3, 576, 288), (4, 2304, 1152)]
        {
            let full = Ohhc::new(d, Construction::FullGroup).unwrap();
            assert_eq!(full.total_processors(), total_full, "d={d} full");
            let half = Ohhc::new(d, Construction::HalfGroup).unwrap();
            assert_eq!(half.total_processors(), total_half, "d={d} half");
        }
    }

    #[test]
    fn connected_and_optical_census() {
        for d in 1..=3 {
            for c in [Construction::FullGroup, Construction::HalfGroup] {
                let net = Ohhc::new(d, c).unwrap();
                assert!(net.graph().is_connected(), "d={d} {c:?}");
                let (elec, opt) = net.graph().edge_census();
                // Electrical edges: G copies of the HHC's edge count.
                let cell_edges = hhc::hhc_graph(d).num_edges();
                assert_eq!(elec, net.groups * cell_edges, "d={d} {c:?} electrical");
                // Optical: every processor has <= 1 optical link; in the
                // full construction exactly G fixed points (g == p) are
                // unpaired; the half construction has G low-half fixed
                // points (g == p) plus G high-half ones ((g, g + G)).
                let expected_unpaired = match c {
                    Construction::FullGroup => net.groups,
                    Construction::HalfGroup => 2 * net.groups,
                };
                let expected_opt = (net.total_processors() - expected_unpaired) / 2;
                assert_eq!(opt, expected_opt, "d={d} {c:?} optical");
            }
        }
    }

    #[test]
    fn optical_pairing_is_an_involution() {
        for d in 1..=3 {
            for c in [Construction::FullGroup, Construction::HalfGroup] {
                let net = Ohhc::new(d, c).unwrap();
                for id in 0..net.total_processors() {
                    let a = net.addr(id);
                    if let Some(b) = net.optical_partner(a) {
                        assert_ne!(a, b);
                        assert_eq!(
                            net.optical_partner(b),
                            Some(a),
                            "{a:?} <-> {b:?} not symmetric"
                        );
                        // And the graph agrees.
                        assert_eq!(
                            net.graph().edge_kind(net.id(a), net.id(b)),
                            Some(LinkKind::Optical)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn algorithm_links_exist_in_both_constructions() {
        // Fig 3.3 requires (g, 0) <-> (0, g) for every non-zero group.
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let net = Ohhc::new(2, c).unwrap();
            for g in 1..net.groups {
                let head = Addr {
                    group: g,
                    cell: 0,
                    node: 0,
                };
                let partner = net.optical_partner(head).unwrap();
                assert_eq!(partner.group, 0, "{c:?} g={g}");
                assert_eq!(partner.local(), g, "{c:?} g={g}");
            }
        }
    }

    #[test]
    fn addr_round_trip() {
        let net = Ohhc::new(3, Construction::HalfGroup).unwrap();
        for id in 0..net.total_processors() {
            let a = net.addr(id);
            assert_eq!(net.id(a), id);
            assert!(a.node < 6);
            assert!(a.cell < net.cells_per_group());
            assert!(a.group < net.groups);
        }
        assert!(net.addr(0).is_master());
        assert!(!net.addr(1).is_master());
    }

    #[test]
    fn intra_group_links_electrical_inter_group_optical() {
        let net = Ohhc::new(2, Construction::FullGroup).unwrap();
        let g = net.graph();
        for u in 0..net.total_processors() {
            for &(v, kind) in g.neighbors(u) {
                let same_group = net.addr(u).group == net.addr(v).group;
                match kind {
                    LinkKind::Electrical => assert!(same_group),
                    LinkKind::Optical => assert!(!same_group),
                }
            }
        }
    }
}
