//! Generic OTIS factory: `OTIS(F)` takes any electronic *factor network*
//! `F` with `P` nodes and builds `P` optically-transposed copies —
//! processor `p` of group `g` links to processor `g` of group `p`.
//!
//! The OHHC of this paper is `OTIS(HHC)`; the literature it builds on
//! (Mahafzah et al. \[3\]) compares against `OTIS(Mesh)` and
//! `OTIS(Hypercube)`, so those are provided as comparators for the
//! topology bench and the §1.5 connectivity discussion.

use super::graph::{Graph, LinkKind};
use super::hypercube::hypercube_graph;
use super::mesh::mesh_graph;

/// Build `OTIS(factor)`: `P` groups of the `P`-node factor network plus
/// the optical transpose.  Node id = `group * P + local`.
pub fn otis_graph(factor: &Graph) -> Graph {
    let p = factor.len();
    let mut g = Graph::with_nodes(p * p);
    // Electronic copies.
    for group in 0..p {
        let base = group * p;
        for u in 0..p {
            for &(v, kind) in factor.neighbors(u) {
                if u < v {
                    g.add_edge(base + u, base + v, kind);
                }
            }
        }
    }
    // Optical transpose: (g, p) <-> (p, g), fixed points excluded.
    for group in 0..p {
        for local in group + 1..p {
            g.add_edge(group * p + local, local * p + group, LinkKind::Optical);
        }
    }
    g
}

/// `OTIS(Mesh_{r×c})` — the classic OTIS-Mesh (square factor required by
/// the transpose, so `r·c` groups of `r·c` processors).
pub fn otis_mesh(rows: usize, cols: usize) -> Graph {
    otis_graph(&mesh_graph(rows, cols))
}

/// `OTIS(Q_d)` — OTIS-Hypercube with `2^d` groups of `2^d` processors.
pub fn otis_hypercube(dims: u32) -> Graph {
    otis_graph(&hypercube_graph(dims))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;
    use crate::topology::ohhc::Ohhc;
    use crate::topology::properties::NetworkProperties;

    #[test]
    fn otis_shape_and_census() {
        let factor = mesh_graph(2, 3); // 6 nodes, 7 edges
        let g = otis_graph(&factor);
        assert_eq!(g.len(), 36);
        let (elec, opt) = g.edge_census();
        assert_eq!(elec, 6 * 7); // one factor copy per group
        assert_eq!(opt, (36 - 6) / 2); // transpose minus fixed points
        assert!(g.is_connected());
    }

    #[test]
    fn otis_hhc_equals_paper_full_construction() {
        // OTIS(HHC_d) built by the generic factory must be isomorphic (in
        // fact identical under our labeling) to the crate's G = P OHHC.
        for d in 1..=2u32 {
            let ohhc = Ohhc::new(d, Construction::FullGroup).unwrap();
            let generic = otis_graph(&crate::topology::hhc::hhc_graph(d));
            assert_eq!(generic.len(), ohhc.graph().len());
            assert_eq!(generic.num_edges(), ohhc.graph().num_edges());
            for u in 0..generic.len() {
                for &(v, kind) in generic.neighbors(u) {
                    assert_eq!(
                        ohhc.graph().edge_kind(u, v),
                        Some(kind),
                        "d={d} edge ({u},{v})"
                    );
                }
            }
        }
    }

    #[test]
    fn otis_transpose_is_an_involution() {
        let g = otis_hypercube(3); // 8x8 = 64 nodes
        for group in 0..8usize {
            for local in 0..8usize {
                if group == local {
                    continue;
                }
                assert!(g.has_edge(group * 8 + local, local * 8 + group));
            }
        }
    }

    #[test]
    fn ohhc_diameter_competitive_with_otis_mesh_at_same_size() {
        // 36-processor comparison: OTIS(HHC_1) vs OTIS(Mesh_2x3).
        let ohhc = NetworkProperties::compute(
            Ohhc::new(1, Construction::FullGroup).unwrap().graph(),
        );
        let omesh = NetworkProperties::compute(&otis_mesh(2, 3));
        assert_eq!(ohhc.nodes, omesh.nodes);
        // The hexa-cell factor (diameter 2) beats the 2x3 mesh factor
        // (diameter 3), which the OTIS construction doubles.
        assert!(ohhc.diameter <= omesh.diameter, "{} vs {}", ohhc.diameter, omesh.diameter);
    }

    #[test]
    fn otis_hypercube_diameter() {
        // OTIS(Q_d) diameter is 2·d + 1 (factor diameter twice + optical).
        for d in 1..=3u32 {
            let p = NetworkProperties::compute(&otis_hypercube(d));
            assert_eq!(p.diameter, 2 * d + 1, "d={d}");
        }
    }
}
