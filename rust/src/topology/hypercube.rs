//! Binary hypercube — substrate of the d-D HHC construction and a
//! comparison baseline for the ablation benches.

use super::graph::{Graph, LinkKind};

/// Build a `d`-dimensional hypercube (`2^d` nodes, `d·2^(d-1)` edges).
pub fn hypercube_graph(dims: u32) -> Graph {
    let n = 1usize << dims;
    let mut g = Graph::with_nodes(n);
    for u in 0..n {
        for k in 0..dims {
            let v = u ^ (1 << k);
            if u < v {
                g.add_edge(u, v, LinkKind::Electrical);
            }
        }
    }
    g
}

/// Index (1-based) of the least-significant set bit — the paper's
/// `GetMyFirstLeastSignificantBit()` in Fig 3.2.  Returns 0 for input 0.
pub fn first_set_bit(x: usize) -> u32 {
    if x == 0 {
        0
    } else {
        x.trailing_zeros() + 1
    }
}

/// Hypercube reduction target: clear the least-significant set bit
/// (paper Fig 3.2: `sendToHHC ← id - 2^(fsb-1)`).
pub fn reduction_parent(x: usize) -> usize {
    debug_assert!(x > 0, "node 0 is the reduction root");
    x & (x - 1)
}

/// Hops of the dimension-order (e-cube) route between two cube nodes.
pub fn ecube_route(src: usize, dst: usize) -> Vec<usize> {
    let mut path = vec![src];
    let mut cur = src;
    let mut diff = src ^ dst;
    while diff != 0 {
        let k = diff.trailing_zeros();
        cur ^= 1 << k;
        diff &= diff - 1;
        path.push(cur);
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube_shape() {
        for d in 0..=5u32 {
            let g = hypercube_graph(d);
            assert_eq!(g.len(), 1 << d);
            assert_eq!(g.num_edges(), (d as usize) << (d.saturating_sub(1)));
            assert!(g.is_connected());
            for u in 0..g.len() {
                assert_eq!(g.degree(u), d as usize);
            }
        }
    }

    #[test]
    fn cube_diameter_is_d() {
        for d in 1..=5u32 {
            let g = hypercube_graph(d);
            let max = g.bfs_distances(0).into_iter().max().unwrap();
            assert_eq!(max, d);
        }
    }

    #[test]
    fn fsb_matches_paper_numbering() {
        // Fig 3.2's fsb is 1-based: fsb(1)=1, fsb(2)=2, fsb(4)=3, fsb(6)=2.
        assert_eq!(first_set_bit(1), 1);
        assert_eq!(first_set_bit(2), 2);
        assert_eq!(first_set_bit(4), 3);
        assert_eq!(first_set_bit(6), 2);
        assert_eq!(first_set_bit(0), 0);
    }

    #[test]
    fn reduction_reaches_zero() {
        // Every node's parent chain terminates at 0 and each hop clears
        // exactly the lowest set bit (Fig 3.2's send rule).
        for start in 1..64usize {
            let mut cur = start;
            let mut hops = 0;
            while cur != 0 {
                let parent = reduction_parent(cur);
                assert_eq!(parent, cur - (1 << (first_set_bit(cur) - 1)));
                cur = parent;
                hops += 1;
                assert!(hops <= 6);
            }
            assert_eq!(hops as u32, start.count_ones());
        }
    }

    #[test]
    fn ecube_route_is_shortest() {
        let g = hypercube_graph(4);
        for src in 0..16 {
            for dst in 0..16 {
                let route = ecube_route(src, dst);
                assert_eq!(route[0], src);
                assert_eq!(*route.last().unwrap(), dst);
                assert_eq!(route.len() - 1, (src ^ dst).count_ones() as usize);
                for w in route.windows(2) {
                    assert!(g.has_edge(w[0], w[1]), "{} -> {}", w[0], w[1]);
                }
            }
        }
    }
}
