//! Structural property extraction for any topology — backs the README's
//! architecture table, the topology ablation bench, and the DESIGN.md
//! cost/performance comparison of OHHC vs classic networks.

use super::graph::Graph;
use crate::util::par;

/// Summary of a network's static structure.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProperties {
    /// Node count.
    pub nodes: usize,
    /// Undirected edge count.
    pub edges: usize,
    /// Electrical edge count.
    pub electrical_edges: usize,
    /// Optical edge count.
    pub optical_edges: usize,
    /// Minimum node degree.
    pub min_degree: usize,
    /// Maximum node degree.
    pub max_degree: usize,
    /// Graph diameter in hops.
    pub diameter: u32,
    /// Mean shortest-path length over ordered pairs (u != v).
    pub avg_distance: f64,
    /// `nodes × diameter` — the classic cost metric for interconnects.
    pub cost: u64,
}

impl NetworkProperties {
    /// Compute all properties (all-pairs BFS, parallelized over sources).
    pub fn compute(g: &Graph) -> Self {
        let n = g.len();
        assert!(n > 0, "empty graph");
        let (electrical_edges, optical_edges) = g.edge_census();
        let degrees: Vec<usize> = (0..n).map(|u| g.degree(u)).collect();

        let (diameter, total): (u32, u64) = par::par_reduce_indices(
            n,
            par::available_workers(),
            |range| {
                let mut max = 0u32;
                let mut sum = 0u64;
                for u in range {
                    for &d in &g.bfs_distances(u) {
                        assert_ne!(d, u32::MAX, "graph is disconnected at {u}");
                        max = max.max(d);
                        sum += d as u64;
                    }
                }
                (max, sum)
            },
            |a, b| (a.0.max(b.0), a.1 + b.1),
            (0, 0),
        );

        let pairs = (n * (n - 1)) as f64;
        NetworkProperties {
            nodes: n,
            edges: g.num_edges(),
            electrical_edges,
            optical_edges,
            min_degree: *degrees.iter().min().unwrap(),
            max_degree: *degrees.iter().max().unwrap(),
            diameter,
            avg_distance: if n > 1 { total as f64 / pairs } else { 0.0 },
            cost: n as u64 * diameter as u64,
        }
    }
}

impl std::fmt::Display for NetworkProperties {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "nodes={} edges={} (elec={}, opt={}) degree={}..{} diameter={} \
             avg_dist={:.3} cost={}",
            self.nodes,
            self.edges,
            self.electrical_edges,
            self.optical_edges,
            self.min_degree,
            self.max_degree,
            self.diameter,
            self.avg_distance,
            self.cost
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;
    use crate::topology::{hhc, hypercube, ohhc::Ohhc, ring};

    #[test]
    fn hexa_cell_properties() {
        let p = NetworkProperties::compute(&hhc::hhc_graph(1));
        assert_eq!(p.nodes, 6);
        assert_eq!(p.edges, 9);
        assert_eq!(p.min_degree, 3);
        assert_eq!(p.max_degree, 3);
        assert_eq!(p.diameter, 2);
    }

    #[test]
    fn group_diameter_is_d_plus_1() {
        // Intra-group diameter d+1 — the quantity Theorem 6 uses.
        for d in 1..=4u32 {
            let p = NetworkProperties::compute(&hhc::hhc_graph(d));
            assert_eq!(p.diameter, d + 1, "d={d}");
        }
    }

    #[test]
    fn hypercube_properties() {
        let p = NetworkProperties::compute(&hypercube::hypercube_graph(4));
        assert_eq!(p.nodes, 16);
        assert_eq!(p.diameter, 4);
        assert_eq!(p.min_degree, 4);
    }

    #[test]
    fn ohhc_diameter_beats_ring_at_same_size() {
        // The optical transpose keeps the OHHC diameter ~constant while a
        // ring of 36 nodes has diameter 18 — the paper's connectivity
        // motivation in §1.5.
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let po = NetworkProperties::compute(net.graph());
        let pr = NetworkProperties::compute(&ring::ring_graph(po.nodes));
        assert!(po.diameter < pr.diameter / 2);
        assert_eq!(po.nodes, 36);
    }

    #[test]
    #[should_panic(expected = "disconnected")]
    fn disconnected_graph_panics() {
        let g = Graph::with_nodes(2);
        NetworkProperties::compute(&g);
    }
}
