//! Deterministic OHHC routing.
//!
//! The intra-group network is the Cartesian product `HHC_cell × Q_(d-1)`
//! (hexa-cell crossed with a binary hypercube), so dimension-order routing
//! — cube coordinates first, then the ≤2-hop hexa-cell correction — is
//! provably shortest inside a group.  Between groups the standard OTIS
//! scheme applies: route electrically to processor `g2` inside the source
//! group, take the single optical transpose hop `(g1, g2) → (g2, g1)`,
//! then route electrically to the destination processor.
//!
//! `route()` is validated against BFS shortest paths in the tests.

use super::graph::Graph;
use super::hhc::{self, CELL_SIZE};
use super::hypercube;
use super::ohhc::{Addr, Ohhc};

/// Shortest route between two nodes *within* one hexa-cell (0–2 hops),
/// as intra-cell node indices (inclusive of endpoints).
pub fn cell_route(from: usize, to: usize) -> Vec<usize> {
    debug_assert!(from < CELL_SIZE && to < CELL_SIZE);
    if from == to {
        return vec![from];
    }
    if cell_adjacent(from, to) {
        return vec![from, to];
    }
    // Hexa-cell diameter is 2: find the (unique smallest) common neighbor.
    for mid in 0..CELL_SIZE {
        if mid != from && mid != to && cell_adjacent(from, mid) && cell_adjacent(mid, to) {
            return vec![from, mid, to];
        }
    }
    unreachable!("hexa-cell diameter is 2; no common neighbor of {from},{to}")
}

/// Adjacency within one hexa-cell (triangles + matching, Fig 1.1).
pub fn cell_adjacent(a: usize, b: usize) -> bool {
    hhc::CELL_EDGES
        .iter()
        .any(|&(u, v)| (u, v) == (a.min(b), b.max(a)))
}

/// Shortest route between two processors of the *same group*, as
/// intra-group processor indices.  Cube dimensions first, then the
/// hexa-cell correction; shortest because the group is a product graph.
pub fn group_route(from: usize, to: usize) -> Vec<usize> {
    let (c1, n1) = hhc::split(from);
    let (c2, n2) = hhc::split(to);
    let mut path: Vec<usize> = hypercube::ecube_route(c1, c2)
        .into_iter()
        .map(|c| hhc::join(c, n1))
        .collect();
    for &n in cell_route(n1, n2).iter().skip(1) {
        path.push(hhc::join(c2, n));
    }
    path
}

/// Full OHHC route between two processors, as flat node ids.
///
/// Same-group routes stay electrical.  Inter-group routes pick the shorter
/// of the two classic OTIS strategies (cf. OTIS-Mesh routing):
///
/// * **window** — electrical to the transpose window (processor
///   `dst.group`), one optical hop, electrical to the destination:
///   `d(p₁, g₂) + 1 + d(g₁, p₂)` links;
/// * **double-transpose** — optical immediately (`(g₁,p₁) → (p₁,g₁)`),
///   electrical across that group, optical again into the target group:
///   `1 + d(g₁, g₂) + 1 + d(p₁, p₂)` links (only when both optical links
///   exist — they always do in `G = P`; the half construction's high-half
///   processors fall back to the window route).
///
/// The paper's algorithm itself only uses window routes (Fig 3.3); the
/// double-transpose matters for the generic message-delay model and the
/// routing benchmarks.
pub fn route(net: &Ohhc, src: Addr, dst: Addr) -> Vec<usize> {
    let p = net.procs_per_group;
    if src.group == dst.group {
        return group_route(src.local(), dst.local())
            .into_iter()
            .map(|l| src.group * p + l)
            .collect();
    }

    // Strategy 1: window route (always available).
    let mut window: Vec<usize> = group_route(src.local(), dst.group)
        .into_iter()
        .map(|l| src.group * p + l)
        .collect();
    // Optical hop (src.group, dst.group) -> (dst.group, src.group).
    window.push(dst.group * p + src.group);
    for &l in group_route(src.group, dst.local()).iter().skip(1) {
        window.push(dst.group * p + l);
    }

    // Strategy 2: double transpose, when the optical links line up.
    let double = double_transpose_route(net, src, dst);
    match double {
        Some(d) if d.len() < window.len() => d,
        _ => window,
    }
}

/// The early-transpose route `src -opt-> (p₁,g₁) -elec-> (p₁,g₂) -opt->
/// (g₂,p₁) -elec-> dst`, if every optical hop exists.
fn double_transpose_route(net: &Ohhc, src: Addr, dst: Addr) -> Option<Vec<usize>> {
    let p = net.procs_per_group;
    let first = net.optical_partner(src)?;
    // The early transpose must land us in group `src.local()` holding
    // processor index `src.group` — true for the low-half transpose rule,
    // not for high-half pairs, which we simply skip.
    if first.group != src.local() || first.local() != src.group {
        return None;
    }
    let mut path: Vec<usize> = vec![net.id(src)];
    // Electrical within group p1: g1 -> g2.
    for &l in group_route(src.group, dst.group).iter() {
        let id = first.group * p + l;
        if *path.last().unwrap() != id {
            path.push(id);
        }
    }
    // Second optical hop: (p1, g2) -> (g2, p1).
    let mid = net.addr(first.group * p + dst.group);
    let second = net.optical_partner(mid)?;
    if second.group != dst.group || second.local() != src.local() {
        return None;
    }
    path.push(net.id(second));
    // Electrical within the destination group: p1 -> p2.
    for &l in group_route(src.local(), dst.local()).iter().skip(1) {
        path.push(dst.group * p + l);
    }
    Some(path)
}

/// Check a path is walkable on a graph (every hop is an edge).
pub fn path_is_valid(g: &Graph, path: &[usize]) -> bool {
    path.windows(2).all(|w| g.has_edge(w[0], w[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;

    #[test]
    fn cell_routes_are_shortest() {
        let g = hhc::hhc_graph(1);
        for a in 0..CELL_SIZE {
            for b in 0..CELL_SIZE {
                let r = cell_route(a, b);
                assert_eq!(r[0], a);
                assert_eq!(*r.last().unwrap(), b);
                assert!(path_is_valid(&g, &r), "{a}->{b}");
                assert_eq!(r.len() as u32 - 1, g.bfs_distances(a)[b], "{a}->{b}");
            }
        }
    }

    #[test]
    fn group_routes_are_shortest() {
        for d in 1..=3u32 {
            let g = hhc::hhc_graph(d);
            let n = g.len();
            for a in 0..n {
                let dist = g.bfs_distances(a);
                for b in 0..n {
                    let r = group_route(a, b);
                    assert!(path_is_valid(&g, &r), "d={d} {a}->{b}");
                    assert_eq!(r.len() as u32 - 1, dist[b], "d={d} {a}->{b}");
                }
            }
        }
    }

    #[test]
    fn ohhc_routes_are_valid_and_tight() {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            for d in 1..=2u32 {
                let net = Ohhc::new(d, c).unwrap();
                let g = net.graph();
                let n = net.total_processors();
                // Sample src nodes to keep the test fast.
                for src_id in (0..n).step_by(7) {
                    let dist = g.bfs_distances(src_id);
                    for dst_id in (0..n).step_by(5) {
                        let r = route(&net, net.addr(src_id), net.addr(dst_id));
                        assert!(path_is_valid(g, &r), "{c:?} d={d} {src_id}->{dst_id}");
                        assert_eq!(r[0], src_id);
                        assert_eq!(*r.last().unwrap(), dst_id);
                        let hops = (r.len() - 1) as u32;
                        // G = P: the min(window, double-transpose) router
                        // is near-optimal (≤ shortest + 2).  The half
                        // construction's high-half optical links create
                        // shortcuts the deterministic router deliberately
                        // ignores (the algorithm never uses them), so only
                        // the analytic worst case is asserted there.
                        if c == Construction::FullGroup {
                            assert!(
                                hops <= dist[dst_id] + 2,
                                "{c:?} d={d} {src_id}->{dst_id}: {hops} vs {}",
                                dist[dst_id]
                            );
                        }
                        // Never beyond the analytic worst case
                        // 2·diam(group) + 1 = 2(d+1) + 1 (Theorem 6).
                        assert!(hops <= 2 * (d + 1) + 1);
                    }
                }
            }
        }
    }

    #[test]
    fn same_group_routes_have_no_optical_hop() {
        let net = Ohhc::new(2, Construction::FullGroup).unwrap();
        // Both addresses inside group 1 (locals 1 and 10).
        let src = net.addr(13);
        let dst = net.addr(22);
        let r = route(&net, src, dst);
        for w in r.windows(2) {
            assert_eq!(
                net.graph().edge_kind(w[0], w[1]),
                Some(crate::topology::LinkKind::Electrical)
            );
        }
    }

    #[test]
    fn cross_group_routes_have_exactly_one_optical_hop() {
        let net = Ohhc::new(2, Construction::HalfGroup).unwrap();
        for (s, t) in [(0usize, 70usize), (15, 40), (60, 3)] {
            let (src, dst) = (net.addr(s), net.addr(t));
            if src.group == dst.group {
                continue;
            }
            let r = route(&net, src, dst);
            let optical = r
                .windows(2)
                .filter(|w| {
                    net.graph().edge_kind(w[0], w[1])
                        == Some(crate::topology::LinkKind::Optical)
                })
                .count();
            assert_eq!(optical, 1, "{s}->{t}");
        }
    }
}
