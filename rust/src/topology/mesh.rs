//! 2-D mesh topology — classic baseline for the ablation benches.

use super::graph::{Graph, LinkKind};

/// Build a `rows × cols` 2-D mesh (no wraparound).
pub fn mesh_graph(rows: usize, cols: usize) -> Graph {
    assert!(rows >= 1 && cols >= 1);
    let mut g = Graph::with_nodes(rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            let u = r * cols + c;
            if c + 1 < cols {
                g.add_edge(u, u + 1, LinkKind::Electrical);
            }
            if r + 1 < rows {
                g.add_edge(u, u + cols, LinkKind::Electrical);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_shape() {
        let g = mesh_graph(6, 6);
        assert_eq!(g.len(), 36);
        assert_eq!(g.num_edges(), 2 * 6 * 5);
        assert!(g.is_connected());
        // Corner degree 2, edge degree 3, interior degree 4.
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 3);
        assert_eq!(g.degree(7), 4);
    }

    #[test]
    fn mesh_diameter_is_manhattan() {
        let g = mesh_graph(4, 7);
        let diam = (0..g.len())
            .map(|u| g.bfs_distances(u).into_iter().max().unwrap())
            .max()
            .unwrap();
        assert_eq!(diam, (4 - 1) + (7 - 1));
    }

    #[test]
    fn degenerate_mesh_is_a_path() {
        let g = mesh_graph(1, 5);
        assert_eq!(g.num_edges(), 4);
        assert!(g.is_connected());
    }
}
