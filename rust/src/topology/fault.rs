//! Fault sets and fault-aware routing over the OHHC.
//!
//! OTIS-class networks tolerate node and link failures by detouring over
//! the redundant intra-group hexa-cell edges and the optical transpose
//! (Ghosh et al., arXiv:1109.1706).  This module supplies the machinery:
//!
//! * [`FaultSet`] — a per-node / per-link failure set, with a seeded
//!   generator whose selections are **nested** (the set at rate `r₁` is a
//!   subset of the set at `r₂ ≥ r₁` under the same seed) and
//!   **connectivity-preserving**, so degradation curves are structurally
//!   monotone;
//! * [`route_avoiding`] — BFS hop-shortest detour on the surviving
//!   subgraph, returning [`RouteOutcome::Unreachable`] exactly when the
//!   failure set partitions the pair;
//! * [`cheapest_path`] — min-*cost* detour under a caller-supplied
//!   per-link-kind weight, used by the DES so detour hops are charged at
//!   their real electronic/optical prices rather than hop counts.

use std::collections::HashSet;

use super::graph::{Graph, LinkKind};

/// Stateless 64-bit mix (splitmix64 finalizer) — gives every edge / node a
/// deterministic rank under a seed without any RNG state to thread.
pub(crate) fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A set of failed processors and links.
///
/// Links are stored normalized as `(min, max)`; querying either direction
/// of an undirected edge gives the same answer.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSet {
    nodes: HashSet<usize>,
    links: HashSet<(usize, usize)>,
}

impl FaultSet {
    /// The empty (healthy) fault set.
    pub fn new() -> Self {
        FaultSet::default()
    }

    /// Mark a processor failed.
    pub fn fail_node(&mut self, node: usize) {
        self.nodes.insert(node);
    }

    /// Mark an undirected link failed.
    pub fn fail_link(&mut self, u: usize, v: usize) {
        self.links.insert((u.min(v), u.max(v)));
    }

    /// Whether a processor is failed.
    pub fn is_node_failed(&self, node: usize) -> bool {
        self.nodes.contains(&node)
    }

    /// Whether a link is failed (either direction).
    pub fn is_link_failed(&self, u: usize, v: usize) -> bool {
        self.links.contains(&(u.min(v), u.max(v)))
    }

    /// True when nothing is failed.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty() && self.links.is_empty()
    }

    /// Number of failed processors.
    pub fn num_failed_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of failed links.
    pub fn num_failed_links(&self) -> usize {
        self.links.len()
    }

    /// Whether the hop `u → v` is usable: both endpoints alive and the
    /// link itself not failed.  (Existence of the edge is the graph's
    /// business, not the fault set's.)
    pub fn allows(&self, u: usize, v: usize) -> bool {
        !self.is_node_failed(u) && !self.is_node_failed(v) && !self.is_link_failed(u, v)
    }

    /// Merge another fault set into this one.
    pub fn extend(&mut self, other: &FaultSet) {
        self.nodes.extend(other.nodes.iter().copied());
        self.links.extend(other.links.iter().copied());
    }

    /// Fail `⌈permille · |E| / 1000⌉` links of `graph`, seeded.
    ///
    /// Edges are scanned in a fixed seed-ranked permutation and selected
    /// greedily, **skipping any edge whose removal would disconnect the
    /// surviving graph**.  Two consequences, both load-bearing for the
    /// campaign's degradation curves:
    ///
    /// * *nested*: under one seed, the set at a lower rate is a strict
    ///   prefix (subset) of the set at any higher rate, so detour costs
    ///   can only grow with the rate;
    /// * *connectivity-preserving*: every node pair still routes, so the
    ///   sort completes (degraded) instead of failing outright.
    ///
    /// Node failures are the tool for modeling outright partitions — see
    /// [`FaultSet::seeded_nodes`].
    pub fn seeded_links(graph: &Graph, permille: u32, seed: u64) -> Self {
        let mut set = FaultSet::new();
        let total = graph.num_edges();
        let target = (total * permille as usize).div_ceil(1000).min(total);
        if target == 0 {
            return set;
        }
        // Fixed seed-ranked permutation of all edges.
        let mut ranked: Vec<(u64, usize, usize)> = Vec::with_capacity(total);
        for u in 0..graph.len() {
            for &(v, _) in graph.neighbors(u) {
                if u < v {
                    let key = splitmix64(seed ^ ((u as u64) << 32 | v as u64));
                    ranked.push((key, u, v));
                }
            }
        }
        ranked.sort_unstable();
        for &(_, u, v) in &ranked {
            if set.num_failed_links() >= target {
                break;
            }
            set.fail_link(u, v);
            if !connected_avoiding(graph, &set) {
                // A bridge by now — keep it alive and move on.
                set.links.remove(&(u, v));
            }
        }
        set
    }

    /// Fail `count` distinct processors, seeded, never the master
    /// (node 0 owns the array; its death is the client process dying,
    /// not a network fault).  Nested in `count` under one seed.
    pub fn seeded_nodes(num_nodes: usize, count: usize, seed: u64) -> Self {
        let mut set = FaultSet::new();
        if num_nodes < 2 {
            return set;
        }
        let mut ranked: Vec<(u64, usize)> = (1..num_nodes)
            .map(|n| (splitmix64(seed ^ 0xA11C_E500 ^ n as u64), n))
            .collect();
        ranked.sort_unstable();
        for &(_, n) in ranked.iter().take(count) {
            set.fail_node(n);
        }
        set
    }
}

/// Whether the surviving subgraph (alive nodes, alive links) is still
/// connected over the alive nodes.
fn connected_avoiding(g: &Graph, faults: &FaultSet) -> bool {
    let n = g.len();
    let start = match (0..n).find(|&u| !faults.is_node_failed(u)) {
        Some(u) => u,
        None => return true,
    };
    let mut seen = vec![false; n];
    let mut stack = vec![start];
    seen[start] = true;
    let mut reached = 1;
    while let Some(u) = stack.pop() {
        for &(v, _) in g.neighbors(u) {
            if !seen[v] && faults.allows(u, v) {
                seen[v] = true;
                reached += 1;
                stack.push(v);
            }
        }
    }
    reached == n - faults.num_failed_nodes()
}

/// Result of fault-aware routing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteOutcome {
    /// A surviving route, inclusive of both endpoints.
    Path(Vec<usize>),
    /// The failure set separates the pair (or an endpoint is dead).
    Unreachable,
}

impl RouteOutcome {
    /// The route, if one survives.
    pub fn path(&self) -> Option<&[usize]> {
        match self {
            RouteOutcome::Path(p) => Some(p),
            RouteOutcome::Unreachable => None,
        }
    }

    /// True when no route survives.
    pub fn is_unreachable(&self) -> bool {
        matches!(self, RouteOutcome::Unreachable)
    }
}

/// Hop-shortest route from `src` to `dst` avoiding every failed element
/// (BFS over the surviving subgraph).  Falls back through whatever
/// redundancy survives — intra-group hexa-cell edges, the hypercube
/// dimensions, the optical transpose — and reports
/// [`RouteOutcome::Unreachable`] exactly when the pair is partitioned.
pub fn route_avoiding(g: &Graph, faults: &FaultSet, src: usize, dst: usize) -> RouteOutcome {
    if faults.is_node_failed(src) || faults.is_node_failed(dst) {
        return RouteOutcome::Unreachable;
    }
    if src == dst {
        return RouteOutcome::Path(vec![src]);
    }
    let mut prev = vec![usize::MAX; g.len()];
    let mut seen = vec![false; g.len()];
    let mut q = std::collections::VecDeque::new();
    seen[src] = true;
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        for &(v, _) in g.neighbors(u) {
            if !seen[v] && faults.allows(u, v) {
                seen[v] = true;
                prev[v] = u;
                if v == dst {
                    let mut path = vec![dst];
                    let mut cur = dst;
                    while cur != src {
                        cur = prev[cur];
                        path.push(cur);
                    }
                    path.reverse();
                    return RouteOutcome::Path(path);
                }
                q.push_back(v);
            }
        }
    }
    RouteOutcome::Unreachable
}

/// Min-*cost* route from `src` to `dst` avoiding failed elements, under a
/// per-hop cost function of the link kind (Dijkstra).  This is what the
/// DES detours over: a two-hop electrical detour and a one-hop optical
/// alternative are compared at their real §1.5 prices, not hop counts.
/// Returns the path and its total cost, or `None` when partitioned.
pub fn cheapest_path(
    g: &Graph,
    faults: &FaultSet,
    src: usize,
    dst: usize,
    cost: impl Fn(LinkKind) -> u64,
) -> Option<(Vec<usize>, u64)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    if faults.is_node_failed(src) || faults.is_node_failed(dst) {
        return None;
    }
    if src == dst {
        return Some((vec![src], 0));
    }
    let n = g.len();
    let mut dist = vec![u64::MAX; n];
    let mut prev = vec![usize::MAX; n];
    let mut heap = BinaryHeap::new();
    dist[src] = 0;
    heap.push(Reverse((0u64, src)));
    while let Some(Reverse((d, u))) = heap.pop() {
        if d > dist[u] {
            continue;
        }
        if u == dst {
            let mut path = vec![dst];
            let mut cur = dst;
            while cur != src {
                cur = prev[cur];
                path.push(cur);
            }
            path.reverse();
            return Some((path, d));
        }
        for &(v, kind) in g.neighbors(u) {
            if !faults.allows(u, v) {
                continue;
            }
            let nd = d.saturating_add(cost(kind));
            if nd < dist[v] {
                dist[v] = nd;
                prev[v] = u;
                heap.push(Reverse((nd, v)));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Construction;
    use crate::topology::ohhc::Ohhc;
    use crate::topology::routing::path_is_valid;

    fn path_graph(n: usize) -> Graph {
        let mut g = Graph::with_nodes(n);
        for i in 0..n - 1 {
            g.add_edge(i, i + 1, LinkKind::Electrical);
        }
        g
    }

    #[test]
    fn queries_normalize_link_direction() {
        let mut f = FaultSet::new();
        assert!(f.is_empty());
        f.fail_link(5, 2);
        f.fail_node(7);
        assert!(f.is_link_failed(2, 5) && f.is_link_failed(5, 2));
        assert!(!f.is_link_failed(2, 4));
        assert!(f.is_node_failed(7));
        assert!(!f.allows(2, 5));
        assert!(!f.allows(7, 8));
        assert!(f.allows(0, 1));
        assert!(!f.is_empty());
        assert_eq!((f.num_failed_nodes(), f.num_failed_links()), (1, 1));
    }

    #[test]
    fn seeded_links_are_nested_and_connectivity_preserving() {
        let net = Ohhc::new(1, Construction::FullGroup).unwrap();
        let g = net.graph();
        let mut prev = FaultSet::new();
        for permille in [0, 50, 150, 300, 500] {
            let f = FaultSet::seeded_links(g, permille, 0xFA11);
            // Nested: every earlier selection survives into later sets.
            for &(u, v) in &prev.links {
                assert!(f.is_link_failed(u, v), "{permille}‰ dropped ({u},{v})");
            }
            assert!(connected_avoiding(g, &f), "{permille}‰ disconnected");
            assert!(f.num_failed_links() <= (g.num_edges() * permille as usize).div_ceil(1000));
            prev = f;
        }
        assert!(prev.num_failed_links() > 0);
        // Determinism: same seed, same set.
        assert_eq!(prev, FaultSet::seeded_links(g, 500, 0xFA11));
        // Different seed, (almost surely) different set.
        assert_ne!(prev, FaultSet::seeded_links(g, 500, 0xFA12));
    }

    #[test]
    fn seeded_nodes_never_kill_the_master() {
        for count in [1, 3, 7] {
            let f = FaultSet::seeded_nodes(36, count, 9);
            assert_eq!(f.num_failed_nodes(), count);
            assert!(!f.is_node_failed(0));
        }
        // Nested in count.
        let small = FaultSet::seeded_nodes(36, 2, 9);
        let large = FaultSet::seeded_nodes(36, 5, 9);
        for &n in &small.nodes {
            assert!(large.is_node_failed(n));
        }
    }

    #[test]
    fn route_avoiding_detours_and_detects_partitions() {
        // Cycle 0-1-2-3-0: killing (0,1) forces the long way round.
        let mut g = path_graph(4);
        g.add_edge(3, 0, LinkKind::Optical);
        let mut f = FaultSet::new();
        f.fail_link(0, 1);
        match route_avoiding(&g, &f, 0, 1) {
            RouteOutcome::Path(p) => assert_eq!(p, vec![0, 3, 2, 1]),
            RouteOutcome::Unreachable => panic!("cycle survives one failure"),
        }
        // Killing the opposite side too partitions the pair.
        f.fail_link(2, 3);
        assert!(route_avoiding(&g, &f, 0, 2).is_unreachable());
        assert!(!route_avoiding(&g, &f, 0, 3).is_unreachable());
        // A dead endpoint is unreachable by definition.
        let mut f = FaultSet::new();
        f.fail_node(2);
        assert!(route_avoiding(&g, &f, 0, 2).is_unreachable());
        assert!(route_avoiding(&g, &f, 2, 0).is_unreachable());
        // Dead intermediate nodes are routed around.
        match route_avoiding(&g, &f, 1, 3) {
            RouteOutcome::Path(p) => assert_eq!(p, vec![1, 0, 3]),
            RouteOutcome::Unreachable => panic!("1-0-3 survives"),
        }
    }

    #[test]
    fn cheapest_path_prices_link_kinds() {
        // Triangle: 0-1-2 electrical, 0-2 optical.  With optical priced
        // above two electrical hops the detour wins, and vice versa.
        let mut g = Graph::with_nodes(3);
        g.add_edge(0, 1, LinkKind::Electrical);
        g.add_edge(1, 2, LinkKind::Electrical);
        g.add_edge(0, 2, LinkKind::Optical);
        let f = FaultSet::new();
        let price_optics_high = |k: LinkKind| match k {
            LinkKind::Electrical => 10,
            LinkKind::Optical => 25,
        };
        let (p, c) = cheapest_path(&g, &f, 0, 2, price_optics_high).unwrap();
        assert_eq!((p, c), (vec![0, 1, 2], 20));
        let price_optics_low = |k: LinkKind| match k {
            LinkKind::Electrical => 10,
            LinkKind::Optical => 5,
        };
        let (p, c) = cheapest_path(&g, &f, 0, 2, price_optics_low).unwrap();
        assert_eq!((p, c), (vec![0, 2], 5));
        // Faults apply: kill the optical link and the detour is forced.
        let mut f = FaultSet::new();
        f.fail_link(0, 2);
        let (p, c) = cheapest_path(&g, &f, 0, 2, price_optics_low).unwrap();
        assert_eq!((p, c), (vec![0, 1, 2], 20));
        f.fail_node(1);
        assert!(cheapest_path(&g, &f, 0, 2, price_optics_low).is_none());
    }

    #[test]
    fn detours_on_the_real_ohhc_are_valid() {
        let net = Ohhc::new(2, Construction::HalfGroup).unwrap();
        let g = net.graph();
        let f = FaultSet::seeded_links(g, 200, 7);
        for src in (0..net.total_processors()).step_by(11) {
            for dst in (0..net.total_processors()).step_by(13) {
                match route_avoiding(g, &f, src, dst) {
                    RouteOutcome::Path(p) => {
                        assert_eq!(p[0], src);
                        assert_eq!(*p.last().unwrap(), dst);
                        assert!(path_is_valid(g, &p));
                        for w in p.windows(2) {
                            assert!(f.allows(w[0], w[1]), "{src}->{dst} uses a dead hop");
                        }
                    }
                    // seeded_links preserves connectivity.
                    RouteOutcome::Unreachable => panic!("{src}->{dst} unreachable"),
                }
            }
        }
    }
}
