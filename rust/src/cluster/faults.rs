//! Cluster-level fault injection: seeded shard blackouts and
//! brownouts, layered *above* the per-shard service
//! [`FaultPlan`](crate::service::FaultPlan).
//!
//! The service plan breaks processors and links *inside* one shard;
//! this plan breaks whole shards, which is the failure mode the
//! OHHC's two-level story actually cares about: a group (shard) drops
//! off the optical fabric and the rest of the cluster must keep
//! serving.  Two mechanisms:
//!
//! * **Windows** ([`FaultWindow`]) — deterministic outage intervals on
//!   the cluster's submission **event clock** (never wall time, so a
//!   replay blacks out the same jobs).  A *blackout* fails every
//!   attempt dispatched to the shard while the window is open; a
//!   *brownout* lets attempts run but inflates their reported latency
//!   by a fixed virtual delay, priced exactly like the
//!   [`InterShardModel`](crate::sim::InterShardModel)'s optical
//!   charge — no thread ever sleeps.
//! * **Rate** (`shard_fail_rate`) — a seeded per-(shard, job, attempt)
//!   [`splitmix64`] draw, the cluster-scale analogue of the service
//!   plan's worker-panic rate.  Failovers redraw with `attempt + 1`,
//!   so a transient shard fault clears on retry just as service
//!   retries redraw their fault sets.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::topology::fault::splitmix64;

/// Domain separator for the shard-failure stream, so cluster draws
/// never correlate with the service plan's panic/link/node streams.
const SHARD_STREAM: u64 = 0x5AA2_DF41;

/// What a [`FaultWindow`] does to the shard while open.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WindowKind {
    /// Every attempt on the shard fails explicitly.
    Blackout,
    /// Attempts run, but each is charged this much extra virtual
    /// latency (deadline accounting included).
    Brownout {
        /// Virtual extra latency per attempt.
        delay: Duration,
    },
}

/// One outage interval on the cluster's submission event clock.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultWindow {
    /// Shard the window applies to.
    pub shard: usize,
    /// First event (inclusive) the window covers.
    pub from_event: u64,
    /// First event *past* the window (exclusive).
    pub until_event: u64,
    /// Blackout or brownout.
    pub kind: WindowKind,
}

impl FaultWindow {
    /// A blackout of `shard` over events `[from, until)`.
    pub fn blackout(shard: usize, from: u64, until: u64) -> FaultWindow {
        FaultWindow {
            shard,
            from_event: from,
            until_event: until,
            kind: WindowKind::Blackout,
        }
    }

    /// A brownout of `shard` over events `[from, until)` adding
    /// `delay` of virtual latency per attempt.
    pub fn brownout(shard: usize, from: u64, until: u64, delay: Duration) -> FaultWindow {
        FaultWindow {
            shard,
            from_event: from,
            until_event: until,
            kind: WindowKind::Brownout { delay },
        }
    }

    /// Parse a comma-separated CLI window list.  Each item is
    /// `SHARD:FROM:UNTIL` (blackout) or `SHARD:FROM:UNTIL:SLOW_MS`
    /// (brownout adding `SLOW_MS` milliseconds), e.g. `1:40:140` or
    /// `1:40:140,2:200:260:5`.
    pub fn parse_list(text: &str) -> Result<Vec<FaultWindow>> {
        let mut windows = Vec::new();
        for item in text.split(',').filter(|s| !s.trim().is_empty()) {
            let fields: Vec<&str> = item.trim().split(':').collect();
            if !(3..=4).contains(&fields.len()) {
                return Err(Error::Config(format!(
                    "fault window '{item}': want SHARD:FROM:UNTIL[:SLOW_MS]"
                )));
            }
            let parse = |what: &str, s: &str| -> Result<u64> {
                s.parse::<u64>()
                    .map_err(|_| Error::Config(format!("fault window '{item}': bad {what} '{s}'")))
            };
            let shard = parse("shard", fields[0])? as usize;
            let from = parse("from", fields[1])?;
            let until = parse("until", fields[2])?;
            if until <= from {
                return Err(Error::Config(format!(
                    "fault window '{item}': until must be > from"
                )));
            }
            windows.push(match fields.get(3) {
                None => FaultWindow::blackout(shard, from, until),
                Some(ms) => {
                    let delay = Duration::from_millis(parse("slow_ms", ms)?);
                    FaultWindow::brownout(shard, from, until, delay)
                }
            });
        }
        Ok(windows)
    }
}

/// The fault injected into one dispatch attempt, if any.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShardFault {
    /// The attempt fails outright, with this cause named in the
    /// result's error.
    Fail {
        /// Human-readable cause.
        reason: &'static str,
    },
    /// The attempt runs, charged `delay` of extra virtual latency.
    Slow {
        /// Virtual extra latency.
        delay: Duration,
    },
}

/// The cluster's seeded shard-outage schedule.
#[derive(Debug, Clone)]
pub struct ClusterFaultPlan {
    /// Seeds the `shard_fail_rate` draws — same seed, same outages.
    pub seed: u64,
    /// Probability in `[0, 1]` that any single dispatch attempt fails
    /// at the shard boundary (drawn per shard, job, and attempt).
    pub shard_fail_rate: f64,
    /// Deterministic outage intervals on the event clock.
    pub windows: Vec<FaultWindow>,
}

impl ClusterFaultPlan {
    /// No cluster-level faults (the default).
    pub fn none() -> ClusterFaultPlan {
        ClusterFaultPlan {
            seed: 0xC1A0_FA11,
            shard_fail_rate: 0.0,
            windows: Vec::new(),
        }
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        self.shard_fail_rate > 0.0 || !self.windows.is_empty()
    }

    /// Reject nonsensical plans before the cluster starts.
    pub fn validate(&self, shards: usize) -> Result<()> {
        if !(0.0..=1.0).contains(&self.shard_fail_rate) {
            return Err(Error::Config(format!(
                "shard_fail_rate must be in [0, 1], got {}",
                self.shard_fail_rate
            )));
        }
        for w in &self.windows {
            if w.shard >= shards {
                return Err(Error::Config(format!(
                    "fault window names shard {} but the cluster has {shards}",
                    w.shard
                )));
            }
        }
        Ok(())
    }

    /// The fault injected into dispatching (`job_id`, `attempt`) onto
    /// `shard` at event-clock value `event` — `None` for a clean
    /// dispatch.  Windows win over the rate draw; the first matching
    /// window applies.
    pub fn draw(&self, shard: usize, event: u64, job_id: u64, attempt: u32) -> Option<ShardFault> {
        for w in &self.windows {
            if w.shard == shard && (w.from_event..w.until_event).contains(&event) {
                return Some(match w.kind {
                    WindowKind::Blackout => ShardFault::Fail {
                        reason: "shard blackout window",
                    },
                    WindowKind::Brownout { delay } => ShardFault::Slow { delay },
                });
            }
        }
        if self.shard_fail_rate > 0.0 {
            let salt = (shard as u64).wrapping_mul(0xA076_1D64_78BD_642F);
            let mixed = splitmix64(SHARD_STREAM ^ job_id ^ salt);
            let word = splitmix64(self.seed ^ mixed ^ ((attempt as u64) << 48));
            let unit = (word >> 11) as f64 / (1u64 << 53) as f64;
            if unit < self.shard_fail_rate {
                return Some(ShardFault::Fail {
                    reason: "injected shard failure",
                });
            }
        }
        None
    }
}

impl Default for ClusterFaultPlan {
    fn default() -> Self {
        ClusterFaultPlan::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_cover_their_half_open_interval_only() {
        let plan = ClusterFaultPlan {
            windows: vec![FaultWindow::blackout(1, 10, 20)],
            ..ClusterFaultPlan::none()
        };
        assert_eq!(plan.draw(1, 9, 7, 0), None);
        assert!(matches!(plan.draw(1, 10, 7, 0), Some(ShardFault::Fail { .. })));
        assert!(matches!(plan.draw(1, 19, 7, 0), Some(ShardFault::Fail { .. })));
        assert_eq!(plan.draw(1, 20, 7, 0), None);
        // Other shards never see the window.
        assert_eq!(plan.draw(0, 15, 7, 0), None);
    }

    #[test]
    fn brownout_windows_slow_instead_of_failing() {
        let delay = Duration::from_millis(5);
        let plan = ClusterFaultPlan {
            windows: vec![FaultWindow::brownout(0, 0, 100, delay)],
            ..ClusterFaultPlan::none()
        };
        assert_eq!(plan.draw(0, 50, 1, 0), Some(ShardFault::Slow { delay }));
    }

    #[test]
    fn rate_draws_are_deterministic_and_redraw_per_attempt() {
        let plan = ClusterFaultPlan {
            shard_fail_rate: 0.5,
            ..ClusterFaultPlan::none()
        };
        for shard in 0..4 {
            for job in 0..32u64 {
                assert_eq!(plan.draw(shard, 0, job, 0), plan.draw(shard, 99, job, 0));
            }
        }
        // Attempt is part of the draw: across many jobs, some must
        // flip between attempt 0 and attempt 1.
        let flips = (0..256u64)
            .filter(|&job| plan.draw(0, 0, job, 0) != plan.draw(0, 0, job, 1))
            .count();
        assert!(flips > 0, "attempt must be folded into the draw");
        let none = ClusterFaultPlan::none();
        assert!(!none.is_active());
        assert_eq!(none.draw(0, 0, 1, 0), None);
    }

    #[test]
    fn parse_list_round_trips_and_rejects_garbage() {
        let windows = FaultWindow::parse_list("1:40:140,2:200:260:5").unwrap();
        assert_eq!(windows[0], FaultWindow::blackout(1, 40, 140));
        assert_eq!(
            windows[1],
            FaultWindow::brownout(2, 200, 260, Duration::from_millis(5))
        );
        assert!(FaultWindow::parse_list("").unwrap().is_empty());
        assert!(FaultWindow::parse_list("1:40").is_err());
        assert!(FaultWindow::parse_list("1:40:x").is_err());
        assert!(FaultWindow::parse_list("1:40:40").is_err(), "empty window");
    }

    #[test]
    fn validate_rejects_out_of_range_plans() {
        let mut plan = ClusterFaultPlan::none();
        plan.shard_fail_rate = 1.5;
        assert!(plan.validate(4).is_err());
        plan.shard_fail_rate = 0.0;
        plan.windows = vec![FaultWindow::blackout(4, 0, 10)];
        assert!(plan.validate(4).is_err(), "shard index out of range");
        assert!(plan.validate(5).is_ok());
    }
}
