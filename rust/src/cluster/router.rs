//! Deterministic shard router: rendezvous (highest-random-weight)
//! consistent hashing on the job key.
//!
//! Every `(key, shard)` pair gets a pseudorandom weight from a
//! SplitMix64-style mixer seeded by the router seed; a key routes to
//! the shard with the highest weight.  That gives the two properties
//! the cluster needs, both tested here:
//!
//! * **Determinism + balance** — the assignment is a pure function of
//!   `(key, shards, seed)`, and because the mixer is uniform the load
//!   spreads near-ideally with no virtual-node tuning (10k keys over
//!   8 shards land within a few percent of ideal).
//! * **Minimal disruption** — when a shard dies, only the keys that
//!   routed *to it* move (to their second-highest weight); every other
//!   key keeps its shard, so a dead shard never reshuffles the healthy
//!   ones.  This mirrors the OTIS distance framing (Das,
//!   arXiv:1310.7376): traffic stays group-local unless its group is
//!   the one that failed.

use crate::service::job::{fnv1a_bytes, JobSpec};

/// The routing key of a job: an FNV-1a digest of the identity fields
/// that survive resubmission (`id`, workload `seed`).  Same schedule,
/// same keys — loadgen replays route identically run to run.
pub fn job_key(spec: &JobSpec) -> u64 {
    fnv1a_bytes(
        spec.id
            .to_le_bytes()
            .into_iter()
            .chain(spec.seed.to_le_bytes()),
    )
}

/// SplitMix64 finalizer — the per-(key, shard) weight mixer.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic rendezvous router over `shards` shards.
#[derive(Debug, Clone)]
pub struct Router {
    shards: usize,
    seed: u64,
}

impl Router {
    /// A router over `shards` shards (at least one) under `seed`.
    pub fn new(shards: usize, seed: u64) -> Router {
        assert!(shards >= 1, "router needs at least one shard");
        Router { shards, seed }
    }

    /// Number of shards routed over.
    pub fn shards(&self) -> usize {
        self.shards
    }

    fn weight(&self, key: u64, shard: usize) -> u64 {
        mix64(key ^ mix64(self.seed ^ (shard as u64).wrapping_mul(0xA076_1D64_78BD_642F)))
    }

    /// The shard `key` routes to: highest rendezvous weight wins.
    pub fn route(&self, key: u64) -> usize {
        (0..self.shards)
            .max_by_key(|&s| self.weight(key, s))
            .expect("at least one shard")
    }

    /// Route among the live shards only (`alive[s] == false` marks a
    /// dead shard).  Keys whose winner is alive keep their assignment
    /// — the minimal-disruption half of consistent hashing.  `None`
    /// when every shard is dead.
    pub fn route_alive(&self, key: u64, alive: &[bool]) -> Option<usize> {
        debug_assert_eq!(alive.len(), self.shards);
        (0..self.shards)
            .filter(|&s| alive.get(s).copied().unwrap_or(false))
            .max_by_key(|&s| self.weight(key, s))
    }

    /// The failover target for `key` after `exclude` failed it: the
    /// highest-weight live shard *other than* `exclude`.  Rendezvous
    /// order makes this deterministic — every retry of the same key
    /// lands on the same next-ranked shard.  `None` when no other live
    /// shard exists.
    pub fn route_failover(&self, key: u64, alive: &[bool], exclude: usize) -> Option<usize> {
        debug_assert_eq!(alive.len(), self.shards);
        (0..self.shards)
            .filter(|&s| s != exclude && alive.get(s).copied().unwrap_or(false))
            .max_by_key(|&s| self.weight(key, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Construction, Distribution, DivideStrategy};

    fn spec(id: u64, seed: u64) -> JobSpec {
        JobSpec {
            id,
            distribution: Distribution::Random,
            elements: 1_000,
            seed,
            dimension: 1,
            construction: Construction::FullGroup,
            strategy: DivideStrategy::PaperFixed,
            deadline: None,
        }
    }

    #[test]
    fn routing_is_deterministic_across_router_instances() {
        let a = Router::new(8, 42);
        let b = Router::new(8, 42);
        for id in 0..1_000u64 {
            let key = job_key(&spec(id, id.wrapping_mul(0xDEAD_BEEF)));
            assert_eq!(a.route(key), b.route(key), "id {id}");
        }
    }

    #[test]
    fn routing_depends_on_the_seed_and_the_job_key() {
        let a = Router::new(8, 1);
        let b = Router::new(8, 2);
        let moved = (0..1_000u64)
            .filter(|&id| {
                let key = job_key(&spec(id, 7));
                a.route(key) != b.route(key)
            })
            .count();
        assert!(moved > 500, "seed change moved only {moved}/1000 keys");
        // Different workload seeds change the job key, hence the route mix.
        let k1 = job_key(&spec(3, 100));
        let k2 = job_key(&spec(3, 101));
        assert_ne!(k1, k2);
    }

    #[test]
    fn ten_thousand_keys_balance_within_20_percent_of_ideal() {
        let router = Router::new(8, 7);
        let mut counts = [0usize; 8];
        for id in 0..10_000u64 {
            let key = job_key(&spec(id, id ^ 0x5EED));
            counts[router.route(key)] += 1;
        }
        let ideal = 10_000.0 / 8.0;
        for (shard, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - ideal).abs() / ideal;
            assert!(dev <= 0.20, "shard {shard}: {c} jobs, {:.1}% off ideal", dev * 100.0);
        }
        assert_eq!(counts.iter().sum::<usize>(), 10_000);
    }

    #[test]
    fn dead_shard_remaps_only_its_own_keys() {
        let router = Router::new(8, 9);
        let mut alive = [true; 8];
        alive[3] = false;
        let mut remapped = 0usize;
        for id in 0..2_000u64 {
            let key = job_key(&spec(id, id));
            let healthy = router.route(key);
            let degraded = router.route_alive(key, &alive).unwrap();
            if healthy == 3 {
                assert_ne!(degraded, 3, "key routed to the dead shard");
                remapped += 1;
            } else {
                assert_eq!(degraded, healthy, "healthy key moved");
            }
        }
        assert!(remapped > 0, "no key ever routed to shard 3");
        // All shards alive: route_alive is exactly route.
        let all = [true; 8];
        for id in 0..200u64 {
            let key = job_key(&spec(id, id));
            assert_eq!(router.route_alive(key, &all), Some(router.route(key)));
        }
        assert_eq!(router.route_alive(1, &[false; 8]), None);
    }

    #[test]
    fn failover_target_is_the_next_ranked_live_shard() {
        let router = Router::new(4, 11);
        let alive = [true; 4];
        for id in 0..500u64 {
            let key = job_key(&spec(id, id));
            let home = router.route(key);
            let next = router.route_failover(key, &alive, home).unwrap();
            assert_ne!(next, home, "failover must leave the failed shard");
            // Identical to masking the failed shard out of route_alive.
            let mut masked = alive;
            masked[home] = false;
            assert_eq!(Some(next), router.route_alive(key, &masked));
        }
        // Nobody left to fail over to.
        assert_eq!(router.route_failover(1, &[false, true, false, false], 1), None);
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        let router = Router::new(1, 0);
        for id in 0..50u64 {
            assert_eq!(router.route(job_key(&spec(id, id))), 0);
        }
    }
}
