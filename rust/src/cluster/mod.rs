//! The sharded scale-out service: OTIS groups mapped to shards.
//!
//! The OHHC is a two-level network — electronic links inside a
//! hexa-cell group, optical transpose links between groups — and that
//! is exactly the shape of a sharded serving cluster.  This module is
//! the cluster layer over [`crate::service`]:
//!
//! * a [`Cluster`] fronts N independent [`SortService`] shards, each
//!   with its own worker pool, [`PlanCache`](crate::campaign::PlanCache)
//!   leases, admission control, and fault plan;
//! * a deterministic rendezvous [`Router`] (consistent hashing on the
//!   [`job_key`]) homes every small job on one shard, so shard-local
//!   traffic stays on the electronic links of one "group";
//! * jobs too big for one shard take the **scatter/merge** path: the
//!   PSRS-style sampled splitter
//!   ([`divide_sampled`](crate::coordinator::divide_sampled)) cuts the
//!   input into per-shard spans, every shard sorts its span through
//!   the normal [`Session`](crate::pipeline::Session) pipeline on its
//!   own leased topology, and a k-way merge ([`kway_merge`])
//!   reassembles the result while the
//!   [`InterShardModel`](crate::sim::InterShardModel) charges the
//!   cross-shard traffic at the DES's optical-hop prices — the paper's
//!   §5 analytical story extended to cluster scale;
//! * ticket forwarding: [`Cluster::submit`] returns a
//!   [`ClusterSubmission`] whose [`ClusterTicket`] wraps the shard's
//!   own [`JobTicket`] (routed jobs) or a cluster-owned completion
//!   slot (split jobs) — poll, wait, cancel, exactly the service's
//!   per-job contract;
//! * observability: [`Cluster::snapshot`] merges every shard's
//!   [`ServiceStats`] at histogram level ([`ServiceStats::merge`]) so
//!   cluster percentiles are computed after the merge, never averaged,
//!   plus the cluster-only counters in [`ClusterStats`] (routed vs
//!   split, cross-shard bytes, virtual transfer charge).
//!
//! A dead shard is handled at the router: [`Router::route_alive`]
//! remaps only the dead shard's keys (rendezvous hashing's minimal
//! disruption), and in-flight jobs on the dying shard fail explicitly
//! through the service's fault plan / retry budget — never silently.

mod merge;
mod router;
mod stats;

pub use merge::kway_merge;
pub use router::{job_key, Router};
pub use stats::{ClusterSnapshot, ClusterStats};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::LinkModel;
use crate::coordinator::divide_sampled;
use crate::error::{Error, Result};
use crate::pipeline::Session;
use crate::service::job::{fnv1a, multiset_fingerprint, JobResult, JobSpec};
use crate::service::loadgen::JobSink;
use crate::service::queue::RejectReason;
use crate::service::stats::{ServiceSnapshot, ServiceStats};
use crate::service::ticket::{JobTicket, Slot, Submission};
use crate::service::{ServiceConfig, SortService};
use crate::sim::transfer::InterShardModel;
use crate::sort::is_sorted;

/// Cluster knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of shards (independent [`SortService`]s).
    pub shards: usize,
    /// Per-shard service configuration (cloned per shard).
    pub shard: ServiceConfig,
    /// Jobs with more keys than this take the scatter/merge path
    /// (single-shard clusters route everything regardless).
    pub split_threshold: usize,
    /// At most this many split jobs in flight; beyond it the cluster
    /// front door sheds explicitly.
    pub max_inflight_splits: usize,
    /// Router seed — same seed, same shard assignment, run after run.
    pub router_seed: u64,
    /// Link parameters pricing the cross-shard optical traffic.
    pub link: LinkModel,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            shards: 2,
            shard: ServiceConfig::default(),
            split_threshold: 65_536,
            max_inflight_splits: 8,
            router_seed: 0x0715C,
            link: LinkModel::default(),
        }
    }
}

/// The tenant's handle to one accepted cluster job.
#[derive(Debug)]
pub struct ClusterTicket {
    shard: Option<usize>,
    inner: JobTicket,
}

impl ClusterTicket {
    /// The job id this ticket tracks.
    pub fn id(&self) -> u64 {
        self.inner.id()
    }

    /// The home shard of a routed job; `None` for a split job (it ran
    /// on every shard).
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }

    /// Did this job take the scatter/merge path?
    pub fn is_split(&self) -> bool {
        self.shard.is_none()
    }

    /// Non-blocking status read (see
    /// [`JobTicket::poll`]).
    pub fn poll(&self) -> crate::service::TicketStatus {
        self.inner.poll()
    }

    /// Non-blocking result take: `Some` exactly once.
    pub fn try_result(&self) -> Option<JobResult> {
        self.inner.try_result()
    }

    /// Block until the result is ready (or `timeout` passes), then
    /// take it.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<JobResult> {
        self.inner.wait_timeout(timeout)
    }

    /// Cancel if nothing claimed the job yet.  Split jobs claim their
    /// slot at submit, so they always lose this race — by design: the
    /// scatter begins immediately.
    pub fn try_cancel(&self) -> bool {
        self.inner.try_cancel()
    }
}

/// Outcome of one [`Cluster::submit`].
#[derive(Debug)]
pub enum ClusterSubmission {
    /// Accepted; `shard` is the home shard (`None` for a split job).
    Accepted {
        /// Home shard index, or `None` when the job was split.
        shard: Option<usize>,
        /// The job's completion handle.
        ticket: ClusterTicket,
    },
    /// Turned away — nothing was enqueued anywhere.
    Rejected {
        /// Why.
        reason: RejectReason,
    },
}

impl ClusterSubmission {
    /// Did the job make it in?
    pub fn is_accepted(&self) -> bool {
        matches!(self, ClusterSubmission::Accepted { .. })
    }

    /// The ticket, consuming the submission (`None` when rejected).
    pub fn ticket(self) -> Option<ClusterTicket> {
        match self {
            ClusterSubmission::Accepted { ticket, .. } => Some(ticket),
            ClusterSubmission::Rejected { .. } => None,
        }
    }
}

/// Split-path shared state: completed split slots for the drain, plus
/// the in-flight gauge the front door sheds on.
#[derive(Debug, Default)]
struct SplitShared {
    completed: Mutex<VecDeque<Arc<Slot>>>,
    ready: Condvar,
    inflight: AtomicUsize,
}

/// N sort-service shards behind one deterministic router.
pub struct Cluster {
    cfg: ClusterConfig,
    shards: Arc<Vec<SortService>>,
    router: Router,
    transfer: InterShardModel,
    stats: Arc<ClusterStats>,
    split: Arc<SplitShared>,
    splitters: Mutex<Vec<JoinHandle<()>>>,
}

impl Cluster {
    /// Start `cfg.shards` independent shards.
    pub fn start(cfg: ClusterConfig) -> Cluster {
        let n = cfg.shards.max(1);
        let shards: Vec<SortService> =
            (0..n).map(|_| SortService::start(cfg.shard.clone())).collect();
        Cluster {
            router: Router::new(n, cfg.router_seed),
            transfer: InterShardModel::new(cfg.link),
            shards: Arc::new(shards),
            stats: Arc::new(ClusterStats::new()),
            split: Arc::new(SplitShared::default()),
            splitters: Mutex::new(Vec::new()),
            cfg,
        }
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `i`'s service (tests, diagnostics).
    pub fn shard(&self, i: usize) -> &SortService {
        &self.shards[i]
    }

    /// The router in use.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Live cluster-level counters.
    pub fn stats(&self) -> &ClusterStats {
        &self.stats
    }

    /// Submit one job.  Small jobs route to their home shard
    /// (consistent hashing on [`job_key`]); jobs above the split
    /// threshold scatter across every shard and merge back.
    pub fn submit(&self, spec: JobSpec) -> ClusterSubmission {
        if self.shards.len() > 1 && spec.elements > self.cfg.split_threshold {
            self.submit_split(spec)
        } else {
            self.submit_routed(spec)
        }
    }

    fn submit_routed(&self, spec: JobSpec) -> ClusterSubmission {
        let shard = self.router.route(job_key(&spec));
        match self.shards[shard].submit(spec) {
            Submission::Accepted { ticket, .. } => {
                self.stats.on_routed();
                ClusterSubmission::Accepted {
                    shard: Some(shard),
                    ticket: ClusterTicket {
                        shard: Some(shard),
                        inner: ticket,
                    },
                }
            }
            Submission::Rejected { reason } => ClusterSubmission::Rejected { reason },
        }
    }

    fn submit_split(&self, spec: JobSpec) -> ClusterSubmission {
        if let Err(e) = spec.validate() {
            return ClusterSubmission::Rejected {
                reason: RejectReason::Invalid {
                    detail: e.to_string(),
                },
            };
        }
        let inflight = self.split.inflight.fetch_add(1, Ordering::AcqRel);
        if inflight >= self.cfg.max_inflight_splits {
            self.split.inflight.fetch_sub(1, Ordering::AcqRel);
            self.stats.on_split_rejected();
            return ClusterSubmission::Rejected {
                reason: RejectReason::Overloaded {
                    depth: inflight,
                    shed_depth: self.cfg.max_inflight_splits,
                },
            };
        }
        let slot = Slot::new(spec.id);
        // The scatter begins immediately: claim now so a cancel can
        // never race a job that is already generating its input.
        assert!(slot.claim(), "fresh slot must claim");
        let ticket = ClusterTicket {
            shard: None,
            inner: JobTicket::new(Arc::clone(&slot)),
        };
        let accepted_at = Instant::now();
        let home = self.router.route(job_key(&spec));
        let shards = Arc::clone(&self.shards);
        let split = Arc::clone(&self.split);
        let stats = Arc::clone(&self.stats);
        let transfer = self.transfer.clone();
        let retain = self.cfg.shard.retain_output;
        let handle = std::thread::Builder::new()
            .name(format!("ohhc-split-{}", spec.id))
            .spawn(move || {
                let result =
                    execute_split(&shards, &spec, home, &transfer, &stats, retain, accepted_at);
                slot.complete(result);
                let mut q = split.completed.lock().unwrap();
                q.push_back(slot);
                drop(q);
                split.ready.notify_all();
                split.inflight.fetch_sub(1, Ordering::AcqRel);
            })
            .expect("spawn split worker");
        self.splitters.lock().unwrap().push(handle);
        ClusterSubmission::Accepted {
            shard: None,
            ticket,
        }
    }

    /// Wait up to `timeout` for any finished job (routed on any shard,
    /// or split) whose result nobody has taken yet, and take it.
    pub fn next_completion(&self, timeout: Duration) -> Option<JobResult> {
        const TICK: Duration = Duration::from_millis(1);
        let deadline = Instant::now().checked_add(timeout);
        loop {
            {
                let mut q = self.split.completed.lock().unwrap();
                while let Some(slot) = q.pop_front() {
                    if let Some(r) = slot.take() {
                        return Some(r);
                    }
                }
            }
            for shard in self.shards.iter() {
                if let Some(r) = shard.try_next_completion() {
                    return Some(r);
                }
            }
            let wait = match deadline {
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    (deadline - now).min(TICK)
                }
                None => TICK,
            };
            // Split completions signal this condvar; shard completions
            // are picked up on the next tick.
            let q = self.split.completed.lock().unwrap();
            let _ = self.split.ready.wait_timeout(q, wait).unwrap();
        }
    }

    /// Non-blocking [`Self::next_completion`].
    pub fn try_next_completion(&self) -> Option<JobResult> {
        self.next_completion(Duration::ZERO)
    }

    /// Freeze the cluster view: per-shard snapshots plus the
    /// histogram-level merge ([`ServiceStats::merge`]).
    pub fn snapshot(&self) -> ClusterSnapshot {
        let merged = ServiceStats::new();
        let mut per = Vec::with_capacity(self.shards.len());
        for shard in self.shards.iter() {
            merged.merge(shard.stats());
            per.push(shard.stats().snapshot());
        }
        self.stats.freeze(per, merged.snapshot())
    }

    /// Graceful shutdown: join every split worker, shut each shard
    /// down (their backlogs still execute), and return the final
    /// snapshot plus every result nobody took.  Drain completions
    /// first (as loadgen does) if the merged histograms must cover
    /// every job — the merge is frozen as the shards close.
    pub fn shutdown(self) -> (ClusterSnapshot, Vec<JobResult>) {
        let Cluster {
            shards,
            stats,
            split,
            splitters,
            ..
        } = self;
        for h in splitters.into_inner().unwrap() {
            let _ = h.join();
        }
        let mut rest = Vec::new();
        {
            let mut q = split.completed.lock().unwrap();
            while let Some(slot) = q.pop_front() {
                if let Some(r) = slot.take() {
                    rest.push(r);
                }
            }
        }
        let shards = Arc::try_unwrap(shards)
            .ok()
            .expect("split workers joined; no shard handle outlives the cluster");
        let merged = ServiceStats::new();
        for shard in &shards {
            merged.merge(shard.stats());
        }
        let mut finals = Vec::with_capacity(shards.len());
        for shard in shards {
            let (snap, leftover) = shard.shutdown();
            finals.push(snap);
            rest.extend(leftover);
        }
        (stats.freeze(finals, merged.snapshot()), rest)
    }
}

impl JobSink for Cluster {
    fn offer(&self, spec: JobSpec) -> bool {
        self.submit(spec).is_accepted()
    }

    fn drain_next(&self, timeout: Duration) -> Option<JobResult> {
        self.next_completion(timeout)
    }

    fn stats_snapshot(&self) -> ServiceSnapshot {
        self.snapshot().merged
    }
}

/// The scatter/merge path, run on a dedicated split worker thread:
/// sampled split into per-shard spans, one pipeline session per shard
/// on that shard's leased topology (accounted into that shard's
/// stats), k-way merge, full verification, optical transfer charge.
fn execute_split(
    shards: &[SortService],
    spec: &JobSpec,
    home: usize,
    transfer: &InterShardModel,
    stats: &ClusterStats,
    retain: bool,
    accepted_at: Instant,
) -> JobResult {
    let data = spec.generate();
    let t0 = Instant::now();
    let queue_latency = t0.duration_since(accepted_at);
    let run = (|| -> Result<(Vec<i32>, f64, u64, Duration, f64)> {
        let n = shards.len();
        let divided = divide_sampled(&data, n)?;
        let imbalance = divided.imbalance();
        let sizes = divided.sizes();
        // One session per shard, concurrently; each shard leases its
        // own (dimension, construction) bundle from its own PlanCache
        // and its stats observe the session's stage boundaries.
        let spans: Vec<&[i32]> = (0..n).map(|b| divided.buckets.bucket(b)).collect();
        let parts: Vec<Result<Option<Vec<i32>>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = spans
                .iter()
                .enumerate()
                .map(|(i, &span)| {
                    let shard = &shards[i];
                    scope.spawn(move || sort_span_on_shard(shard, spec, span))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::Invariant("span sorter panicked".into())))
                })
                .collect()
        });
        let mut sorted_parts: Vec<Vec<i32>> = Vec::with_capacity(n);
        for part in parts {
            if let Some(p) = part? {
                sorted_parts.push(p);
            }
        }
        let refs: Vec<&[i32]> = sorted_parts.iter().map(Vec::as_slice).collect();
        let merge_t0 = Instant::now();
        let merged = kway_merge(&refs);
        let merge_wall = merge_t0.elapsed();
        if merged.len() != data.len()
            || !is_sorted(&merged)
            || multiset_fingerprint(&merged) != multiset_fingerprint(&data)
        {
            return Err(Error::Invariant(
                "cluster merge is not a sorted permutation of the input".into(),
            ));
        }
        let charge = transfer.split_transfer(home, &sizes);
        Ok((
            merged,
            imbalance,
            charge.cross_shard_bytes,
            merge_wall,
            charge.transfer_ns,
        ))
    })();
    let sort_latency = t0.elapsed();
    let total_latency = accepted_at.elapsed();
    let deadline_met = spec.deadline.map(|d| total_latency <= d);
    match run {
        Ok((merged, imbalance, bytes, merge_wall, transfer_ns)) => {
            stats.on_split(bytes, transfer_ns, merge_wall);
            JobResult {
                id: spec.id,
                elements: data.len(),
                dimension: spec.dimension,
                batched: false,
                queue_latency,
                sort_latency,
                total_latency,
                deadline: spec.deadline,
                deadline_met,
                sorted_ok: true,
                checksum: fnv1a(&merged),
                imbalance,
                skew_redivides: 0,
                retries: 0,
                error: None,
                output: retain.then_some(merged),
            }
        }
        Err(e) => JobResult {
            id: spec.id,
            elements: data.len(),
            dimension: spec.dimension,
            batched: false,
            queue_latency,
            sort_latency,
            total_latency,
            deadline: spec.deadline,
            deadline_met,
            sorted_ok: false,
            checksum: 0,
            imbalance: 0.0,
            skew_redivides: 0,
            retries: 0,
            error: Some(e.to_string()),
            output: None,
        },
    }
}

/// Sort one span through the shard's normal pipeline path, accounting
/// the sub-job into the shard's stats (one accepted, one completed or
/// failed — the per-shard invariant holds for split traffic too).
fn sort_span_on_shard(
    shard: &SortService,
    spec: &JobSpec,
    span: &[i32],
) -> Result<Option<Vec<i32>>> {
    if span.is_empty() {
        return Ok(None);
    }
    let lease = shard.plan_cache().lease(spec.dimension, spec.construction)?;
    shard.stats().on_submit(true);
    let t0 = Instant::now();
    let run = (|| -> Result<crate::pipeline::Outcome> {
        Ok(Session::single(&lease.net, &lease.plans, span)
            .with_divide_strategy(spec.strategy)
            .with_observer(shard.stats())
            .divide()?
            .local_sort()?
            .gather()?)
    })();
    let wall = t0.elapsed();
    let sub = |ok: bool, checksum: u64, imbalance: f64, redivides: u32, error: Option<String>| {
        JobResult {
            id: spec.id,
            elements: span.len(),
            dimension: spec.dimension,
            batched: false,
            queue_latency: Duration::ZERO,
            sort_latency: wall,
            total_latency: wall,
            deadline: None,
            deadline_met: None,
            sorted_ok: ok,
            checksum,
            imbalance,
            skew_redivides: redivides,
            retries: 0,
            error,
            output: None,
        }
    };
    match run {
        Ok(outcome) => {
            let ok = is_sorted(&outcome.sorted)
                && multiset_fingerprint(&outcome.sorted) == multiset_fingerprint(span);
            shard.stats().on_result(&sub(
                ok,
                fnv1a(&outcome.sorted),
                outcome.imbalance,
                outcome.skew_redivides,
                None,
            ));
            if ok {
                Ok(Some(outcome.sorted))
            } else {
                Err(Error::Invariant("shard span failed verification".into()))
            }
        }
        Err(e) => {
            shard.stats().on_result(&sub(false, 0, 0.0, 0, Some(e.to_string())));
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Construction, Distribution, DivideStrategy};

    fn spec(id: u64, elements: usize) -> JobSpec {
        JobSpec {
            id,
            distribution: Distribution::Random,
            elements,
            seed: 0xC0FFEE + id,
            dimension: 1,
            construction: Construction::FullGroup,
            strategy: DivideStrategy::PaperFixed,
            deadline: None,
        }
    }

    fn tiny_cluster(shards: usize, split_threshold: usize) -> Cluster {
        Cluster::start(ClusterConfig {
            shards,
            shard: ServiceConfig {
                workers: 1,
                retain_output: true,
                ..Default::default()
            },
            split_threshold,
            ..Default::default()
        })
    }

    #[test]
    fn routed_jobs_complete_on_their_home_shard() {
        let cluster = tiny_cluster(2, usize::MAX);
        let mut homes = Vec::new();
        for id in 0..8u64 {
            match cluster.submit(spec(id, 2_000)) {
                ClusterSubmission::Accepted { shard, ticket } => {
                    assert_eq!(shard, ticket.shard());
                    assert!(!ticket.is_split());
                    homes.push((ticket, shard.unwrap()));
                }
                ClusterSubmission::Rejected { reason } => panic!("rejected: {reason}"),
            }
        }
        for (ticket, home) in &homes {
            let r = ticket.wait_timeout(Duration::from_secs(60)).expect("result");
            assert!(r.sorted_ok, "{:?}", r.error);
            assert!(*home < 2);
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.routed, 8);
        assert_eq!(snap.split_jobs, 0);
        assert_eq!(snap.merged.completed, 8);
        assert_eq!(
            snap.shards.iter().map(|s| s.completed).sum::<u64>(),
            snap.merged.completed
        );
        let (final_snap, rest) = cluster.shutdown();
        assert!(rest.is_empty(), "all results already taken");
        assert_eq!(final_snap.merged.completed, 8);
    }

    #[test]
    fn split_job_output_matches_the_sequential_sort() {
        let cluster = tiny_cluster(3, 1_000);
        let job = spec(1, 12_000);
        let mut expect = job.generate();
        expect.sort_unstable();
        let sub = cluster.submit(job);
        assert!(sub.is_accepted());
        let ticket = sub.ticket().unwrap();
        assert!(ticket.is_split());
        assert!(!ticket.try_cancel(), "split jobs claim at submit");
        let r = ticket.wait_timeout(Duration::from_secs(120)).expect("split result");
        assert!(r.sorted_ok, "{:?}", r.error);
        assert_eq!(r.output.as_deref(), Some(expect.as_slice()));
        let snap = cluster.snapshot();
        assert_eq!(snap.split_jobs, 1);
        assert!(snap.cross_shard_bytes > 0, "spans must cross shards");
        assert!(snap.transfer.p50 > Duration::ZERO);
        // Every shard that sorted a span accounted it.
        for s in &snap.shards {
            assert_eq!(s.accepted, s.completed + s.failed);
        }
        cluster.shutdown();
    }

    #[test]
    fn split_shedding_is_explicit() {
        let cluster = Cluster::start(ClusterConfig {
            shards: 2,
            shard: ServiceConfig {
                workers: 1,
                ..Default::default()
            },
            split_threshold: 100,
            max_inflight_splits: 0,
            ..Default::default()
        });
        match cluster.submit(spec(0, 10_000)) {
            ClusterSubmission::Rejected {
                reason: RejectReason::Overloaded { .. },
            } => {}
            other => panic!("expected Overloaded shed, got {other:?}"),
        }
        assert_eq!(cluster.snapshot().split_rejected, 1);
        cluster.shutdown();
    }

    #[test]
    fn drain_covers_routed_and_split_results() {
        let cluster = tiny_cluster(2, 4_000);
        let mut accepted = 0;
        for id in 0..4u64 {
            // ids 0/2 small (routed), 1/3 big (split).
            let elements = if id % 2 == 0 { 2_000 } else { 9_000 };
            if cluster.submit(spec(id, elements)).is_accepted() {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 4);
        let mut got = Vec::new();
        while got.len() < accepted {
            match cluster.next_completion(Duration::from_secs(120)) {
                Some(r) => got.push(r.id),
                None => panic!("drain stalled with {} of {accepted}", got.len()),
            }
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert!(cluster.try_next_completion().is_none());
        cluster.shutdown();
    }
}
