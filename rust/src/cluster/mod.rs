//! The sharded scale-out service: OTIS groups mapped to shards.
//!
//! The OHHC is a two-level network — electronic links inside a
//! hexa-cell group, optical transpose links between groups — and that
//! is exactly the shape of a sharded serving cluster.  This module is
//! the cluster layer over [`crate::service`]:
//!
//! * a [`Cluster`] fronts N independent [`SortService`] shards, each
//!   with its own worker pool, [`PlanCache`](crate::campaign::PlanCache)
//!   leases, admission control, and fault plan;
//! * a deterministic rendezvous [`Router`] (consistent hashing on the
//!   [`job_key`]) homes every small job on one shard, so shard-local
//!   traffic stays on the electronic links of one "group";
//! * jobs too big for one shard take the **scatter/merge** path: the
//!   PSRS-style sampled splitter
//!   ([`divide_sampled`](crate::coordinator::divide_sampled)) cuts the
//!   input into per-shard spans, every shard sorts its span through
//!   the normal [`Session`](crate::pipeline::Session) pipeline on its
//!   own leased topology, and a k-way merge ([`kway_merge`])
//!   reassembles the result while the
//!   [`InterShardModel`](crate::sim::InterShardModel) charges the
//!   cross-shard traffic at the DES's optical-hop prices — the paper's
//!   §5 analytical story extended to cluster scale;
//! * ticket forwarding: [`Cluster::submit`] returns a
//!   [`ClusterSubmission`] whose [`ClusterTicket`] wraps a
//!   cluster-owned completion slot — poll, wait, cancel, exactly the
//!   service's per-job contract;
//! * observability: [`Cluster::snapshot`] merges every shard's
//!   [`ServiceStats`] at histogram level ([`ServiceStats::merge`]) so
//!   cluster percentiles are computed after the merge, never averaged,
//!   plus the cluster-only counters in [`ClusterStats`] (routed vs
//!   split, cross-shard bytes, failovers, span re-issues).
//!
//! # Resilience
//!
//! OTIS networks stay connected when the base graph is faulty (Ghosh
//! et al., arXiv:1109.1706), and the cluster honors that at serving
//! scale.  A [`HealthBoard`] runs one circuit breaker per shard
//! (Healthy → Suspect → Down → Probing, event-driven and seeded —
//! see [`health`](self::ShardHealth)); [`Cluster::submit`] routes
//! through [`Router::route_alive`] under the live routing mask, so a
//! Down shard's keys remap to their next-ranked survivor while every
//! healthy shard keeps its keyspace (minimal disruption, end to end).
//! A routed job whose shard fails it gets **exactly one** cross-shard
//! failover: the supervisor re-routes it via [`Router::route_failover`]
//! to the next-ranked live shard and counts it in
//! [`ClusterStats::failovers`]; a second failure (or nowhere to go) is
//! an explicit, named failure — never a silent drop.  A split job
//! whose span fails on one shard re-issues *only that span* to a
//! healthy shard before the merge; an unrecoverable span fails the
//! whole job with the span and shards named.  [`ClusterFaultPlan`]
//! injects seeded shard blackouts/brownouts above the per-shard
//! service [`FaultPlan`](crate::service::FaultPlan)s, and
//! [`Cluster::drain_shard`] / [`Cluster::rejoin_shard`] cover planned
//! maintenance.  Every path preserves the ledger:
//! `accepted == completed + failed`, per shard and cluster-wide.

mod faults;
mod health;
mod merge;
mod router;
mod stats;

pub use faults::{ClusterFaultPlan, FaultWindow, ShardFault, WindowKind};
pub use health::{HealthBoard, HealthConfig, HealthState, ShardHealth, ShardHealthSnapshot};
pub use merge::kway_merge;
pub use router::{job_key, Router};
pub use stats::{ClusterSnapshot, ClusterStats};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::LinkModel;
use crate::coordinator::divide_sampled;
use crate::error::{Error, Result};
use crate::pipeline::Session;
use crate::service::job::{fnv1a, multiset_fingerprint, JobResult, JobSpec};
use crate::service::loadgen::JobSink;
use crate::service::queue::RejectReason;
use crate::service::stats::{ServiceSnapshot, ServiceStats};
use crate::service::ticket::{JobTicket, Slot, Submission};
use crate::service::{ServiceConfig, SortService};
use crate::sim::transfer::InterShardModel;
use crate::sort::is_sorted;
use crate::topology::fault::splitmix64;

/// Cluster knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of shards (independent [`SortService`]s).
    pub shards: usize,
    /// Per-shard service configuration (cloned per shard).
    pub shard: ServiceConfig,
    /// Jobs with more keys than this take the scatter/merge path
    /// (single-shard clusters route everything regardless).
    pub split_threshold: usize,
    /// At most this many split jobs in flight; beyond it the cluster
    /// front door sheds explicitly.
    pub max_inflight_splits: usize,
    /// Router seed — same seed, same shard assignment, run after run.
    pub router_seed: u64,
    /// Link parameters pricing the cross-shard optical traffic.
    pub link: LinkModel,
    /// Cluster-level fault injection (shard blackouts/brownouts).
    pub faults: ClusterFaultPlan,
    /// Per-shard circuit-breaker thresholds and probe schedule.
    pub health: HealthConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            shards: 2,
            shard: ServiceConfig::default(),
            split_threshold: 65_536,
            max_inflight_splits: 8,
            router_seed: 0x0715C,
            link: LinkModel::default(),
            faults: ClusterFaultPlan::none(),
            health: HealthConfig::default(),
        }
    }
}

/// The tenant's handle to one accepted cluster job.
#[derive(Debug)]
pub struct ClusterTicket {
    shard: Option<usize>,
    inner: JobTicket,
}

impl ClusterTicket {
    /// The job id this ticket tracks.
    pub fn id(&self) -> u64 {
        self.inner.id()
    }

    /// The home shard a routed job was first dispatched to (a failover
    /// may finish it elsewhere); `None` for a split job (it ran on
    /// every shard).
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }

    /// Did this job take the scatter/merge path?
    pub fn is_split(&self) -> bool {
        self.shard.is_none()
    }

    /// Non-blocking status read (see
    /// [`JobTicket::poll`]).
    pub fn poll(&self) -> crate::service::TicketStatus {
        self.inner.poll()
    }

    /// Non-blocking result take: `Some` exactly once.
    pub fn try_result(&self) -> Option<JobResult> {
        self.inner.try_result()
    }

    /// Block until the result is ready (or `timeout` passes), then
    /// take it.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<JobResult> {
        self.inner.wait_timeout(timeout)
    }

    /// Cancel delivery.  A routed job stays cancellable until the
    /// failover supervisor publishes its result (the shard-side work
    /// may still run to completion, but its result is discarded, never
    /// delivered).  Split jobs claim their slot at submit, so they
    /// always lose this race — by design: the scatter begins
    /// immediately.
    pub fn try_cancel(&self) -> bool {
        self.inner.try_cancel()
    }
}

/// Outcome of one [`Cluster::submit`].
#[derive(Debug)]
pub enum ClusterSubmission {
    /// Accepted; `shard` is the home shard (`None` for a split job).
    Accepted {
        /// Home shard index, or `None` when the job was split.
        shard: Option<usize>,
        /// The job's completion handle.
        ticket: ClusterTicket,
    },
    /// Turned away — nothing was enqueued anywhere.
    Rejected {
        /// Why.
        reason: RejectReason,
    },
}

impl ClusterSubmission {
    /// Did the job make it in?
    pub fn is_accepted(&self) -> bool {
        matches!(self, ClusterSubmission::Accepted { .. })
    }

    /// The ticket, consuming the submission (`None` when rejected).
    pub fn ticket(self) -> Option<ClusterTicket> {
        match self {
            ClusterSubmission::Accepted { ticket, .. } => Some(ticket),
            ClusterSubmission::Rejected { .. } => None,
        }
    }
}

/// Finished cluster-owned slots (routed and split) for the drain,
/// plus the split in-flight gauge the front door sheds on.
#[derive(Debug, Default)]
struct Completions {
    done: Mutex<VecDeque<Arc<Slot>>>,
    ready: Condvar,
    inflight_splits: AtomicUsize,
}

/// One routed job the supervisor is tracking: the shard-side ticket
/// it polls and the cluster-owned outer slot it publishes into.
#[derive(Debug)]
struct RoutedPending {
    spec: JobSpec,
    key: u64,
    shard: usize,
    first_shard: usize,
    attempt: u32,
    event: u64,
    slow: Duration,
    inner: JobTicket,
    outer: Arc<Slot>,
    accepted_at: Instant,
}

/// Supervisor shared state.
#[derive(Debug, Default)]
struct RoutedShared {
    pending: Mutex<Vec<RoutedPending>>,
    wake: Condvar,
    closing: AtomicBool,
}

/// Everything the cluster's threads share.
struct Core {
    cfg: ClusterConfig,
    shards: Vec<SortService>,
    router: Router,
    transfer: InterShardModel,
    stats: ClusterStats,
    health: HealthBoard,
    completions: Completions,
    routed: RoutedShared,
}

/// Outcome of dispatching one attempt onto one shard.
enum Dispatch {
    /// The shard queued it; the supervisor will poll `inner`.
    Inflight { inner: JobTicket, slow: Duration },
    /// The shard's admission control said no.
    Rejected { reason: RejectReason },
    /// The cluster fault plan failed the attempt at the shard
    /// boundary (charged to that shard's ledger).
    Failed { error: String },
}

/// Outcome of the one allowed cross-shard failover.
enum Failover {
    /// Re-routed; the retry is in flight on `shard`.
    Inflight {
        shard: usize,
        inner: JobTicket,
        slow: Duration,
    },
    /// Nothing could save the job; fail it explicitly with `error`.
    Exhausted { error: String },
}

/// N sort-service shards behind one deterministic router, plus the
/// failover supervisor and split workers.
pub struct Cluster {
    core: Arc<Core>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
    splitters: Mutex<Vec<JoinHandle<()>>>,
}

impl Cluster {
    /// Start `cfg.shards` independent shards and the failover
    /// supervisor.
    ///
    /// # Panics
    /// When `cfg.faults` names a shard the cluster does not have or
    /// carries an out-of-range rate (the CLI validates first and
    /// reports nicely; programmatic callers get the panic).
    pub fn start(cfg: ClusterConfig) -> Cluster {
        let n = cfg.shards.max(1);
        cfg.faults.validate(n).expect("cluster fault plan");
        let shards: Vec<SortService> =
            (0..n).map(|_| SortService::start(cfg.shard.clone())).collect();
        let core = Arc::new(Core {
            router: Router::new(n, cfg.router_seed),
            transfer: InterShardModel::new(cfg.link),
            shards,
            stats: ClusterStats::new(),
            health: HealthBoard::new(n, cfg.health.clone()),
            completions: Completions::default(),
            routed: RoutedShared::default(),
            cfg,
        });
        let supervisor = {
            let core = Arc::clone(&core);
            std::thread::Builder::new()
                .name("ohhc-cluster-supervisor".into())
                .spawn(move || supervise(&core))
                .expect("spawn cluster supervisor")
        };
        Cluster {
            core,
            supervisor: Mutex::new(Some(supervisor)),
            splitters: Mutex::new(Vec::new()),
        }
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.core.shards.len()
    }

    /// Shard `i`'s service (tests, diagnostics).
    pub fn shard(&self, i: usize) -> &SortService {
        &self.core.shards[i]
    }

    /// The router in use.
    pub fn router(&self) -> &Router {
        &self.core.router
    }

    /// Live cluster-level counters.
    pub fn stats(&self) -> &ClusterStats {
        &self.core.stats
    }

    /// Administratively drain shard `i`: no new routes, failovers, or
    /// span re-issues land on it, while everything already queued
    /// there finishes normally.
    pub fn drain_shard(&self, i: usize) {
        self.core.health.drain(i);
    }

    /// Rejoin a drained shard.  Its full rendezvous assignment comes
    /// straight back — the router never stopped *hashing* the shard,
    /// only admitting it — so exactly the keys that left return.
    pub fn rejoin_shard(&self, i: usize) {
        self.core.health.rejoin(i);
    }

    /// Submit one job.  Small jobs route to a live home shard
    /// (consistent hashing on [`job_key`] over the health board's
    /// routing mask); jobs above the split threshold scatter across
    /// every shard and merge back.
    pub fn submit(&self, spec: JobSpec) -> ClusterSubmission {
        if self.core.shards.len() > 1 && spec.elements > self.core.cfg.split_threshold {
            self.submit_split(spec)
        } else {
            self.submit_routed(spec)
        }
    }

    fn submit_routed(&self, spec: JobSpec) -> ClusterSubmission {
        let core = &self.core;
        let event = core.health.tick();
        let key = job_key(&spec);
        let mask = core.health.routing_mask();
        let Some(shard) = core.router.route_alive(key, &mask) else {
            return ClusterSubmission::Rejected {
                reason: RejectReason::Unavailable,
            };
        };
        let outer = Slot::new(spec.id);
        let ticket = ClusterTicket {
            shard: Some(shard),
            inner: JobTicket::new(Arc::clone(&outer)),
        };
        let accepted_at = Instant::now();
        match core.dispatch_routed(&spec, shard, event, 0) {
            Dispatch::Inflight { inner, slow } => {
                core.stats.on_routed();
                core.enqueue_pending(RoutedPending {
                    spec,
                    key,
                    shard,
                    first_shard: shard,
                    attempt: 0,
                    event,
                    slow,
                    inner,
                    outer,
                    accepted_at,
                });
                ClusterSubmission::Accepted {
                    shard: Some(shard),
                    ticket,
                }
            }
            Dispatch::Rejected { reason } => ClusterSubmission::Rejected { reason },
            Dispatch::Failed { error } => {
                // The fault plan killed the attempt at the shard
                // boundary.  The job *is* accepted at the cluster —
                // it fails over right now, synchronously.
                core.stats.on_routed();
                match core.failover_routed(&spec, key, shard, event) {
                    Failover::Inflight {
                        shard: next,
                        inner,
                        slow,
                    } => core.enqueue_pending(RoutedPending {
                        spec,
                        key,
                        shard: next,
                        first_shard: shard,
                        attempt: 1,
                        event,
                        slow,
                        inner,
                        outer,
                        accepted_at,
                    }),
                    Failover::Exhausted { error: then } => {
                        let why = format!("{error}; {then}");
                        core.publish(outer, synth_cluster_failure(&spec, accepted_at, why));
                    }
                }
                ClusterSubmission::Accepted {
                    shard: Some(shard),
                    ticket,
                }
            }
        }
    }

    fn submit_split(&self, spec: JobSpec) -> ClusterSubmission {
        let core = &self.core;
        if let Err(e) = spec.validate() {
            return ClusterSubmission::Rejected {
                reason: RejectReason::Invalid {
                    detail: e.to_string(),
                },
            };
        }
        let event = core.health.tick();
        let inflight = core.completions.inflight_splits.fetch_add(1, Ordering::AcqRel);
        if inflight >= core.cfg.max_inflight_splits {
            core.completions.inflight_splits.fetch_sub(1, Ordering::AcqRel);
            core.stats.on_split_rejected();
            return ClusterSubmission::Rejected {
                reason: RejectReason::Overloaded {
                    depth: inflight,
                    shed_depth: core.cfg.max_inflight_splits,
                },
            };
        }
        core.stats.on_split_accepted();
        let slot = Slot::new(spec.id);
        // The scatter begins immediately: claim now so a cancel can
        // never race a job that is already generating its input.
        assert!(slot.claim(), "fresh slot must claim");
        let ticket = ClusterTicket {
            shard: None,
            inner: JobTicket::new(Arc::clone(&slot)),
        };
        let accepted_at = Instant::now();
        let home = core.router.route(job_key(&spec));
        let core_handle = Arc::clone(core);
        let retain = core.cfg.shard.retain_output;
        let handle = std::thread::Builder::new()
            .name(format!("ohhc-split-{}", spec.id))
            .spawn(move || {
                let core = &*core_handle;
                let result = execute_split(core, &spec, home, event, retain, accepted_at);
                // Publication races the drain loop in `next_completion`
                // and any direct ticket wait.
                crate::interleave!("cluster/split-complete");
                slot.complete(result);
                crate::interleave!("cluster/split-enqueue");
                let mut q = core.completions.done.lock().unwrap();
                q.push_back(slot);
                drop(q);
                core.completions.ready.notify_all();
                core.completions.inflight_splits.fetch_sub(1, Ordering::AcqRel);
            })
            .expect("spawn split worker");
        self.splitters.lock().unwrap().push(handle);
        ClusterSubmission::Accepted {
            shard: None,
            ticket,
        }
    }

    /// Wait up to `timeout` for any finished job (routed or split)
    /// whose result nobody has taken yet, and take it.  Routed results
    /// arrive here through the supervisor's outer slots — never by
    /// raiding the shards' own completion queues, which the supervisor
    /// owns.
    pub fn next_completion(&self, timeout: Duration) -> Option<JobResult> {
        const TICK: Duration = Duration::from_millis(1);
        let deadline = Instant::now().checked_add(timeout);
        loop {
            {
                // Drain racing concurrent drains and the split workers'
                // complete-then-enqueue publication sequence.
                crate::interleave!("cluster/drain");
                let mut q = self.core.completions.done.lock().unwrap();
                while let Some(slot) = q.pop_front() {
                    if let Some(r) = slot.take() {
                        return Some(r);
                    }
                }
            }
            let wait = match deadline {
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    (deadline - now).min(TICK)
                }
                None => TICK,
            };
            let q = self.core.completions.done.lock().unwrap();
            let _ = self.core.completions.ready.wait_timeout(q, wait).unwrap();
        }
    }

    /// Non-blocking [`Self::next_completion`].
    pub fn try_next_completion(&self) -> Option<JobResult> {
        self.next_completion(Duration::ZERO)
    }

    /// Freeze the cluster view: per-shard snapshots, the
    /// histogram-level merge ([`ServiceStats::merge`]), and per-shard
    /// breaker health.
    pub fn snapshot(&self) -> ClusterSnapshot {
        let merged = ServiceStats::new();
        let mut per = Vec::with_capacity(self.core.shards.len());
        for shard in &self.core.shards {
            merged.merge(shard.stats());
            per.push(shard.stats().snapshot());
        }
        self.core.stats.freeze(per, merged.snapshot(), self.core.health.snapshot())
    }

    /// Graceful shutdown: join every split worker, let the supervisor
    /// drain its in-flight routed jobs, shut each shard down (their
    /// backlogs still execute), and return the final snapshot plus
    /// every result nobody took.  Drain completions first (as loadgen
    /// does) if the merged histograms must cover every job — the merge
    /// is frozen as the shards close.
    pub fn shutdown(self) -> (ClusterSnapshot, Vec<JobResult>) {
        let Cluster {
            core,
            supervisor,
            splitters,
        } = self;
        for h in splitters.into_inner().unwrap() {
            let _ = h.join();
        }
        core.routed.closing.store(true, Ordering::Release);
        core.routed.wake.notify_all();
        if let Some(h) = supervisor.into_inner().unwrap().take() {
            let _ = h.join();
        }
        let mut rest = Vec::new();
        {
            let mut q = core.completions.done.lock().unwrap();
            while let Some(slot) = q.pop_front() {
                if let Some(r) = slot.take() {
                    rest.push(r);
                }
            }
        }
        let Ok(core) = Arc::try_unwrap(core) else {
            unreachable!("supervisor and split workers joined; no handle outlives the cluster")
        };
        let Core {
            shards,
            stats,
            health,
            ..
        } = core;
        let merged = ServiceStats::new();
        for shard in &shards {
            merged.merge(shard.stats());
        }
        let health_snap = health.snapshot();
        let mut finals = Vec::with_capacity(shards.len());
        for shard in shards {
            let (snap, leftover) = shard.shutdown();
            finals.push(snap);
            rest.extend(leftover);
        }
        (stats.freeze(finals, merged.snapshot(), health_snap), rest)
    }
}

impl JobSink for Cluster {
    fn offer(&self, spec: JobSpec) -> bool {
        self.submit(spec).is_accepted()
    }

    fn drain_next(&self, timeout: Duration) -> Option<JobResult> {
        self.next_completion(timeout)
    }

    fn stats_snapshot(&self) -> ServiceSnapshot {
        self.snapshot().merged
    }
}

impl Core {
    /// Dispatch one attempt of a routed job onto `shard`, applying the
    /// cluster fault plan first.  A blackout (window or rate draw)
    /// fails the attempt at the shard boundary, charged to that
    /// shard's ledger (`accepted == completed + failed` holds for
    /// synthesized failures too); a brownout lets it run and returns
    /// the virtual latency to charge.
    fn dispatch_routed(&self, spec: &JobSpec, shard: usize, event: u64, attempt: u32) -> Dispatch {
        let mut slow = Duration::ZERO;
        match self.cfg.faults.draw(shard, event, spec.id, attempt) {
            Some(ShardFault::Fail { reason }) => {
                let error = format!("shard {shard}: {reason}");
                let stats = self.shards[shard].stats();
                stats.on_submit(true);
                stats.on_result(&synth_shard_failure(spec, spec.elements, &error));
                self.health.record_failure(shard);
                return Dispatch::Failed { error };
            }
            Some(ShardFault::Slow { delay }) => slow = delay,
            None => {}
        }
        match self.shards[shard].submit(spec.clone()) {
            Submission::Accepted { ticket, .. } => Dispatch::Inflight {
                inner: ticket,
                slow,
            },
            Submission::Rejected { reason } => {
                self.health.record_rejection(shard);
                Dispatch::Rejected { reason }
            }
        }
    }

    /// The one allowed cross-shard failover of a routed job whose
    /// attempt on `failed` did not survive: re-route via rendezvous to
    /// the next-ranked live shard and dispatch attempt 1 there.
    fn failover_routed(&self, spec: &JobSpec, key: u64, failed: usize, event: u64) -> Failover {
        let alive = self.health.alive_mask();
        let Some(next) = self.router.route_failover(key, &alive, failed) else {
            self.stats.on_failover_exhausted();
            return Failover::Exhausted {
                error: format!("no live shard left to fail job {} over to", spec.id),
            };
        };
        match self.dispatch_routed(spec, next, event, 1) {
            Dispatch::Inflight { inner, slow } => {
                self.stats.on_failover();
                Failover::Inflight {
                    shard: next,
                    inner,
                    slow,
                }
            }
            Dispatch::Rejected { reason } => {
                self.stats.on_failover_exhausted();
                Failover::Exhausted {
                    error: format!("failover to shard {next} rejected: {reason}"),
                }
            }
            Dispatch::Failed { error } => {
                self.stats.on_failover();
                self.stats.on_failover_exhausted();
                Failover::Exhausted {
                    error: format!("failover to shard {next} failed: {error}"),
                }
            }
        }
    }

    fn enqueue_pending(&self, entry: RoutedPending) {
        let mut p = self.routed.pending.lock().unwrap();
        p.push(entry);
        drop(p);
        self.routed.wake.notify_all();
    }

    /// Advance one tracked routed job.  Returns the entry back when it
    /// is still in flight, `None` once it has been resolved (published,
    /// failed over into a new entry, or cancelled away).
    fn step_pending(&self, entry: RoutedPending) -> Option<RoutedPending> {
        if entry.outer.is_cancelled() && entry.inner.try_cancel() {
            // Tenant cancelled before the shard started the job:
            // nothing ran, nothing to deliver.  (If the shard already
            // claimed it, the result arrives below and is discarded by
            // the cancelled outer slot.)
            return None;
        }
        let Some(mut r) = entry.inner.try_result() else {
            return Some(entry);
        };
        let RoutedPending {
            spec,
            key,
            shard,
            first_shard,
            attempt,
            event,
            slow,
            outer,
            accepted_at,
            ..
        } = entry;
        charge_slow(&mut r, slow);
        let failed = r.error.is_some() || !r.sorted_ok;
        if !failed {
            self.health.record_success(shard);
            if attempt > 0 {
                finalize_failover(&mut r, spec.deadline, accepted_at.elapsed(), true);
            }
            self.publish(outer, r);
            return None;
        }
        self.health.record_failure(shard);
        if attempt == 0 {
            match self.failover_routed(&spec, key, shard, event) {
                Failover::Inflight {
                    shard: next,
                    inner,
                    slow,
                } => {
                    return Some(RoutedPending {
                        spec,
                        key,
                        shard: next,
                        first_shard,
                        attempt: 1,
                        event,
                        slow,
                        inner,
                        outer,
                        accepted_at,
                    });
                }
                Failover::Exhausted { error } => {
                    let cause = r.error.take().unwrap_or_else(|| "failed verification".into());
                    r.error = Some(format!("shard {shard}: {cause}; {error}"));
                    finalize_failover(&mut r, spec.deadline, accepted_at.elapsed(), false);
                    self.publish(outer, r);
                    return None;
                }
            }
        }
        // Failed again after the one allowed failover: explicit.
        self.stats.on_failover_exhausted();
        let cause = r.error.take().unwrap_or_else(|| "failed verification".into());
        r.error = Some(format!(
            "job {} failed over from shard {first_shard} to {shard} and failed again: {cause}",
            spec.id
        ));
        finalize_failover(&mut r, spec.deadline, accepted_at.elapsed(), true);
        self.publish(outer, r);
        None
    }

    /// Publish a routed result into the cluster completion queue
    /// through its outer slot.  A cancelled slot refuses the claim and
    /// the result is dropped — the tenant asked for exactly that.
    fn publish(&self, outer: Arc<Slot>, r: JobResult) {
        if outer.claim() {
            outer.complete(r);
            let mut q = self.completions.done.lock().unwrap();
            q.push_back(outer);
            drop(q);
            self.completions.ready.notify_all();
        }
    }
}

/// The supervisor loop: poll every tracked routed job, drive
/// failovers, and feed the health board from each shard's stats
/// deltas.  Exits once the cluster is closing and nothing is pending.
fn supervise(core: &Core) {
    const TICK: Duration = Duration::from_millis(1);
    loop {
        let batch = std::mem::take(&mut *core.routed.pending.lock().unwrap());
        let mut keep = Vec::with_capacity(batch.len());
        for entry in batch {
            if let Some(still) = core.step_pending(entry) {
                keep.push(still);
            }
        }
        let empty = {
            let mut p = core.routed.pending.lock().unwrap();
            // Submissions that arrived mid-scan sit in `p` already.
            p.extend(keep);
            p.is_empty()
        };
        for (i, shard) in core.shards.iter().enumerate() {
            let s = shard.stats();
            core.health.absorb_stats(i, s.completed(), s.failed(), s.rejected());
        }
        if empty && core.routed.closing.load(Ordering::Acquire) {
            return;
        }
        let guard = core.routed.pending.lock().unwrap();
        let _ = core.routed.wake.wait_timeout(guard, TICK).unwrap();
    }
}

/// Fold a brownout's virtual latency into a result and re-judge its
/// deadline — the same virtual-pricing treatment the
/// [`InterShardModel`] gives cross-shard bytes; no thread ever slept.
fn charge_slow(r: &mut JobResult, slow: Duration) {
    if slow.is_zero() {
        return;
    }
    r.sort_latency += slow;
    r.total_latency += slow;
    if let Some(d) = r.deadline {
        r.deadline_met = Some(r.total_latency <= d);
    }
}

/// Re-judge a routed result that reached the tenant through the
/// failover path.  The deadline is judged against the *whole journey*
/// — queue, failed first attempt, failover, retry — never the winning
/// attempt alone, and the extra attempt is visible in `retries`.
fn finalize_failover(
    r: &mut JobResult,
    deadline: Option<Duration>,
    elapsed: Duration,
    retried: bool,
) {
    if retried {
        r.retries += 1;
    }
    if elapsed > r.total_latency {
        r.queue_latency = elapsed.saturating_sub(r.sort_latency);
        r.total_latency = elapsed;
    }
    r.deadline = deadline;
    r.deadline_met = deadline.map(|d| r.total_latency <= d);
}

/// A zero-latency failed result charged to a shard's ledger for an
/// attempt the fault plan killed before the shard ever ran it — the
/// synthesized counterpart of a real pipeline failure, keeping
/// `accepted == completed + failed` exact under blackouts.
fn synth_shard_failure(spec: &JobSpec, elements: usize, error: &str) -> JobResult {
    JobResult {
        id: spec.id,
        elements,
        dimension: spec.dimension,
        batched: false,
        queue_latency: Duration::ZERO,
        sort_latency: Duration::ZERO,
        total_latency: Duration::ZERO,
        deadline: None,
        deadline_met: None,
        sorted_ok: false,
        checksum: 0,
        imbalance: 0.0,
        skew_redivides: 0,
        retries: 0,
        error: Some(error.to_string()),
        output: None,
    }
}

/// The explicit cluster-level failure delivered to the tenant when a
/// routed job could not be saved (its shard attempts are already on
/// the shard ledgers; this is the tenant-facing copy).
fn synth_cluster_failure(spec: &JobSpec, accepted_at: Instant, error: String) -> JobResult {
    let total = accepted_at.elapsed();
    JobResult {
        id: spec.id,
        elements: spec.elements,
        dimension: spec.dimension,
        batched: false,
        queue_latency: total,
        sort_latency: Duration::ZERO,
        total_latency: total,
        deadline: spec.deadline,
        deadline_met: spec.deadline.map(|d| total <= d),
        sorted_ok: false,
        checksum: 0,
        imbalance: 0.0,
        skew_redivides: 0,
        retries: 0,
        error: Some(error),
        output: None,
    }
}

/// The scatter/merge path, run on a dedicated split worker thread:
/// sampled split into per-shard spans, one pipeline session per shard
/// on that shard's leased topology (accounted into that shard's
/// stats), per-span failure recovery, k-way merge, full verification,
/// optical transfer charge.
fn execute_split(
    core: &Core,
    spec: &JobSpec,
    home: usize,
    event: u64,
    retain: bool,
    accepted_at: Instant,
) -> JobResult {
    let data = spec.generate();
    let t0 = Instant::now();
    let queue_latency = t0.duration_since(accepted_at);
    let n = core.shards.len();
    let span_faults: Vec<Option<ShardFault>> =
        (0..n).map(|i| core.cfg.faults.draw(i, event, spec.id, 0)).collect();
    // Brownouts price the job, not a thread: spans run concurrently,
    // so the virtual charge is the worst shard's delay.
    let slow = span_faults.iter().flatten().fold(Duration::ZERO, |acc, f| match f {
        ShardFault::Slow { delay } => acc.max(*delay),
        ShardFault::Fail { .. } => acc,
    });
    let run = (|| -> Result<(Vec<i32>, f64, u64, Duration, f64)> {
        let divided = divide_sampled(&data, n)?;
        let imbalance = divided.imbalance();
        let sizes = divided.sizes();
        // One session per shard, concurrently; each shard leases its
        // own (dimension, construction) bundle from its own PlanCache
        // and its stats observe the session's stage boundaries.  A
        // span blacked out by the fault plan fails at the shard
        // boundary, charged to that shard's ledger.
        let spans: Vec<&[i32]> = (0..n).map(|b| divided.buckets.bucket(b)).collect();
        let parts: Vec<Result<Option<Vec<i32>>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = spans
                .iter()
                .enumerate()
                .map(|(i, &span)| {
                    let shard = &core.shards[i];
                    let fault = span_faults[i];
                    scope.spawn(move || -> Result<Option<Vec<i32>>> {
                        if span.is_empty() {
                            return Ok(None);
                        }
                        if let Some(ShardFault::Fail { reason }) = fault {
                            let error = format!("shard {i}: {reason}");
                            shard.stats().on_submit(true);
                            shard.stats().on_result(&synth_shard_failure(
                                spec,
                                span.len(),
                                &error,
                            ));
                            return Err(Error::Invariant(error));
                        }
                        shard.stats().on_submit(true);
                        sort_span_on_shard(shard, spec, span)
                    })
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(i, h)| {
                    h.join().unwrap_or_else(|_| {
                        // A panicked span sorter is an explicit
                        // per-span failure on that shard's ledger
                        // (the span's accepted mark is balanced by
                        // this failed result), never a bare invariant.
                        let error = format!("shard {i}: span sorter panicked");
                        let stats = core.shards[i].stats();
                        stats.on_worker_panic();
                        stats.on_result(&synth_shard_failure(spec, spans[i].len(), &error));
                        Err(Error::Invariant(error))
                    })
                })
                .collect()
        });
        let mut sorted_parts: Vec<Vec<i32>> = Vec::with_capacity(n);
        for (i, part) in parts.into_iter().enumerate() {
            match part {
                Ok(Some(p)) => {
                    core.health.record_success(i);
                    sorted_parts.push(p);
                }
                Ok(None) => {}
                Err(e) => {
                    core.health.record_failure(i);
                    sorted_parts.push(reissue_span(core, spec, event, i, spans[i], &e)?);
                }
            }
        }
        let refs: Vec<&[i32]> = sorted_parts.iter().map(Vec::as_slice).collect();
        let merge_t0 = Instant::now();
        let merged = kway_merge(&refs);
        let merge_wall = merge_t0.elapsed();
        if merged.len() != data.len()
            || !is_sorted(&merged)
            || multiset_fingerprint(&merged) != multiset_fingerprint(&data)
        {
            return Err(Error::Invariant(
                "cluster merge is not a sorted permutation of the input".into(),
            ));
        }
        let charge = core.transfer.split_transfer(home, &sizes);
        Ok((
            merged,
            imbalance,
            charge.cross_shard_bytes,
            merge_wall,
            charge.transfer_ns,
        ))
    })();
    let sort_latency = t0.elapsed() + slow;
    let total_latency = accepted_at.elapsed() + slow;
    let deadline_met = spec.deadline.map(|d| total_latency <= d);
    match run {
        Ok((merged, imbalance, bytes, merge_wall, transfer_ns)) => {
            core.stats.on_split_transfer(bytes, transfer_ns, merge_wall);
            JobResult {
                id: spec.id,
                elements: data.len(),
                dimension: spec.dimension,
                batched: false,
                queue_latency,
                sort_latency,
                total_latency,
                deadline: spec.deadline,
                deadline_met,
                sorted_ok: true,
                checksum: fnv1a(&merged),
                imbalance,
                skew_redivides: 0,
                retries: 0,
                error: None,
                output: retain.then_some(merged),
            }
        }
        Err(e) => JobResult {
            id: spec.id,
            elements: data.len(),
            dimension: spec.dimension,
            batched: false,
            queue_latency,
            sort_latency,
            total_latency,
            deadline: spec.deadline,
            deadline_met,
            sorted_ok: false,
            checksum: 0,
            imbalance: 0.0,
            skew_redivides: 0,
            retries: 0,
            error: Some(e.to_string()),
            output: None,
        },
    }
}

/// Re-issue one failed span to the next-ranked live shard — exactly
/// one attempt, charged to the target shard's ledger and counted in
/// [`ClusterStats::span_reissues`].  An unrecoverable span fails the
/// whole split job with the span and every shard involved named.
fn reissue_span(
    core: &Core,
    spec: &JobSpec,
    event: u64,
    from: usize,
    span: &[i32],
    cause: &Error,
) -> Result<Vec<i32>> {
    let key = splitmix64(job_key(spec) ^ (from as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let alive = core.health.alive_mask();
    let Some(target) = core.router.route_failover(key, &alive, from) else {
        return Err(Error::Invariant(format!(
            "split job {}: span {from} failed on shard {from} ({cause}) \
             and no live shard remains to re-issue it",
            spec.id
        )));
    };
    if let Some(ShardFault::Fail { reason }) = core.cfg.faults.draw(target, event, spec.id, 1) {
        let error = format!("shard {target}: {reason}");
        let stats = core.shards[target].stats();
        stats.on_submit(true);
        stats.on_result(&synth_shard_failure(spec, span.len(), &error));
        core.health.record_failure(target);
        return Err(Error::Invariant(format!(
            "split job {}: span {from} failed on shard {from} ({cause}); \
             re-issue to shard {target} failed: {error}",
            spec.id
        )));
    }
    core.stats.on_span_reissue();
    core.shards[target].stats().on_submit(true);
    match sort_span_on_shard(&core.shards[target], spec, span) {
        Ok(Some(p)) => {
            core.health.record_success(target);
            Ok(p)
        }
        Ok(None) => unreachable!("failed spans are never empty"),
        Err(e) => {
            core.health.record_failure(target);
            Err(Error::Invariant(format!(
                "split job {}: span {from} failed on shard {from} ({cause}); \
                 re-issue to shard {target} failed: {e}",
                spec.id
            )))
        }
    }
}

/// Sort one span through the shard's normal pipeline path.  The
/// caller has already recorded the accepted submission
/// (`on_submit(true)`); this function records exactly one matching
/// result on every non-panic path — lease errors included — so the
/// per-shard invariant holds for split traffic too.
fn sort_span_on_shard(
    shard: &SortService,
    spec: &JobSpec,
    span: &[i32],
) -> Result<Option<Vec<i32>>> {
    if span.is_empty() {
        return Ok(None);
    }
    let t0 = Instant::now();
    let run = (|| -> Result<crate::pipeline::Outcome> {
        let lease = shard.plan_cache().lease(spec.dimension, spec.construction)?;
        Ok(Session::single(&lease.net, &lease.plans, span)
            .with_divide_strategy(spec.strategy)
            .with_observer(shard.stats())
            .divide()?
            .local_sort()?
            .gather()?)
    })();
    let wall = t0.elapsed();
    let sub = |ok: bool, checksum: u64, imbalance: f64, redivides: u32, error: Option<String>| {
        JobResult {
            id: spec.id,
            elements: span.len(),
            dimension: spec.dimension,
            batched: false,
            queue_latency: Duration::ZERO,
            sort_latency: wall,
            total_latency: wall,
            deadline: None,
            deadline_met: None,
            sorted_ok: ok,
            checksum,
            imbalance,
            skew_redivides: redivides,
            retries: 0,
            error,
            output: None,
        }
    };
    match run {
        Ok(outcome) => {
            let ok = is_sorted(&outcome.sorted)
                && multiset_fingerprint(&outcome.sorted) == multiset_fingerprint(span);
            shard.stats().on_result(&sub(
                ok,
                fnv1a(&outcome.sorted),
                outcome.imbalance,
                outcome.skew_redivides,
                None,
            ));
            if ok {
                Ok(Some(outcome.sorted))
            } else {
                Err(Error::Invariant("shard span failed verification".into()))
            }
        }
        Err(e) => {
            shard.stats().on_result(&sub(false, 0, 0.0, 0, Some(e.to_string())));
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Construction, Distribution, DivideStrategy};

    fn spec(id: u64, elements: usize) -> JobSpec {
        JobSpec {
            id,
            distribution: Distribution::Random,
            elements,
            seed: 0xC0FFEE + id,
            dimension: 1,
            construction: Construction::FullGroup,
            strategy: DivideStrategy::PaperFixed,
            deadline: None,
        }
    }

    fn tiny_cluster(shards: usize, split_threshold: usize) -> Cluster {
        Cluster::start(ClusterConfig {
            shards,
            shard: ServiceConfig {
                workers: 1,
                retain_output: true,
                ..Default::default()
            },
            split_threshold,
            ..Default::default()
        })
    }

    #[test]
    fn routed_jobs_complete_on_their_home_shard() {
        let cluster = tiny_cluster(2, usize::MAX);
        let mut homes = Vec::new();
        for id in 0..8u64 {
            match cluster.submit(spec(id, 2_000)) {
                ClusterSubmission::Accepted { shard, ticket } => {
                    assert_eq!(shard, ticket.shard());
                    assert!(!ticket.is_split());
                    homes.push((ticket, shard.unwrap()));
                }
                ClusterSubmission::Rejected { reason } => panic!("rejected: {reason}"),
            }
        }
        for (ticket, home) in &homes {
            let r = ticket.wait_timeout(Duration::from_secs(60)).expect("result");
            assert!(r.sorted_ok, "{:?}", r.error);
            assert!(*home < 2);
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.routed, 8);
        assert_eq!(snap.split_jobs, 0);
        assert_eq!(snap.failovers, 0);
        assert_eq!(snap.merged.completed, 8);
        assert_eq!(
            snap.shards.iter().map(|s| s.completed).sum::<u64>(),
            snap.merged.completed
        );
        assert!(snap.health.iter().all(|h| h.state == HealthState::Healthy));
        let (final_snap, rest) = cluster.shutdown();
        assert!(rest.is_empty(), "all results already taken");
        assert_eq!(final_snap.merged.completed, 8);
    }

    #[test]
    fn split_job_output_matches_the_sequential_sort() {
        let cluster = tiny_cluster(3, 1_000);
        let job = spec(1, 12_000);
        let mut expect = job.generate();
        expect.sort_unstable();
        let sub = cluster.submit(job);
        assert!(sub.is_accepted());
        let ticket = sub.ticket().unwrap();
        assert!(ticket.is_split());
        assert!(!ticket.try_cancel(), "split jobs claim at submit");
        let r = ticket.wait_timeout(Duration::from_secs(120)).expect("split result");
        assert!(r.sorted_ok, "{:?}", r.error);
        assert_eq!(r.output.as_deref(), Some(expect.as_slice()));
        let snap = cluster.snapshot();
        assert_eq!(snap.split_jobs, 1);
        assert!(snap.cross_shard_bytes > 0, "spans must cross shards");
        assert!(snap.transfer.p50 > Duration::ZERO);
        // Every shard that sorted a span accounted it.
        for s in &snap.shards {
            assert_eq!(s.accepted, s.completed + s.failed);
        }
        cluster.shutdown();
    }

    #[test]
    fn split_shedding_is_explicit() {
        let cluster = Cluster::start(ClusterConfig {
            shards: 2,
            shard: ServiceConfig {
                workers: 1,
                ..Default::default()
            },
            split_threshold: 100,
            max_inflight_splits: 0,
            ..Default::default()
        });
        match cluster.submit(spec(0, 10_000)) {
            ClusterSubmission::Rejected {
                reason: RejectReason::Overloaded { .. },
            } => {}
            other => panic!("expected Overloaded shed, got {other:?}"),
        }
        assert_eq!(cluster.snapshot().split_rejected, 1);
        cluster.shutdown();
    }

    #[test]
    fn drain_covers_routed_and_split_results() {
        let cluster = tiny_cluster(2, 4_000);
        let mut accepted = 0;
        for id in 0..4u64 {
            // ids 0/2 small (routed), 1/3 big (split).
            let elements = if id % 2 == 0 { 2_000 } else { 9_000 };
            if cluster.submit(spec(id, elements)).is_accepted() {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 4);
        let mut got = Vec::new();
        while got.len() < accepted {
            match cluster.next_completion(Duration::from_secs(120)) {
                Some(r) => got.push(r.id),
                None => panic!("drain stalled with {} of {accepted}", got.len()),
            }
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert!(cluster.try_next_completion().is_none());
        cluster.shutdown();
    }

    #[test]
    fn drained_shard_gets_no_new_routes_and_rejoin_restores_its_keys() {
        let cluster = tiny_cluster(3, usize::MAX);
        // Find ids homed on shard 2 under the default router seed.
        let homed: Vec<u64> = (0..200u64)
            .filter(|&id| cluster.router().route(job_key(&spec(id, 1_000))) == 2)
            .collect();
        assert!(!homed.is_empty(), "some key must home on shard 2");
        cluster.drain_shard(2);
        for &id in homed.iter().take(4) {
            match cluster.submit(spec(id, 1_000)) {
                ClusterSubmission::Accepted { shard, .. } => {
                    assert_ne!(shard, Some(2), "drained shard took a new route")
                }
                ClusterSubmission::Rejected { reason } => panic!("rejected: {reason}"),
            }
        }
        cluster.rejoin_shard(2);
        let id = homed[homed.len() - 1];
        match cluster.submit(spec(id, 1_000)) {
            ClusterSubmission::Accepted { shard, .. } => {
                assert_eq!(shard, Some(2), "rejoined shard must win its keys back")
            }
            ClusterSubmission::Rejected { reason } => panic!("rejected: {reason}"),
        }
        for n in 0..5 {
            assert!(
                cluster.next_completion(Duration::from_secs(60)).is_some(),
                "routed job {n} of 5 never resolved"
            );
        }
        let (snap, rest) = cluster.shutdown();
        assert!(rest.is_empty());
        assert!(!snap.health[2].drained, "rejoin must clear the drain flag");
    }

    #[test]
    fn failover_deadline_judges_the_whole_journey() {
        let mut r = synth_shard_failure(&spec(9, 100), 100, "x");
        r.sorted_ok = true;
        r.error = None;
        r.sort_latency = Duration::from_millis(1);
        r.total_latency = Duration::from_millis(2);
        // The retry itself met the 5 ms deadline, but the journey —
        // including the failed first attempt — took 12 ms.
        finalize_failover(
            &mut r,
            Some(Duration::from_millis(5)),
            Duration::from_millis(12),
            true,
        );
        assert_eq!(r.retries, 1);
        assert_eq!(r.total_latency, Duration::from_millis(12));
        assert_eq!(r.queue_latency, Duration::from_millis(11));
        assert_eq!(
            r.deadline_met,
            Some(false),
            "deadline must be judged against the whole journey, not the winning attempt"
        );
        // A journey inside the deadline still passes.
        let mut ok = synth_shard_failure(&spec(9, 100), 100, "x");
        ok.total_latency = Duration::from_millis(2);
        finalize_failover(
            &mut ok,
            Some(Duration::from_millis(50)),
            Duration::from_millis(3),
            true,
        );
        assert_eq!(ok.deadline_met, Some(true));
    }

    #[test]
    fn brownout_charge_is_virtual_and_rejudges_the_deadline() {
        let mut r = synth_shard_failure(&spec(1, 100), 100, "x");
        r.sorted_ok = true;
        r.error = None;
        r.total_latency = Duration::from_millis(1);
        r.deadline = Some(Duration::from_millis(4));
        r.deadline_met = Some(true);
        charge_slow(&mut r, Duration::from_millis(5));
        assert_eq!(r.total_latency, Duration::from_millis(6));
        assert_eq!(r.deadline_met, Some(false), "brownout must count against the SLO");
    }
}
