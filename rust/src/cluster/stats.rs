//! Cluster observability: per-shard [`ServiceSnapshot`]s, one merged
//! roll-up (histogram-accurate, via [`ServiceStats::merge`]), and the
//! cluster-level counters no single shard can see — routed vs split
//! jobs, cross-shard bytes, the virtual optical transfer charge, and
//! the degraded-mode ledger (failovers, span re-issues, per-shard
//! breaker health).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::cluster::health::ShardHealthSnapshot;
use crate::metrics::Histogram;
use crate::service::stats::{LatencySummary, ServiceSnapshot};
use crate::util::json::Json;

/// Live cluster-level counters, shared by the router front door,
/// every split worker, and the failover supervisor.
#[derive(Debug, Default)]
pub struct ClusterStats {
    routed: AtomicU64,
    split_jobs: AtomicU64,
    split_rejected: AtomicU64,
    failovers: AtomicU64,
    failover_exhausted: AtomicU64,
    span_reissues: AtomicU64,
    cross_shard_bytes: AtomicU64,
    transfer_ns: Mutex<Histogram>,
    merge_ns: Mutex<Histogram>,
}

impl ClusterStats {
    /// Fresh stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// One small job accepted onto its home shard.
    pub fn on_routed(&self) {
        self.routed.fetch_add(1, Ordering::Relaxed);
    }

    /// One split job accepted at the cluster front door.  Counted at
    /// accept — not at completion — so `routed + split_jobs ==
    /// accepted` holds even when a split later fails under chaos.
    pub fn on_split_accepted(&self) {
        self.split_jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// One split job finished its scatter/merge: `bytes` crossed the
    /// optical fabric (both directions), charged `transfer_ns` of
    /// virtual optical time, and the host-side k-way merge took
    /// `merge_wall`.
    pub fn on_split_transfer(&self, bytes: u64, transfer_ns: f64, merge_wall: Duration) {
        self.cross_shard_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.transfer_ns.lock().unwrap().record(transfer_ns.max(0.0) as u64);
        self.merge_ns.lock().unwrap().record_duration(merge_wall);
    }

    /// One split job shed at the cluster front door.
    pub fn on_split_rejected(&self) {
        self.split_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One routed job re-routed to the next-ranked live shard after
    /// its home shard failed it.
    pub fn on_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// One routed job whose failover could not be placed or failed
    /// again — it was failed explicitly, never retried a second time.
    pub fn on_failover_exhausted(&self) {
        self.failover_exhausted.fetch_add(1, Ordering::Relaxed);
    }

    /// One failed split span re-issued to a healthy shard.
    pub fn on_span_reissue(&self) {
        self.span_reissues.fetch_add(1, Ordering::Relaxed);
    }

    /// Jobs routed whole to a shard so far.
    pub fn routed(&self) -> u64 {
        self.routed.load(Ordering::Relaxed)
    }

    /// Split jobs accepted so far.
    pub fn split_jobs(&self) -> u64 {
        self.split_jobs.load(Ordering::Relaxed)
    }

    /// Cross-shard failover retries so far.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    /// Failovers that could not save the job.
    pub fn failover_exhausted(&self) -> u64 {
        self.failover_exhausted.load(Ordering::Relaxed)
    }

    /// Split spans re-issued so far.
    pub fn span_reissues(&self) -> u64 {
        self.span_reissues.load(Ordering::Relaxed)
    }

    /// Cross-shard bytes accumulated so far.
    pub fn cross_shard_bytes(&self) -> u64 {
        self.cross_shard_bytes.load(Ordering::Relaxed)
    }

    /// Freeze the cluster-level half of a snapshot (the caller supplies
    /// the per-shard and merged service views plus the health board's
    /// per-shard breaker snapshots).
    pub fn freeze(
        &self,
        shards: Vec<ServiceSnapshot>,
        merged: ServiceSnapshot,
        health: Vec<ShardHealthSnapshot>,
    ) -> ClusterSnapshot {
        ClusterSnapshot {
            shards,
            merged,
            health,
            routed: self.routed(),
            split_jobs: self.split_jobs(),
            split_rejected: self.split_rejected.load(Ordering::Relaxed),
            failovers: self.failovers(),
            failover_exhausted: self.failover_exhausted(),
            span_reissues: self.span_reissues(),
            cross_shard_bytes: self.cross_shard_bytes(),
            transfer: LatencySummary::of(&self.transfer_ns.lock().unwrap()),
            merge: LatencySummary::of(&self.merge_ns.lock().unwrap()),
        }
    }
}

/// Frozen cluster view: every shard's service snapshot, the merged
/// roll-up, per-shard breaker health, and the cluster-level counters.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    /// Per-shard service snapshots, shard order.
    pub shards: Vec<ServiceSnapshot>,
    /// All shards merged at histogram level — percentiles are computed
    /// *after* the merge, not averaged across shards.
    pub merged: ServiceSnapshot,
    /// Per-shard breaker health (state, incidents, blackout seconds,
    /// transition history), shard order.
    pub health: Vec<ShardHealthSnapshot>,
    /// Jobs routed whole to their home shard.
    pub routed: u64,
    /// Jobs that took the scatter/merge path (counted at accept).
    pub split_jobs: u64,
    /// Split jobs shed at the cluster front door.
    pub split_rejected: u64,
    /// Routed jobs re-routed to another live shard after their home
    /// shard failed them (at most one per job).
    pub failovers: u64,
    /// Routed jobs failed explicitly because no failover could save
    /// them (no live shard, rejected, or failed twice).
    pub failover_exhausted: u64,
    /// Failed split spans re-issued to a healthy shard.
    pub span_reissues: u64,
    /// Bytes that crossed the optical fabric (both directions).
    pub cross_shard_bytes: u64,
    /// Virtual optical transfer charge per split job (ns).
    pub transfer: LatencySummary,
    /// Host wall time of the k-way merge per split job.
    pub merge: LatencySummary,
}

impl ClusterSnapshot {
    /// The snapshot as a JSON object (alphabetical keys).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("cross_shard_bytes", Json::int(self.cross_shard_bytes as usize)),
            ("failover_exhausted", Json::int(self.failover_exhausted as usize)),
            ("failovers", Json::int(self.failovers as usize)),
            (
                "health",
                Json::arr(self.health.iter().map(ShardHealthSnapshot::to_json)),
            ),
            ("merge_latency", self.merge.to_json()),
            ("merged", self.merged.to_json()),
            ("routed", Json::int(self.routed as usize)),
            (
                "shards",
                Json::arr(self.shards.iter().map(ServiceSnapshot::to_json)),
            ),
            ("span_reissues", Json::int(self.span_reissues as usize)),
            ("split_jobs", Json::int(self.split_jobs as usize)),
            ("split_rejected", Json::int(self.split_rejected as usize)),
            ("transfer_ns", self.transfer.to_json()),
        ])
    }

    /// Human-readable multi-line summary for the CLI.
    pub fn summary_text(&self) -> String {
        let mut out = format!(
            "cluster: {} shards, {} routed, {} split ({} shed), \
             {} cross-shard bytes\n\
             resilience: {} failovers ({} exhausted), {} span re-issues\n\
             transfer (virtual): p50 {} ns p99 {} ns; merge: p50 {:.3?} p99 {:.3?}\n\
             merged {}",
            self.shards.len(),
            self.routed,
            self.split_jobs,
            self.split_rejected,
            self.cross_shard_bytes,
            self.failovers,
            self.failover_exhausted,
            self.span_reissues,
            self.transfer.p50.as_nanos(),
            self.transfer.p99.as_nanos(),
            self.merge.p50,
            self.merge.p99,
            self.merged.summary_text(),
        );
        for (i, s) in self.shards.iter().enumerate() {
            let health = match self.health.get(i) {
                Some(h) if h.drained => format!(" [{} drained]", h.state.label()),
                Some(h) => format!(" [{}]", h.state.label()),
                None => String::new(),
            };
            out.push_str(&format!(
                "shard {i}: {} accepted, {} completed, {} failed, {} rejected{health}\n",
                s.accepted, s.completed, s.failed, s.rejected
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::health::{HealthBoard, HealthConfig};
    use crate::service::stats::ServiceStats;

    #[test]
    fn counters_accumulate_and_freeze() {
        let stats = ClusterStats::new();
        stats.on_routed();
        stats.on_routed();
        stats.on_split_accepted();
        stats.on_split_transfer(8_000, 525.0, Duration::from_micros(40));
        stats.on_split_rejected();
        stats.on_failover();
        stats.on_failover_exhausted();
        stats.on_span_reissue();
        let board = HealthBoard::new(2, HealthConfig::default());
        let empty = ServiceStats::new().snapshot();
        let snap = stats.freeze(vec![empty.clone(), empty.clone()], empty, board.snapshot());
        assert_eq!(snap.routed, 2);
        assert_eq!(snap.split_jobs, 1);
        assert_eq!(snap.split_rejected, 1);
        assert_eq!(snap.failovers, 1);
        assert_eq!(snap.failover_exhausted, 1);
        assert_eq!(snap.span_reissues, 1);
        assert_eq!(snap.cross_shard_bytes, 8_000);
        assert_eq!(snap.transfer.count, 1);
        assert_eq!(snap.merge.count, 1);
        assert_eq!(snap.health.len(), 2);
        let j = snap.to_json();
        assert_eq!(j.get("routed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("cross_shard_bytes").unwrap().as_usize(), Some(8_000));
        assert_eq!(j.get("failovers").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("span_reissues").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("shards").unwrap().as_arr().map(<[Json]>::len), Some(2));
        assert_eq!(j.get("health").unwrap().as_arr().map(<[Json]>::len), Some(2));
        assert!(j.get("merged").unwrap().get("completed").is_some());
        let text = snap.summary_text();
        assert!(text.contains("2 routed"));
        assert!(text.contains("1 failovers"));
        assert!(text.contains("shard 1:"));
        assert!(text.contains("[healthy]"));
    }
}
