//! Per-shard health: a hand-rolled circuit breaker.
//!
//! Every shard carries a [`ShardHealth`] state machine —
//! Healthy → Suspect → Down → Probing — fed by two signals: the
//! outcome of every cluster-observed attempt (success / failure /
//! admission reject) and the failure deltas the supervisor absorbs
//! from each shard's [`ServiceStats`](crate::service::ServiceStats)
//! between scans.  Consecutive failures trip the breaker (Down);
//! a seeded, **event-count-based** probe schedule reopens it half-way
//! (Probing) after an exponential backoff, and a short streak of probe
//! successes closes it again (Healthy).  Nothing here reads the wall
//! clock to make a decision — the clock is a submission counter and
//! the probe jitter is a [`splitmix64`] draw, so a replayed run trips,
//! probes, and recovers at exactly the same points.  (Wall time *is*
//! recorded, but only as measurement: `blackout_seconds` in the
//! snapshot.)
//!
//! The [`HealthBoard`] owns one machine per shard plus the shared
//! event clock, and renders the two masks the routing layer consumes:
//!
//! * [`HealthBoard::routing_mask`] — where *new* jobs may be homed.
//!   Down and drained shards are excluded; a Probing shard is admitted
//!   only every `probe_stride`-th tick, the half-open trickle that
//!   tests recovery without re-flooding a struggling shard.
//! * [`HealthBoard::alive_mask`] — where failover retries and span
//!   re-issues may land.  Pure view, no probe accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::topology::fault::splitmix64;
use crate::util::json::Json;

/// Breaker states, in the order a failing shard walks them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Full traffic.
    Healthy,
    /// Failures observed but below the trip threshold; still routable.
    Suspect,
    /// Breaker open: no new routes until the probe schedule fires.
    Down,
    /// Half-open: a trickle of probe jobs decides Healthy vs Down.
    Probing,
}

impl HealthState {
    /// Lower-case label used in snapshots and JSON.
    pub fn label(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Down => "down",
            HealthState::Probing => "probing",
        }
    }
}

/// Breaker thresholds and the probe schedule seed.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Consecutive failures that turn Healthy into Suspect.
    pub suspect_after: u32,
    /// Consecutive failures that open the breaker (Down).
    pub down_after: u32,
    /// Consecutive admission rejects that open the breaker — a shard
    /// that sheds everything is as useless as one that fails.
    pub reject_down_after: u32,
    /// Base probe delay in **events** (submissions), doubled per
    /// incident up to 16x.
    pub probe_after: u64,
    /// While Probing, admit a route only every this-many ticks.
    pub probe_stride: u64,
    /// Consecutive probe successes that close the breaker.
    pub probe_successes: u32,
    /// Seeds the probe-delay jitter: same seed, same schedule.
    pub seed: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            suspect_after: 2,
            down_after: 4,
            reject_down_after: 16,
            probe_after: 32,
            probe_stride: 4,
            probe_successes: 2,
            seed: 0xB12E_A4E5,
        }
    }
}

/// One recorded state transition (event clock, from, to).
#[derive(Debug, Clone)]
pub struct HealthTransition {
    /// Event-clock value when the transition fired.
    pub event: u64,
    /// State left.
    pub from: HealthState,
    /// State entered.
    pub to: HealthState,
}

/// How many transitions each shard's history ring keeps.
const HISTORY_CAP: usize = 64;

/// The per-shard breaker state machine.
///
/// Deliberately a plain (non-thread-safe) struct so the transitions
/// can be unit-tested as a pure event walk; [`HealthBoard`] provides
/// the locking.
#[derive(Debug)]
pub struct ShardHealth {
    cfg: HealthConfig,
    shard: usize,
    state: HealthState,
    failure_streak: u32,
    rejection_streak: u32,
    probe_wins: u32,
    probe_ticks: u64,
    incidents: u32,
    probe_at: u64,
    drained: bool,
    down_since: Option<Instant>,
    down_total: Duration,
    history: Vec<HealthTransition>,
}

impl ShardHealth {
    /// A fresh, healthy machine for shard `shard`.
    pub fn new(cfg: HealthConfig, shard: usize) -> ShardHealth {
        ShardHealth {
            cfg,
            shard,
            state: HealthState::Healthy,
            failure_streak: 0,
            rejection_streak: 0,
            probe_wins: 0,
            probe_ticks: 0,
            incidents: 0,
            probe_at: 0,
            drained: false,
            down_since: None,
            down_total: Duration::ZERO,
            history: Vec::new(),
        }
    }

    /// Current state.
    pub fn state(&self) -> HealthState {
        self.state
    }

    /// How many times the breaker has opened.
    pub fn incidents(&self) -> u32 {
        self.incidents
    }

    /// Is the shard administratively drained?
    pub fn is_drained(&self) -> bool {
        self.drained
    }

    /// May failover retries / span re-issues land here?  (Drained and
    /// Down shards: no.  Probing counts as alive — a retry is as good
    /// a probe as a fresh route.)
    pub fn alive(&self) -> bool {
        !self.drained && self.state != HealthState::Down
    }

    /// May a *new* job be homed here right now?  Mutates the probe
    /// tick counter: while Probing, only every `probe_stride`-th call
    /// answers yes (the half-open trickle).
    pub fn admit_route(&mut self) -> bool {
        if self.drained {
            return false;
        }
        match self.state {
            HealthState::Healthy | HealthState::Suspect => true,
            HealthState::Down => false,
            HealthState::Probing => {
                let tick = self.probe_ticks;
                self.probe_ticks += 1;
                tick % self.cfg.probe_stride.max(1) == 0
            }
        }
    }

    /// Event-clock advance: promotes Down → Probing once the seeded
    /// probe schedule fires.
    pub fn on_tick(&mut self, clock: u64) {
        if self.state == HealthState::Down && clock >= self.probe_at {
            self.probe_wins = 0;
            self.probe_ticks = 0;
            self.transition(HealthState::Probing, clock);
        }
    }

    /// An attempt on this shard succeeded.
    pub fn on_success(&mut self, clock: u64) {
        self.failure_streak = 0;
        self.rejection_streak = 0;
        match self.state {
            HealthState::Healthy => {}
            HealthState::Probing => {
                self.probe_wins += 1;
                if self.probe_wins >= self.cfg.probe_successes {
                    self.transition(HealthState::Healthy, clock);
                }
            }
            HealthState::Suspect | HealthState::Down => {
                // A Down shard can still finish in-flight work; one
                // success is evidence enough to close from Suspect,
                // and from Down it shortcuts the probe dance.
                self.transition(HealthState::Healthy, clock);
            }
        }
    }

    /// An attempt on this shard failed.
    pub fn on_failure(&mut self, clock: u64) {
        self.probe_wins = 0;
        self.failure_streak += 1;
        match self.state {
            HealthState::Probing => self.open(clock),
            HealthState::Down => {}
            HealthState::Healthy | HealthState::Suspect => {
                if self.failure_streak >= self.cfg.down_after {
                    self.open(clock);
                } else if self.failure_streak >= self.cfg.suspect_after
                    && self.state == HealthState::Healthy
                {
                    self.transition(HealthState::Suspect, clock);
                }
            }
        }
    }

    /// The shard's admission control rejected an attempt.
    pub fn on_rejection(&mut self, clock: u64) {
        self.rejection_streak += 1;
        let open = self.rejection_streak >= self.cfg.reject_down_after;
        if open && self.state != HealthState::Down {
            self.open(clock);
        }
    }

    /// Administrative drain: no new routes, failovers, or re-issues.
    pub fn drain(&mut self) {
        self.drained = true;
    }

    /// Undo [`Self::drain`]; rendezvous assignment is restored because
    /// routing never stopped *hashing* the shard, only admitting it.
    pub fn rejoin(&mut self) {
        self.drained = false;
    }

    /// Open the breaker and schedule the next probe.
    fn open(&mut self, clock: u64) {
        self.incidents += 1;
        let backoff = self.cfg.probe_after << (self.incidents - 1).min(4);
        let jitter_span = self.cfg.probe_after / 2 + 1;
        let salt = (self.shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let jitter = splitmix64(self.cfg.seed ^ salt ^ u64::from(self.incidents)) % jitter_span;
        self.probe_at = clock + backoff + jitter;
        self.failure_streak = 0;
        self.rejection_streak = 0;
        self.transition(HealthState::Down, clock);
    }

    fn transition(&mut self, to: HealthState, clock: u64) {
        let from = self.state;
        if from == to {
            return;
        }
        if to == HealthState::Down {
            // Measurement only: feeds the blackout snapshot field,
            // never a decision.
            // repolint: allow(wall-clock)
            self.down_since = Some(Instant::now());
        } else if from == HealthState::Down {
            if let Some(t) = self.down_since.take() {
                self.down_total += t.elapsed();
            }
        }
        if self.history.len() == HISTORY_CAP {
            self.history.remove(0);
        }
        self.history.push(HealthTransition {
            event: clock,
            from,
            to,
        });
        self.state = to;
    }

    /// The recorded transition history (oldest first, ring-capped).
    /// Every entry's `event` is the event-clock value of the call that
    /// fired it — the model tests assert the machine never stamps a
    /// stale id.
    pub fn transitions(&self) -> &[HealthTransition] {
        &self.history
    }

    /// Freeze this machine's view for reporting.
    pub fn snapshot(&self) -> ShardHealthSnapshot {
        let mut blackout = self.down_total;
        if let Some(t) = self.down_since {
            blackout += t.elapsed();
        }
        ShardHealthSnapshot {
            state: self.state,
            incidents: self.incidents,
            drained: self.drained,
            blackout,
            history: self
                .history
                .iter()
                .map(|t| format!("e{} {}->{}", t.event, t.from.label(), t.to.label()))
                .collect(),
        }
    }
}

/// A frozen per-shard health view, embedded in
/// [`ClusterSnapshot`](crate::cluster::ClusterSnapshot).
#[derive(Debug, Clone)]
pub struct ShardHealthSnapshot {
    /// Breaker state at freeze time.
    pub state: HealthState,
    /// Times the breaker opened.
    pub incidents: u32,
    /// Administratively drained?
    pub drained: bool,
    /// Total wall time spent Down (measurement only — decisions are
    /// event-driven).
    pub blackout: Duration,
    /// Recent transitions, oldest first, e.g. `"e41 suspect->down"`.
    pub history: Vec<String>,
}

impl ShardHealthSnapshot {
    /// JSON object (alphabetical keys, crate-wide convention).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("blackout_seconds", Json::num(self.blackout.as_secs_f64())),
            ("drained", Json::int(usize::from(self.drained))),
            ("history", Json::arr(self.history.iter().map(Json::str).collect::<Vec<_>>())),
            ("incidents", Json::int(self.incidents as usize)),
            ("state", Json::str(self.state.label())),
        ])
    }
}

/// Baselines for deduplicating the two signal paths: outcomes the
/// supervisor sees directly vs the stats deltas it absorbs per scan.
#[derive(Debug, Default, Clone)]
struct Seen {
    completed: u64,
    failed: u64,
    rejected: u64,
}

/// The cluster-wide health registry: one [`ShardHealth`] per shard
/// behind one lock, plus the shared event clock.
#[derive(Debug)]
pub struct HealthBoard {
    clock: AtomicU64,
    inner: Mutex<BoardInner>,
}

#[derive(Debug)]
struct BoardInner {
    shards: Vec<ShardHealth>,
    seen: Vec<Seen>,
}

/// Cap on breaker events fed from one stats-delta absorption, so a
/// huge backlog of failures counts as "the shard is failing", not as
/// thousands of individual trips replayed at once.
const ABSORB_CAP: u64 = 8;

impl HealthBoard {
    /// A board of `shards` healthy machines.
    pub fn new(shards: usize, cfg: HealthConfig) -> HealthBoard {
        HealthBoard {
            clock: AtomicU64::new(0),
            inner: Mutex::new(BoardInner {
                shards: (0..shards).map(|i| ShardHealth::new(cfg.clone(), i)).collect(),
                seen: vec![Seen::default(); shards],
            }),
        }
    }

    /// Advance the event clock (one tick per submission) and run the
    /// probe schedule.  Returns the new clock value.
    pub fn tick(&self) -> u64 {
        let clock = self.clock.fetch_add(1, Ordering::AcqRel) + 1;
        let mut inner = self.inner.lock().unwrap();
        for s in &mut inner.shards {
            s.on_tick(clock);
        }
        clock
    }

    /// Current event clock.
    pub fn clock(&self) -> u64 {
        self.clock.load(Ordering::Acquire)
    }

    /// Where may a new job be homed?  Consumes probe-stride ticks on
    /// Probing shards.
    pub fn routing_mask(&self) -> Vec<bool> {
        let mut inner = self.inner.lock().unwrap();
        inner.shards.iter_mut().map(ShardHealth::admit_route).collect()
    }

    /// Where may failover retries / span re-issues land?  Pure view.
    pub fn alive_mask(&self) -> Vec<bool> {
        let inner = self.inner.lock().unwrap();
        inner.shards.iter().map(ShardHealth::alive).collect()
    }

    /// A cluster-observed attempt on `shard` succeeded.
    pub fn record_success(&self, shard: usize) {
        let clock = self.clock();
        let mut inner = self.inner.lock().unwrap();
        inner.seen[shard].completed += 1;
        inner.shards[shard].on_success(clock);
    }

    /// A cluster-observed attempt on `shard` failed.
    pub fn record_failure(&self, shard: usize) {
        let clock = self.clock();
        let mut inner = self.inner.lock().unwrap();
        inner.seen[shard].failed += 1;
        inner.shards[shard].on_failure(clock);
    }

    /// `shard`'s admission control rejected a cluster submission.
    pub fn record_rejection(&self, shard: usize) {
        let clock = self.clock();
        let mut inner = self.inner.lock().unwrap();
        inner.seen[shard].rejected += 1;
        inner.shards[shard].on_rejection(clock);
    }

    /// Feed the breaker from a shard's cumulative [`ServiceStats`]
    /// counters (completed / failed / rejected), deduplicated against
    /// everything already recorded directly.  This is how failures the
    /// supervisor never sees first-hand — e.g. jobs submitted straight
    /// to a shard, or retries inside the service — still move the
    /// breaker.
    pub fn absorb_stats(&self, shard: usize, completed: u64, failed: u64, rejected: u64) {
        let clock = self.clock();
        let mut inner = self.inner.lock().unwrap();
        let seen = &mut inner.seen[shard];
        let d_completed = completed.saturating_sub(seen.completed).min(ABSORB_CAP);
        let d_failed = failed.saturating_sub(seen.failed).min(ABSORB_CAP);
        let d_rejected = rejected.saturating_sub(seen.rejected).min(ABSORB_CAP);
        seen.completed = seen.completed.max(completed);
        seen.failed = seen.failed.max(failed);
        seen.rejected = seen.rejected.max(rejected);
        let machine = &mut inner.shards[shard];
        // Failures first: a mixed delta should leave the streak
        // reflecting the most recent evidence (successes clear it).
        for _ in 0..d_failed {
            machine.on_failure(clock);
        }
        for _ in 0..d_rejected {
            machine.on_rejection(clock);
        }
        for _ in 0..d_completed {
            machine.on_success(clock);
        }
    }

    /// Administratively drain `shard` (see [`ShardHealth::drain`]).
    pub fn drain(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].drain();
    }

    /// Rejoin a drained `shard`.
    pub fn rejoin(&self, shard: usize) {
        self.inner.lock().unwrap().shards[shard].rejoin();
    }

    /// Freeze every shard's health view.
    pub fn snapshot(&self) -> Vec<ShardHealthSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner.shards.iter().map(ShardHealth::snapshot).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            suspect_after: 2,
            down_after: 4,
            reject_down_after: 3,
            probe_after: 8,
            probe_stride: 2,
            probe_successes: 2,
            seed: 42,
        }
    }

    #[test]
    fn consecutive_failures_walk_healthy_suspect_down() {
        let mut h = ShardHealth::new(cfg(), 0);
        assert_eq!(h.state(), HealthState::Healthy);
        h.on_failure(1);
        assert_eq!(h.state(), HealthState::Healthy);
        h.on_failure(2);
        assert_eq!(h.state(), HealthState::Suspect);
        h.on_failure(3);
        h.on_failure(4);
        assert_eq!(h.state(), HealthState::Down);
        assert_eq!(h.incidents(), 1);
        assert!(!h.alive());
        assert!(!h.admit_route());
    }

    #[test]
    fn one_success_clears_a_suspect_streak() {
        let mut h = ShardHealth::new(cfg(), 0);
        h.on_failure(1);
        h.on_failure(2);
        assert_eq!(h.state(), HealthState::Suspect);
        h.on_success(3);
        assert_eq!(h.state(), HealthState::Healthy);
        // Streak reset: it takes a full fresh run of failures to trip.
        h.on_failure(4);
        assert_eq!(h.state(), HealthState::Healthy);
    }

    #[test]
    fn probe_schedule_is_deterministic_and_probes_close_the_breaker() {
        let trip = |h: &mut ShardHealth| {
            for e in 1..=4 {
                h.on_failure(e);
            }
        };
        let mut a = ShardHealth::new(cfg(), 3);
        let mut b = ShardHealth::new(cfg(), 3);
        trip(&mut a);
        trip(&mut b);
        assert_eq!(a.probe_at, b.probe_at, "same seed, same schedule");

        // Before the schedule fires, ticks do nothing.
        a.on_tick(a.probe_at - 1);
        assert_eq!(a.state(), HealthState::Down);
        let fire = a.probe_at;
        a.on_tick(fire);
        assert_eq!(a.state(), HealthState::Probing);
        // Half-open: stride 2 admits every other route.
        assert!(a.admit_route());
        assert!(!a.admit_route());
        assert!(a.admit_route());
        // Two probe wins close it.
        a.on_success(fire + 1);
        assert_eq!(a.state(), HealthState::Probing);
        a.on_success(fire + 2);
        assert_eq!(a.state(), HealthState::Healthy);
    }

    #[test]
    fn a_failed_probe_reopens_with_backoff() {
        let mut h = ShardHealth::new(cfg(), 0);
        for e in 1..=4 {
            h.on_failure(e);
        }
        let first = h.probe_at;
        h.on_tick(first);
        assert_eq!(h.state(), HealthState::Probing);
        h.on_failure(first + 1);
        assert_eq!(h.state(), HealthState::Down);
        assert_eq!(h.incidents(), 2);
        assert!(
            h.probe_at - (first + 1) >= 2 * 8,
            "second incident must back off at least 2x the base delay"
        );
    }

    #[test]
    fn rejection_streak_opens_the_breaker() {
        let mut h = ShardHealth::new(cfg(), 0);
        h.on_rejection(1);
        h.on_rejection(2);
        assert_eq!(h.state(), HealthState::Healthy);
        h.on_rejection(3);
        assert_eq!(h.state(), HealthState::Down);
    }

    #[test]
    fn drain_excludes_from_both_masks_and_rejoin_restores() {
        let board = HealthBoard::new(3, cfg());
        board.drain(1);
        assert_eq!(board.routing_mask(), vec![true, false, true]);
        assert_eq!(board.alive_mask(), vec![true, false, true]);
        assert!(board.snapshot()[1].drained);
        board.rejoin(1);
        assert_eq!(board.routing_mask(), vec![true, true, true]);
        assert!(!board.snapshot()[1].drained);
    }

    #[test]
    fn absorbed_stats_deltas_are_deduplicated_against_direct_records() {
        let board = HealthBoard::new(1, cfg());
        // Two failures recorded directly...
        board.record_failure(0);
        board.record_failure(0);
        assert_eq!(board.snapshot()[0].state, HealthState::Suspect);
        // ...then a stats scan reporting those same two failures must
        // not double-count them into a trip.
        board.absorb_stats(0, 0, 2, 0);
        assert_eq!(board.snapshot()[0].state, HealthState::Suspect);
        // A scan with genuinely new failures does move the machine.
        board.absorb_stats(0, 0, 4, 0);
        assert_eq!(board.snapshot()[0].state, HealthState::Down);
    }

    /// Exhaustive model check: every interleaving of four failure
    /// reports with three clock ticks (C(7,3) = 35 schedules) drives a
    /// real machine, and in every one the breaker only ever stamps the
    /// event id of the call that fired the transition — never a stale
    /// one — the history stays monotone in the event clock, an open
    /// always schedules its probe strictly in the future, and a tick
    /// promotes Down -> Probing only once the schedule has fired.
    #[test]
    fn every_failure_tick_interleaving_keeps_event_clock_invariants() {
        let small = HealthConfig {
            probe_after: 2,
            ..cfg()
        };
        let schedules = crate::runtime::check::interleavings(4, 3);
        assert_eq!(schedules.len(), 35, "C(7,3) merge orders");
        for schedule in &schedules {
            let mut h = ShardHealth::new(small.clone(), 0);
            let mut clock = 0u64;
            let mut failures = 0u32;
            for &is_failure in schedule {
                clock += 1;
                let seen = h.transitions().len();
                let was = h.state();
                if is_failure {
                    failures += 1;
                    h.on_failure(clock);
                } else {
                    h.on_tick(clock);
                }
                // Any transition this op fired carries exactly this
                // op's event id.
                for t in &h.transitions()[seen..] {
                    assert_eq!(t.event, clock, "stale event id under {schedule:?}");
                    assert_eq!(t.from, was, "{schedule:?}");
                }
                let events: Vec<u64> = h.transitions().iter().map(|t| t.event).collect();
                assert!(
                    events.windows(2).all(|w| w[0] <= w[1]),
                    "history not monotone under {schedule:?}: {events:?}"
                );
                if h.state() == HealthState::Down && h.transitions().len() > seen {
                    assert!(h.probe_at > clock, "open must schedule a future probe");
                }
                if h.state() == HealthState::Probing && was == HealthState::Down {
                    assert!(clock >= h.probe_at, "premature probe under {schedule:?}");
                }
            }
            // Terminal shape is schedule-independent: ticks never
            // create or absorb failure evidence.
            assert_eq!(failures, 4);
            assert_eq!(h.incidents(), 1, "{schedule:?}");
            let walk: Vec<(HealthState, HealthState)> =
                h.transitions().iter().map(|t| (t.from, t.to)).collect();
            assert_eq!(walk[0], (HealthState::Healthy, HealthState::Suspect), "{schedule:?}");
            assert_eq!(walk[1], (HealthState::Suspect, HealthState::Down), "{schedule:?}");
            assert!(walk.len() <= 3, "{schedule:?}: {walk:?}");
            if let Some(&last) = walk.get(2) {
                assert_eq!(last, (HealthState::Down, HealthState::Probing), "{schedule:?}");
            }
        }
    }

    /// The probe backoff is monotone across incidents: with
    /// `probe_after = 2` the jitter span is {0, 1} while the per-
    /// incident floors are 2, 4, 8, 16, 32 — strictly separated — so
    /// each failed probe must push the next probe strictly further
    /// out, until the shift cap holds the floor at 32.
    #[test]
    fn probe_backoff_is_monotone_across_incidents() {
        let small = HealthConfig {
            probe_after: 2,
            ..cfg()
        };
        let mut h = ShardHealth::new(small, 0);
        let mut clock = 0u64;
        let mut last_delta = 0u64;
        for incident in 1u32..=6 {
            while h.state() != HealthState::Down {
                clock += 1;
                h.on_failure(clock);
            }
            assert_eq!(h.incidents(), incident);
            let delta = h.probe_at - clock;
            let floor = 2u64 << (incident - 1).min(4);
            assert!(
                delta >= floor && delta <= floor + 1,
                "incident {incident}: delta {delta} outside [{floor}, {}]",
                floor + 1
            );
            if incident <= 5 {
                assert!(delta > last_delta, "incident {incident}: {delta} <= {last_delta}");
            }
            last_delta = delta;
            // Walk the clock to the probe, then fail the probe to
            // reopen at the next incident.
            clock = h.probe_at;
            h.on_tick(clock);
            assert_eq!(h.state(), HealthState::Probing);
        }
    }

    #[test]
    fn history_records_the_walk() {
        let mut h = ShardHealth::new(cfg(), 0);
        for e in 1..=4 {
            h.on_failure(e);
        }
        let snap = h.snapshot();
        assert_eq!(snap.state, HealthState::Down);
        assert_eq!(snap.incidents, 1);
        assert_eq!(
            snap.history,
            vec!["e2 healthy->suspect".to_string(), "e4 suspect->down".to_string()]
        );
        let json = snap.to_json().dump();
        assert!(json.contains("\"state\""), "{json}");
    }
}
