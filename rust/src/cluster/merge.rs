//! K-way merge: reassembles a split job's per-shard sorted spans into
//! one globally sorted array.
//!
//! The sampled splitter's spans are range-partitioned, so for healthy
//! splits a plain concatenation would already be sorted — but the
//! merge must hold for *any* per-part sorted inputs (degraded shards,
//! future splitters without the range property), so it is a real
//! heap-based k-way merge.  For the cluster's small k (shard counts)
//! the heap overhead is negligible next to the span sorts it follows.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Merge `parts` (each individually sorted ascending) into one sorted
/// vector.  Empty parts are fine; an empty part list yields an empty
/// output.
pub fn kway_merge(parts: &[&[i32]]) -> Vec<i32> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    // Fast paths: nothing to interleave.
    let mut non_empty = parts.iter().filter(|p| !p.is_empty());
    if let (Some(first), None) = (non_empty.next(), non_empty.next()) {
        out.extend_from_slice(first);
        return out;
    }
    // Heap of (head value, part index); cursors advance per part.
    let mut cursors = vec![0usize; parts.len()];
    let mut heap: BinaryHeap<Reverse<(i32, usize)>> = parts
        .iter()
        .enumerate()
        .filter(|(_, p)| !p.is_empty())
        .map(|(i, p)| Reverse((p[0], i)))
        .collect();
    while let Some(Reverse((v, i))) = heap.pop() {
        out.push(v);
        cursors[i] += 1;
        if let Some(&next) = parts[i].get(cursors[i]) {
            heap.push(Reverse((next, i)));
        }
    }
    debug_assert_eq!(out.len(), total);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn merge_equals_sorted_concatenation() {
        let mut rng = Rng::new(0xCAFE);
        for k in [2usize, 3, 8] {
            let parts: Vec<Vec<i32>> = (0..k)
                .map(|_| {
                    let n = rng.below(500) as usize;
                    let mut v: Vec<i32> =
                        (0..n).map(|_| rng.below(10_000) as i32 - 5_000).collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            let refs: Vec<&[i32]> = parts.iter().map(Vec::as_slice).collect();
            let merged = kway_merge(&refs);
            let mut expect: Vec<i32> = parts.concat();
            expect.sort_unstable();
            assert_eq!(merged, expect, "k = {k}");
        }
    }

    #[test]
    fn merge_handles_empty_and_singleton_parts() {
        assert_eq!(kway_merge(&[]), Vec::<i32>::new());
        assert_eq!(kway_merge(&[&[][..], &[][..]]), Vec::<i32>::new());
        assert_eq!(kway_merge(&[&[1, 2, 3][..]]), vec![1, 2, 3]);
        assert_eq!(kway_merge(&[&[][..], &[5][..], &[][..]]), vec![5]);
        assert_eq!(
            kway_merge(&[&[1, 4][..], &[][..], &[2, 3][..]]),
            vec![1, 2, 3, 4]
        );
    }

    #[test]
    fn merge_preserves_duplicate_multiplicities() {
        let merged = kway_merge(&[&[1, 1, 2][..], &[1, 2, 2][..]]);
        assert_eq!(merged, vec![1, 1, 1, 2, 2, 2]);
    }
}
