//! Scoped-thread parallelism helpers (the crate's rayon substitute).

/// Host parallelism (≥ 1).
pub fn available_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Parallel map over owned items: applies `f` to every element using up to
/// `workers` scoped threads, preserving order.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = workers.max(1);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 || n == 1 {
        return items.into_iter().map(f).collect();
    }
    // Work-steal over a shared index counter; results land in slots.
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let next = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().expect("item taken twice");
                let r = f(item);
                *outputs[i].lock().unwrap() = Some(r);
            });
        }
    });
    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

/// Parallel fold over an index range: each worker reduces a chunk with
/// `(map, merge)`; chunk results are merged in order.
pub fn par_reduce_indices<R, M, G>(n: usize, workers: usize, map: M, merge: G, identity: R) -> R
where
    R: Send,
    M: Fn(std::ops::Range<usize>) -> R + Sync,
    G: Fn(R, R) -> R,
{
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return identity;
    }
    if workers == 1 {
        return merge(identity, map(0..n));
    }
    let chunk = n.div_ceil(workers);
    let mut parts = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let map = &map;
            handles.push(scope.spawn(move || map(lo..hi)));
        }
        for h in handles {
            parts.push(h.join().expect("worker panicked"));
        }
    });
    parts.into_iter().fold(identity, |acc, p| merge(acc, p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let v: Vec<usize> = (0..1000).collect();
        let out = par_map(v, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_worker_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 8, |x| x), Vec::<i32>::new());
    }

    #[test]
    fn par_reduce_sums() {
        let total = par_reduce_indices(10_000, 8, |r| r.sum::<usize>(), |a, b| a + b, 0);
        assert_eq!(total, 10_000 * 9_999 / 2);
    }

    #[test]
    fn par_reduce_max_with_identity() {
        let m = par_reduce_indices(
            1000,
            3,
            |r| r.map(|i| (i * 7) % 101).max().unwrap_or(0),
            |a, b| a.max(b),
            0,
        );
        assert_eq!(m, 100);
    }
}
