//! Executor-backed parallelism helpers (the crate's rayon substitute).
//!
//! Every helper here submits to the persistent work-stealing pool
//! ([`Executor::global`]) instead of spawning scoped threads, so the sort
//! hot path pays **zero** thread spawn/teardown inside the timed parallel
//! region, and there are **zero per-item locks**: items and results live
//! in plain slot arrays written exactly once by the unique claimant of
//! each index (the same disjoint-raw-write idiom as the divide scatter).

use std::mem::MaybeUninit;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::runtime::Executor;

/// Host parallelism (≥ 1).  Under Miri the interpreter multiplies the
/// cost of every simulated thread, so the pool is capped at two
/// workers — enough to exercise every cross-thread path, small enough
/// to keep `cargo miri test` tractable.
pub fn available_workers() -> usize {
    if cfg!(miri) {
        return 2;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Raw pointer into a slot array, shareable across pool tasks.
struct Slots<P>(*mut MaybeUninit<P>);

// SAFETY: the pointee arrays outlive the executor scope that uses them
// (the scope blocks until every task completes), and the index counter
// hands each slot to exactly one task — no write ever aliases.
unsafe impl<P: Send> Send for Slots<P> {}
unsafe impl<P: Send> Sync for Slots<P> {}

/// Parallel map over owned items, preserving order: up to `workers`
/// runner tasks on the shared pool claim indices from an atomic counter
/// (work-steal over the index space, so heterogeneous item costs
/// balance), each moving its item out of a slot and writing the result
/// into the matching output slot — lock-free on the per-item path.
///
/// `workers == 1` (or a single item) runs inline on the caller.  If `f`
/// panics the scope completes the remaining items, then rethrows here;
/// unclaimed items and already-written results are leaked, never
/// double-dropped.
pub fn par_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1);
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 || n == 1 {
        return items.into_iter().map(f).collect();
    }

    let inputs: Vec<MaybeUninit<T>> = items.into_iter().map(MaybeUninit::new).collect();
    let mut outputs: Vec<MaybeUninit<R>> = Vec::with_capacity(n);
    outputs.resize_with(n, MaybeUninit::uninit);
    let next = AtomicUsize::new(0);
    let input_slots = Slots(inputs.as_ptr().cast_mut());
    let output_slots = Slots(outputs.as_mut_ptr());

    Executor::global().scope(|s| {
        for _ in 0..workers.min(n) {
            let f = &f;
            let next = &next;
            let input_slots = &input_slots;
            let output_slots = &output_slots;
            s.submit(move || loop {
                // The index claim: exactly-once slot handoff between
                // racing runners.
                crate::interleave!("par/claim");
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                crate::interleave!("par/slot-write");
                // SAFETY: the fetch_add hands index `i` to exactly one
                // runner; the input slot was initialized above and is
                // moved out exactly once, the output slot written
                // exactly once — both strictly before the scope returns.
                let item = unsafe { input_slots.0.add(i).read().assume_init() };
                let r = f(item);
                unsafe { output_slots.0.add(i).write(MaybeUninit::new(r)) };
            });
        }
    });
    debug_assert!(next.load(Ordering::Relaxed) >= n, "runner tasks exhausted the index space");

    // Every input slot was moved out (`MaybeUninit` storage never drops
    // its content) and every output slot written — reinterpret the
    // output storage as the result vector.
    drop(inputs);
    let mut outputs = std::mem::ManuallyDrop::new(outputs);
    // SAFETY: all `n` slots initialized by the scope above;
    // `MaybeUninit<R>` has the same layout as `R`.
    unsafe { Vec::from_raw_parts(outputs.as_mut_ptr().cast::<R>(), n, outputs.capacity()) }
}

/// Parallel fold over an index range: each pooled task reduces one
/// contiguous chunk with `map`; chunk results are merged in order.
pub fn par_reduce_indices<R, M, G>(n: usize, workers: usize, map: M, merge: G, identity: R) -> R
where
    R: Send,
    M: Fn(Range<usize>) -> R + Sync,
    G: Fn(R, R) -> R,
{
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return identity;
    }
    if workers == 1 {
        return merge(identity, map(0..n));
    }
    let parts = par_map(chunk_ranges(n, workers), workers, map);
    parts.into_iter().fold(identity, merge)
}

/// Parallel for over an index range: `f` runs once per contiguous chunk
/// (at most `workers` chunks) on the shared pool.  The side-effect
/// counterpart of [`par_reduce_indices`], for fan-outs whose chunks need
/// no per-chunk state threaded in (disjoint writes keyed purely on the
/// index range; chunk-state waves like the divide scatter go through
/// [`par_map`] instead).
pub fn par_for_ranges<F>(n: usize, workers: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return;
    }
    if workers == 1 {
        f(0..n);
        return;
    }
    par_map(chunk_ranges(n, workers), workers, f);
}

/// Split `0..n` into at most `workers` non-empty contiguous chunks.
fn chunk_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    let chunk = n.div_ceil(workers);
    (0..workers)
        .map(|w| w * chunk..((w + 1) * chunk).min(n))
        .filter(|r| !r.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let v: Vec<usize> = (0..1000).collect();
        let out = par_map(v, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_worker_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 8, |x| x), Vec::<i32>::new());
    }

    #[test]
    fn par_map_moves_non_copy_items_exactly_once() {
        let items: Vec<String> = (0..200).map(|i| format!("item-{i}")).collect();
        let out = par_map(items, 6, |s| s.len());
        assert_eq!(out.len(), 200);
        assert_eq!(out[0], "item-0".len());
        assert_eq!(out[199], "item-199".len());
    }

    #[test]
    fn par_map_nests_without_deadlock() {
        // A pooled task fanning out again exercises the executor's
        // helping loop (the campaign → divide nesting in miniature).
        let outer: Vec<usize> = (0..8).collect();
        let out = par_map(outer, 4, |i| {
            let inner: Vec<usize> = (0..50).collect();
            par_map(inner, 4, move |j| i * 1000 + j).into_iter().sum::<usize>()
        });
        for (i, &sum) in out.iter().enumerate() {
            assert_eq!(sum, i * 1000 * 50 + 49 * 50 / 2);
        }
    }

    #[test]
    fn par_reduce_sums() {
        let total = par_reduce_indices(10_000, 8, |r| r.sum::<usize>(), |a, b| a + b, 0);
        assert_eq!(total, 10_000 * 9_999 / 2);
    }

    #[test]
    fn par_reduce_max_with_identity() {
        let m = par_reduce_indices(
            1000,
            3,
            |r| r.map(|i| (i * 7) % 101).max().unwrap_or(0),
            |a, b| a.max(b),
            0,
        );
        assert_eq!(m, 100);
    }

    #[test]
    fn par_for_ranges_covers_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for_ranges(n, 8, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // Degenerate shapes.
        par_for_ranges(0, 4, |_| panic!("no ranges for n == 0"));
        let small: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        par_for_ranges(3, 16, |r| {
            for i in r {
                small[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(small.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        for (n, w) in [(10, 3), (1, 8), (100, 100), (7, 2)] {
            let ranges = chunk_ranges(n, w);
            assert!(ranges.len() <= w);
            let mut expect = 0;
            for r in &ranges {
                assert_eq!(r.start, expect);
                assert!(!r.is_empty());
                expect = r.end;
            }
            assert_eq!(expect, n);
        }
    }
}
