//! Tiny benchmark harness (the crate's criterion substitute).
//!
//! Used by the `rust/benches/*.rs` targets (`harness = false`): warmup,
//! fixed repetition count, median / MAD / min / max reporting, and a
//! CSV-friendly one-line format so EXPERIMENTS.md tables can be pasted
//! straight from bench output.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Repetitions measured (after warmup).
    pub reps: usize,
    /// Median duration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Fastest observation.
    pub min: Duration,
    /// Slowest observation.
    pub max: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<52} median {:>12?}  mad {:>10?}  min {:>12?}  max {:>12?}  ({} reps)",
            self.name, self.median, self.mad, self.min, self.max, self.reps
        )
    }
}

/// Benchmark runner with fixed warmup/measure counts.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Measured iterations.
    pub reps: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, reps: 7 }
    }
}

impl Bench {
    /// Quick-run configuration honouring `OHHC_BENCH_FAST=1` (CI smoke).
    pub fn from_env() -> Self {
        if std::env::var("OHHC_BENCH_FAST").as_deref() == Ok("1") {
            Bench { warmup: 1, reps: 3 }
        } else {
            Bench::default()
        }
    }

    /// Measure `f`, which must return something observable (guards against
    /// dead-code elimination via `std::hint::black_box`).
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let median = samples[(samples.len() - 1) / 2];
        let mut devs: Vec<Duration> = samples
            .iter()
            .map(|&s| if s > median { s - median } else { median - s })
            .collect();
        devs.sort();
        let result = BenchResult {
            name: name.to_string(),
            reps: samples.len(),
            median,
            mad: devs[(devs.len() - 1) / 2],
            min: samples[0],
            max: *samples.last().unwrap(),
        };
        println!("{result}");
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders() {
        let b = Bench { warmup: 1, reps: 5 };
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.reps, 5);
        assert!(r.min <= r.median && r.median <= r.max);
    }
}
