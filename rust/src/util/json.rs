//! Minimal JSON parser and builder — enough for `artifacts/manifest.json`
//! and the campaign reports.
//!
//! Recursive descent over the full JSON grammar (objects, arrays, strings
//! with escapes, numbers, booleans, null).  No serialization framework;
//! callers pattern-match on [`Json`] or assemble documents with the
//! [`Json::obj`] / [`Json::arr`] / [`Json::str`] / [`Json::num`] builders.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64; manifest values are small integers).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(err(&p, "trailing characters"));
        }
        Ok(v)
    }

    /// Build an object from `(key, value)` pairs (keys sort, as always).
    pub fn obj<K: Into<String>>(pairs: impl IntoIterator<Item = (K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// Build an array.
    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Build a number value from an integer count (lossless below 2^53).
    pub fn int(n: usize) -> Json {
        Json::Num(n as f64)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value (lossless for |n| < 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array elements, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map, if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to JSON text (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write_into(&mut s);
        s
    }

    /// Serialize with two-space indentation (campaign report files).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(depth + 1));
                    e.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(depth + 1));
                    Json::Str(k.clone()).write_into(out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push('}');
            }
            other => other.write_into(out),
        }
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&(*n as i64).to_string());
                } else {
                    out.push_str(&n.to_string());
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write_into(out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

fn err(p: &Parser, msg: &str) -> Error {
    Error::Artifact(format!("json parse error at byte {}: {msg}", p.i))
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(err(self, &format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(err(self, &format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(err(self, "unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(err(self, "expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(err(self, "expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(err(self, "unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(err(self, "truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| err(self, "bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| err(self, "bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(err(self, "bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| err(self, "invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| err(self, "bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "chunk": 65536,
            "artifacts": {
                "minmax_n65536": {
                    "inputs": [["s32", [65536]]],
                    "outputs": [["s32", [1]], ["s32", [1]]],
                    "sha256": "abc123",
                    "bytes": 7799
                }
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("chunk").unwrap().as_usize(), Some(65536));
        let art = j.get("artifacts").unwrap().get("minmax_n65536").unwrap();
        assert_eq!(art.get("bytes").unwrap().as_usize(), Some(7799));
        let ins = art.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].as_arr().unwrap()[0].as_str(), Some("s32"));
        assert_eq!(
            ins[0].as_arr().unwrap()[1].as_arr().unwrap()[0].as_usize(),
            Some(65536)
        );
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "\"x", "1 2", "{\"a\"}", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3],[]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }

    #[test]
    fn dump_round_trips() {
        for doc in [
            r#"{"a":[1,2.5,-3],"b":"x\ny","c":null,"d":true}"#,
            "[]",
            r#"{"nested":{"deep":[[1]]}}"#,
        ] {
            let j = Json::parse(doc).unwrap();
            let dumped = j.dump();
            assert_eq!(Json::parse(&dumped).unwrap(), j, "{doc}");
        }
    }

    #[test]
    fn dump_integers_without_point() {
        assert_eq!(Json::Num(65536.0).dump(), "65536");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn builders_compose_documents() {
        let doc = Json::obj([
            ("cells", Json::arr([Json::int(3), Json::num(0.5)])),
            ("name", Json::str("campaign")),
        ]);
        assert_eq!(doc.dump(), r#"{"cells":[3,0.5],"name":"campaign"}"#);
    }

    #[test]
    fn pretty_round_trips() {
        let doc = Json::obj([
            ("a", Json::arr([Json::int(1), Json::str("x")])),
            ("b", Json::obj(Vec::<(&str, Json)>::new())),
            ("c", Json::arr([])),
        ]);
        let pretty = doc.pretty();
        assert!(pretty.contains("\n  \"a\": [\n"));
        assert_eq!(Json::parse(&pretty).unwrap(), doc);
    }
}
