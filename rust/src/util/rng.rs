//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! SplitMix64 passes BigCrush, needs no warm-up, and is seedable from a
//! single `u64` — exactly what reproducible workload generation needs.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift rejection-free
    /// approximation is fine here — bias < 2⁻³² for our bounds).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `i64` in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Rng::new(1);
            (0..10).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(1);
            (0..10).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Rng::new(2);
            (0..10).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn below_respects_bound_and_spreads() {
        let mut r = Rng::new(7);
        let mut hits = [0usize; 10];
        for _ in 0..100_000 {
            hits[r.below(10) as usize] += 1;
        }
        for &h in &hits {
            assert!((8_000..12_000).contains(&h), "{hits:?}");
        }
    }

    #[test]
    fn range_inclusive_hits_both_ends() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }
}
