//! In-repo substrate utilities.
//!
//! The build is fully offline with a deliberately tiny dependency surface,
//! so the usual ecosystem helpers are implemented here instead:
//!
//! * [`rng`] — deterministic SplitMix64/xoshiro-style PRNG (replaces
//!   `rand` for workload generation and property tests);
//! * [`json`] — a minimal recursive-descent JSON parser (replaces
//!   `serde_json` for the artifact manifest);
//! * [`par`] — parallel map / index-chunk helpers on the persistent
//!   executor pool (replaces `rayon` for the divide waves, the waves
//!   backend, campaign sweeps, and all-pairs BFS);
//! * [`mod@bench`] — a small timing harness with warmup, repetitions and
//!   median/MAD reporting (replaces `criterion` for `rust/benches/`).

pub mod bench;
pub mod json;
pub mod par;
pub mod rng;
