//! `ohhc-qsort` — CLI launcher for the OHHC parallel Quick Sort system.
//!
//! Subcommands:
//!
//! * `run`      — one experiment cell (dimension × construction ×
//!   distribution × size), printed as a full report.
//! * `campaign` — the paper's §6 experiment grid in one invocation:
//!   declarative sweep, concurrent jobs, cached topologies, one
//!   aggregated JSON/CSV report.
//! * `serve`    — the in-process multi-tenant sort service over a
//!   jobfile / stdin job stream.
//! * `loadgen`  — deterministic open-/closed-loop load generation
//!   against an in-process service (or a sharded cluster with
//!   `--shards`), with a JSON latency report.
//! * `cluster`  — shard-scaling sweep: the same seeded load replayed
//!   against 1/2/4/8-shard clusters, jobs/sec per shard count.
//! * `figures`  — regenerate paper tables/figures into CSV + stdout.
//! * `sweep`    — the paper's full 216-run sweep, CSV per cell.
//! * `topo`     — topology properties (OHHC and baselines).
//! * `validate` — analytical-model checks against the DES.
//! * `artifacts`— inspect the AOT artifact registry (PJRT).
//!
//! Argument parsing is hand-rolled (`--key value` / `--flag`); run with
//! `help` for usage.

use std::path::PathBuf;

use ohhc_qsort::analysis::validate;
use ohhc_qsort::bail;
use ohhc_qsort::campaign::{Campaign, SweepSpec};
use ohhc_qsort::cluster::{Cluster, ClusterConfig, ClusterFaultPlan, FaultWindow};
use ohhc_qsort::config::{
    Backend, Construction, Distribution, DivideEngine, DivideStrategy, ExperimentConfig,
};
use ohhc_qsort::coordinator::OhhcSorter;
use ohhc_qsort::ensure;
use ohhc_qsort::figures::{ALL_IDS, FigureHarness};
use ohhc_qsort::runtime::ArtifactRegistry;
use ohhc_qsort::service::{
    loadgen, FaultPlan, JobResult, JobSpec, LoadGenConfig, LoadMode, RejectReason, ServiceConfig,
    SortService, Submission,
};
use ohhc_qsort::topology::{hhc, hypercube, mesh, ring, NetworkProperties, Ohhc};
use ohhc_qsort::util::json::Json;
use ohhc_qsort::util::par;
use ohhc_qsort::CliResult;

const USAGE: &str = "\
ohhc-qsort — parallel Quick Sort on the OTIS Hyper Hexa-Cell network
            (Nsour & Fasha 2021 reproduction)

USAGE: ohhc-qsort <command> [options]

COMMANDS
  run        run one experiment cell
             --dimension N        OHHC dimension (default 1)
             --construction C     full | half (default full)
             --distribution D     random | sorted | reversed | local, or an
                                  adversarial one: organ_pipe | few_uniques |
                                  zipf | anti_pivot
             --elements N         i32 keys (default 1048576)
             --backend B          threaded | des (default threaded)
             --divide-strategy S  paper | sampling | adaptive (default paper)
             --xla-divide         divide via the XLA AOT artifact
             --workers N          0 = one OS thread per processor (default)
             --config FILE        load a key=value experiment file
             --trace-out FILE     dump the DES comm trace as JSON (des only)
  campaign   run the paper's §6 grid as one concurrent campaign
             --dims LIST          dimensions (default 1,2,3,4)
             --constructions LIST full,half (default both)
             --dists LIST         random,sorted,reverse,local (default all;
                                  adversarial names accepted)
             --sizes LIST         key counts (default paper sizes × --scale)
             --scale F            scale for the default sizes (default 0.1)
             --backends LIST      threaded,des (default threaded)
             --divide-strategies LIST
                                  paper,sampling,adaptive (default paper); the
                                  report gains a per_strategy robustness table
             --workers N          per-run workers; 0 = direct (default pool)
             --jobs N             concurrent cells (default 1)
             --reps N             timing repetitions per cell (default 1)
             --seed N             workload seed
             --fault-rates LIST   link-failure axis in permille, e.g. 0,100,250
                                  (seeded, bridge-free; default 0 = healthy)
             --shards LIST        cluster-shards axis, e.g. 1,2,4 (default 1 =
                                  single OHHC; the report gains a
                                  per_shard_count scaling table)
             --spec FILE          key=value sweep spec (axis flags override it)
             --out FILE           aggregated JSON (default results/campaign.json)
             --csv FILE           also write a per-cell CSV table
             --quiet              no per-cell progress lines
  serve      run the in-process multi-tenant sort service on a job stream
             --jobs-file FILE     one `dist,elements,seed[,dim[,deadline_ms
                                  [,strategy]]]` per line (default: stdin)
             --workers N          sorter-pool threads (default: host-sized)
             --queue N            bounded queue capacity (default 256)
             --rate R             token-bucket admit rate, jobs/s (default: off)
             --burst N            token-bucket burst (default 16)
             --shed-depth N       shed at queue depth N (default: off)
             --batch N            coalesce up to N small jobs (default 8)
             --small N            batchable-job key threshold (default 4096)
             --fault-rate P       inject worker panics with probability P
             --fault-links N      fail N permille of links per attempt
             --fault-nodes N      kill N processors per attempt (jobs fail)
             --fault-seed N       fault-plan seed (default 64017)
             --retry-budget N     retries per panicked/detoured job (default 2)
             --retain             keep sorted outputs in results (memory!)
             --out FILE           write the service report JSON
  loadgen    drive an in-process service with a seeded synthetic stream
             --jobs N             schedule length (default 1000)
             --seed N             schedule seed (default 7)
             --rate R             OPEN loop: offered jobs/s
             --concurrency N      CLOSED loop: jobs in flight (default 8)
             --dims LIST          dimensions to mix (default 1,2,3)
             --dists LIST         distributions to mix (default all four;
                                  adversarial names accepted)
             --min-keys N         smallest job (default 2000)
             --max-keys N         largest job, log-uniform (default 32000)
             --divide-strategy S  paper | sampling | adaptive for every job
             --deadline-ms N      per-job latency SLO
             --workers/--queue/--burst/--shed-depth/--batch/--small
             --fault-rate/--fault-links/--fault-nodes/--fault-seed/--retry-budget
                                  service knobs as in `serve`
             --admit-rate R       service token-bucket admit rate, jobs/s
             --shards N           drive an N-shard cluster instead of one
                                  service; the JSON gains a `cluster` object
                                  with per-shard snapshots
             --split-threshold N  scatter/merge jobs above N keys (cluster
                                  mode only; default 65536)
             --shard-fault-rate P fail dispatch attempts at the shard boundary
                                  with probability P (cluster mode; seeded,
                                  failovers redraw)
             --blackout LIST      shard outage windows on the submission event
                                  clock: SHARD:FROM:UNTIL fails the shard,
                                  SHARD:FROM:UNTIL:SLOW_MS brownouts it, comma
                                  separated (cluster mode)
             --assert-no-rejects  exit nonzero if anything was rejected
             --out FILE           write the throughput/latency report JSON
  cluster    shard-scaling sweep: seeded closed-loop load vs shard count
             --shards-list LIST   shard counts to sweep (default 1,2,4,8)
             --jobs N             jobs per shard count (default 400)
             --seed N             schedule seed (default 7)
             --workers N          sorter threads per shard (default 2)
             --min-keys N         smallest job (default 500)
             --max-keys N         largest job, log-uniform (default 4000)
             --split-threshold N  scatter/merge above N keys (default 65536)
             --shard-fault-rate P seeded shard-boundary failure probability
             --blackout LIST      shard outage windows as in loadgen
             --out FILE           write the scaling table JSON
  figures    regenerate paper tables/figures
             --out DIR            CSV output directory (default results)
             --only ID[,ID...]    subset (default: all 26 ids)
             --scale F            size scale vs paper 10-60 MB (default 0.1)
             --repetitions N      timing reps per cell (default 1)
             --direct             paper-faithful 1 thread per processor
             --plot               render ASCII charts alongside the tables
  baselines  ablation: OHHC sort vs PSRS vs hypercube bitonic vs fork/join
             --elements N         i32 keys (default 1048576)
             --skewed             use a skewed workload (step-point stress)
  sweep      the paper's full 216-run sweep
             --out FILE           CSV path (default results/sweep.csv)
             --scale F            size scale (default 0.1)
             --max-dimension N    default 4
  topo       print topology properties
             --dimension N        default 1
             --baselines          include ring/mesh/hypercube
  validate   check Theorem 3 against the DES
  artifacts  inspect the AOT artifact registry
             --dir DIR            default artifacts
  help       this text
";

/// Tiny argument cursor over `--key value` / `--flag` style options.
/// Carries the subcommand name so every parse error says **which**
/// subcommand rejected **which** flag.
struct Args {
    cmd: String,
    args: Vec<String>,
}

impl Args {
    fn new(cmd: &str, args: Vec<String>) -> Self {
        Args {
            cmd: cmd.to_string(),
            args,
        }
    }

    /// Consume `--name value`; error if the flag appears without a value.
    fn opt(&mut self, name: &str) -> CliResult<Option<String>> {
        if let Some(i) = self.args.iter().position(|a| a == name) {
            if i + 1 >= self.args.len() {
                bail!("{}: {name} requires a value", self.cmd);
            }
            let v = self.args.remove(i + 1);
            self.args.remove(i);
            Ok(Some(v))
        } else {
            Ok(None)
        }
    }

    /// Consume a boolean `--flag`.
    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.args.iter().position(|a| a == name) {
            self.args.remove(i);
            true
        } else {
            false
        }
    }

    /// Parse a typed option with a default.
    fn parse_or<T: std::str::FromStr>(&mut self, name: &str, default: T) -> CliResult<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_parse(name)? {
            Some(t) => Ok(t),
            None => Ok(default),
        }
    }

    /// Parse a typed option with no default (`None` when absent).
    fn opt_parse<T: std::str::FromStr>(&mut self, name: &str) -> CliResult<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name)? {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => bail!("{}: bad value for {name}: {e}", self.cmd),
            },
        }
    }

    /// Everything consumed?
    fn finish(self) -> CliResult {
        if self.args.is_empty() {
            Ok(())
        } else {
            bail!(
                "{}: unrecognized arguments: {:?} (run `help` for the {} flag list)",
                self.cmd,
                self.args,
                self.cmd
            )
        }
    }
}

fn main() -> CliResult {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let mut args = Args::new(&cmd, argv);
    match cmd.as_str() {
        "run" => cmd_run(&mut args)?,
        "campaign" => cmd_campaign(&mut args)?,
        "serve" => cmd_serve(&mut args)?,
        "loadgen" => cmd_loadgen(&mut args)?,
        "cluster" => cmd_cluster(&mut args)?,
        "figures" => cmd_figures(&mut args)?,
        "baselines" => cmd_baselines(&mut args)?,
        "sweep" => cmd_sweep(&mut args)?,
        "topo" => cmd_topo(&mut args)?,
        "validate" => cmd_validate()?,
        "artifacts" => cmd_artifacts(&mut args)?,
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            return Ok(());
        }
        other => bail!("unknown command `{other}` (try `help`)"),
    }
    args.finish()
}

fn cmd_run(args: &mut Args) -> CliResult {
    let trace_out = args.opt("--trace-out")?;
    let cfg = if let Some(path) = args.opt("--config")? {
        ExperimentConfig::from_file(&PathBuf::from(path))?
    } else {
        ExperimentConfig {
            dimension: args.parse_or("--dimension", 1u32)?,
            construction: Construction::parse(
                &args.opt("--construction")?.unwrap_or_else(|| "full".into()),
            )?,
            distribution: Distribution::parse(
                &args.opt("--distribution")?.unwrap_or_else(|| "random".into()),
            )?,
            elements: args.parse_or("--elements", 1usize << 20)?,
            backend: Backend::parse(
                &args.opt("--backend")?.unwrap_or_else(|| "threaded".into()),
            )?,
            divide_engine: if args.flag("--xla-divide") {
                DivideEngine::Xla
            } else {
                DivideEngine::Native
            },
            divide_strategy: DivideStrategy::parse(
                &args.opt("--divide-strategy")?.unwrap_or_else(|| "paper".into()),
            )?,
            workers: args.parse_or("--workers", 0usize)?,
            ..Default::default()
        }
    };
    let sorter = OhhcSorter::new(&cfg)?;
    let net = sorter.network();
    println!(
        "OHHC d={} {} → {} groups × {} processors = {}",
        cfg.dimension,
        cfg.construction.label(),
        net.groups,
        net.procs_per_group,
        net.total_processors()
    );
    let r = sorter.run()?;
    println!("elements            {}", r.elements);
    println!("sequential time     {:?}", r.sequential_time);
    println!("parallel time       {:?}", r.parallel_time);
    println!("  divide phase      {:?}", r.divide_time);
    println!(
        "  stages            divide {:?} / scatter {:?} / sort {:?} / gather {:?}",
        r.stage_times.divide, r.stage_times.scatter, r.stage_times.local_sort, r.stage_times.gather
    );
    println!(
        "speedup             {:.4}x ({:.2}%)",
        r.speedup, r.speedup_pct
    );
    println!("efficiency          {:.4}", r.efficiency);
    println!("imbalance           {:.3}", r.imbalance);
    println!(
        "counters            recursions={} iterations={} swaps={} comparisons={}",
        r.counters.recursion_calls, r.counters.iterations, r.counters.swaps, r.counters.comparisons
    );
    if let Some(ns) = r.des_completion_ns {
        println!("DES completion      {:.1} µs", ns / 1000.0);
    }
    if let Some((e, o)) = r.des_steps {
        println!("DES comm steps      electrical={e} optical={o}");
    }
    if let Some(path) = trace_out {
        match &r.des_trace {
            Some(trace) => {
                std::fs::write(&path, trace.to_json().dump())?;
                println!("DES trace           → {path}");
            }
            None => bail!("--trace-out requires --backend des"),
        }
    }
    Ok(())
}

fn cmd_campaign(args: &mut Args) -> CliResult {
    let out = PathBuf::from(args.opt("--out")?.unwrap_or_else(|| "results/campaign.json".into()));
    let csv = args.opt("--csv")?;
    let quiet = args.flag("--quiet");

    let mut spec = if let Some(path) = args.opt("--spec")? {
        // A spec file carries its own sizes; --scale would be silently
        // ignored here, so leave it unconsumed for finish() to reject.
        SweepSpec::from_file(&PathBuf::from(path))?
    } else {
        let scale: f64 = args.parse_or("--scale", 0.1)?;
        SweepSpec {
            sizes: ExperimentConfig::paper_sizes(scale),
            ..Default::default()
        }
    };
    if let Some(v) = args.opt("--dims")? {
        spec.dimensions = SweepSpec::parse_dimensions(&v)?;
    }
    if let Some(v) = args.opt("--constructions")? {
        spec.constructions = SweepSpec::parse_constructions(&v)?;
    }
    if let Some(v) = args.opt("--dists")? {
        spec.distributions = SweepSpec::parse_distributions(&v)?;
    }
    if let Some(v) = args.opt("--sizes")? {
        spec.sizes = SweepSpec::parse_sizes(&v)?;
    }
    if let Some(v) = args.opt("--backends")? {
        spec.backends = SweepSpec::parse_backends(&v)?;
    }
    if let Some(v) = args.opt("--divide-strategies")? {
        spec.strategies = SweepSpec::parse_strategies(&v)?;
    }
    if let Some(v) = args.opt("--fault-rates")? {
        spec.fault_permille = SweepSpec::parse_fault_rates(&v)?;
    }
    if let Some(v) = args.opt("--shards")? {
        spec.shards = SweepSpec::parse_shards(&v)?;
    }
    spec.workers = args.parse_or("--workers", spec.workers)?;
    spec.jobs = args.parse_or("--jobs", spec.jobs)?;
    spec.repetitions = args.parse_or("--reps", spec.repetitions)?;
    spec.seed = args.parse_or("--seed", spec.seed)?;

    let planned = spec.expand()?.len();
    eprintln!(
        "campaign: {planned} cells ({} dims × {} constructions × {} dists × {} sizes × {} \
         backends × {} strategies × {} fault rates × {} shard counts, deduplicated), {} job(s)",
        spec.dimensions.len(),
        spec.constructions.len(),
        spec.distributions.len(),
        spec.sizes.len(),
        spec.backends.len(),
        spec.strategies.len(),
        spec.fault_permille.len(),
        spec.shards.len(),
        spec.jobs.max(1)
    );

    let campaign = Campaign::new(spec);
    let report = campaign.run_with(|cell| {
        if !quiet {
            eprintln!(
                "  [{}] {} speedup {:.3}x eff {:.4}",
                cell.status.label(),
                cell.key(),
                cell.speedup,
                cell.efficiency
            );
        }
    })?;

    print!("{}", report.summary_text());
    let json_path = report.write_json(&out)?;
    println!("aggregated JSON     → {}", json_path.display());
    if let Some(csv) = csv {
        let csv_path = report.write_csv(&PathBuf::from(csv))?;
        println!("per-cell CSV        → {}", csv_path.display());
    }
    ensure!(
        report.failed() == 0,
        "{} of {} cells failed (see {})",
        report.failed(),
        report.cells.len(),
        json_path.display()
    );
    Ok(())
}

/// Consume the service knobs shared by `serve` and `loadgen`.
fn service_config(args: &mut Args) -> CliResult<ServiceConfig> {
    let defaults = ServiceConfig::default();
    let faults = FaultPlan {
        worker_panic_rate: args.parse_or("--fault-rate", defaults.faults.worker_panic_rate)?,
        link_fail_permille: args.parse_or("--fault-links", defaults.faults.link_fail_permille)?,
        node_failures: args.parse_or("--fault-nodes", defaults.faults.node_failures)?,
        seed: args.parse_or("--fault-seed", defaults.faults.seed)?,
    };
    ensure!(
        (0.0..=1.0).contains(&faults.worker_panic_rate),
        "{}: --fault-rate must be in [0, 1]",
        args.cmd
    );
    ensure!(
        faults.link_fail_permille <= 1000,
        "{}: --fault-links is per mille (0..=1000)",
        args.cmd
    );
    Ok(ServiceConfig {
        workers: args.parse_or("--workers", defaults.workers)?,
        queue_capacity: args.parse_or("--queue", defaults.queue_capacity)?,
        burst: args.parse_or("--burst", defaults.burst)?,
        shed_depth: args.parse_or("--shed-depth", defaults.shed_depth)?,
        batch_max_jobs: args.parse_or("--batch", defaults.batch_max_jobs)?,
        small_job_threshold: args.parse_or("--small", defaults.small_job_threshold)?,
        faults,
        retry_budget: args.parse_or("--retry-budget", defaults.retry_budget)?,
        ..defaults
    })
}

fn cmd_serve(args: &mut Args) -> CliResult {
    use std::io::BufRead;

    let jobs_file = args.opt("--jobs-file")?;
    let out = args.opt("--out")?;
    let retain = args.flag("--retain");
    let rate = args.opt_parse::<f64>("--rate")?;
    let mut cfg = service_config(args)?;
    cfg.rate = rate;
    cfg.retain_output = retain;
    let faults_active = cfg.faults.is_active();

    // Read the whole job stream up front: jobfile or stdin.
    let text = match &jobs_file {
        Some(path) => std::fs::read_to_string(path)?,
        None => {
            let mut buf = String::new();
            for line in std::io::stdin().lock().lines() {
                buf.push_str(&line?);
                buf.push('\n');
            }
            buf
        }
    };
    let mut specs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        specs.push(JobSpec::parse_line(line, lineno as u64)?);
    }
    ensure!(!specs.is_empty(), "serve: no jobs in the input stream");

    eprintln!(
        "serve: {} jobs over {} workers, queue capacity {}",
        specs.len(),
        cfg.workers,
        cfg.queue_capacity
    );
    let service = SortService::start(cfg);
    let mut retries = 0usize;
    let mut tickets = Vec::with_capacity(specs.len());
    for spec in specs {
        // serve owns a finite stream: on backpressure (queue full, rate,
        // shed) wait for capacity instead of dropping input.  Only
        // invalid jobs and shutdown are fatal.
        // NOTE: every retry is a fresh submission attempt, so the service
        // snapshot's submitted/rejected count attempts, not jobs — the
        // `stream` numbers below are the per-job truth.
        loop {
            match service.submit(spec.clone()) {
                Submission::Accepted { ticket, .. } => {
                    tickets.push(ticket);
                    break;
                }
                Submission::Rejected {
                    reason: reason @ (RejectReason::Closed | RejectReason::Invalid { .. }),
                } => bail!("serve: job {} rejected: {reason}", spec.id),
                Submission::Rejected { .. } => {
                    retries += 1;
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }
        }
    }
    // Every accepted job has its own ticket; results cannot be mixed up
    // across tenants, and a stall names the job that stalled.
    let accepted = tickets.len();
    let mut results = Vec::with_capacity(accepted);
    for ticket in &tickets {
        match ticket.wait_timeout(std::time::Duration::from_secs(300)) {
            Some(r) => results.push(r),
            None => bail!("serve: job {} produced no result in 300s", ticket.id()),
        }
    }
    let (snapshot, rest) = service.shutdown();
    results.extend(rest);
    results.sort_by_key(|r| r.id);

    let failures = results.iter().filter(|r| !r.sorted_ok).count();
    println!(
        "stream: {accepted} jobs accepted ({retries} backpressure retries), {failures} failures"
    );
    print!("{}", snapshot.summary_text());
    if let Some(path) = out {
        let stream = Json::obj([
            ("accepted", Json::int(accepted)),
            ("backpressure_retries", Json::int(retries)),
            ("failures", Json::int(failures)),
        ]);
        let doc = Json::obj([
            ("jobs", Json::arr(results.iter().map(JobResult::to_json))),
            ("service", snapshot.to_json()),
            ("stream", stream),
        ]);
        let mut text = doc.pretty();
        text.push('\n');
        if let Some(parent) = PathBuf::from(&path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, text)?;
        println!("service report      → {path}");
    }
    // Under injected faults, explicit failures are expected (retry
    // budgets exhaust); silent drops never are — every accepted ticket
    // already produced a result above.
    if faults_active {
        if failures > 0 {
            eprintln!("serve: {failures} job(s) failed explicitly under injected faults");
        }
    } else {
        ensure!(failures == 0, "serve: {failures} job(s) failed verification");
    }
    Ok(())
}

/// Consume the cluster chaos knobs shared by `loadgen` and `cluster`.
/// The plan reuses the service fault seed (`--fault-seed`) so one knob
/// replays both layers of injection.
fn cluster_fault_plan(args: &mut Args, seed: u64) -> CliResult<ClusterFaultPlan> {
    let shard_fail_rate: f64 = args.parse_or("--shard-fault-rate", 0.0)?;
    let windows = match args.opt("--blackout")? {
        Some(list) => FaultWindow::parse_list(&list)?,
        None => Vec::new(),
    };
    Ok(ClusterFaultPlan {
        seed,
        shard_fail_rate,
        windows,
    })
}

fn cmd_loadgen(args: &mut Args) -> CliResult {
    let out = args.opt("--out")?;
    let assert_no_rejects = args.flag("--assert-no-rejects");
    let jobs: usize = args.parse_or("--jobs", 1000)?;
    let seed: u64 = args.parse_or("--seed", 7)?;
    let rate = args.opt_parse::<f64>("--rate")?;
    let concurrency: usize = args.parse_or("--concurrency", 8)?;
    let dims = match args.opt("--dims")? {
        Some(v) => SweepSpec::parse_dimensions(&v)?,
        None => vec![1, 2, 3],
    };
    let dists = match args.opt("--dists")? {
        Some(v) => SweepSpec::parse_distributions(&v)?,
        None => Distribution::ALL.to_vec(),
    };
    let min_keys: usize = args.parse_or("--min-keys", 2_000)?;
    let max_keys: usize = args.parse_or("--max-keys", 32_000)?;
    let strategy = DivideStrategy::parse(
        &args.opt("--divide-strategy")?.unwrap_or_else(|| "paper".into()),
    )?;
    let deadline_ms = args.opt_parse::<u64>("--deadline-ms")?;
    let admit_rate = args.opt_parse::<f64>("--admit-rate")?;
    let shards: usize = args.parse_or("--shards", 1)?;
    ensure!(shards >= 1, "loadgen: --shards must be at least 1");
    let split_threshold: usize =
        args.parse_or("--split-threshold", ClusterConfig::default().split_threshold)?;
    let mut cfg = service_config(args)?;
    cfg.rate = admit_rate;
    let cluster_faults = cluster_fault_plan(args, cfg.faults.seed)?;
    ensure!(
        shards > 1 || !cluster_faults.is_active(),
        "loadgen: --shard-fault-rate/--blackout need --shards > 1"
    );
    if let Err(e) = cluster_faults.validate(shards) {
        bail!("loadgen: {e}");
    }
    let faults_active = cfg.faults.is_active() || cluster_faults.is_active();

    let gen_cfg = LoadGenConfig {
        jobs,
        seed,
        dimensions: dims,
        distributions: dists,
        min_elements: min_keys,
        max_elements: max_keys,
        strategy,
        deadline: deadline_ms.map(std::time::Duration::from_millis),
        mode: match rate {
            Some(r) => LoadMode::Open { rate: r },
            None => LoadMode::Closed { concurrency },
        },
        ..Default::default()
    };
    eprintln!(
        "loadgen: {jobs} jobs seed {seed}, {} over {} worker(s){}",
        match gen_cfg.mode {
            LoadMode::Open { rate } => format!("open loop at {rate} jobs/s"),
            LoadMode::Closed { concurrency } => format!("closed loop, {concurrency} in flight"),
        },
        cfg.workers,
        if shards > 1 {
            format!(" × {shards} shards")
        } else {
            String::new()
        }
    );

    let (report, cluster_snap) = if shards > 1 {
        let cluster = Cluster::start(ClusterConfig {
            shards,
            split_threshold,
            shard: cfg,
            faults: cluster_faults,
            ..Default::default()
        });
        let report = loadgen::run_on(&cluster, &gen_cfg);
        let (snap, _leftovers) = cluster.shutdown();
        (report, Some(snap))
    } else {
        let service = SortService::start(cfg);
        let report = loadgen::run(&service, &gen_cfg);
        service.shutdown();
        (report, None)
    };

    print!("{}", report.summary_text());
    if let Some(snap) = &cluster_snap {
        print!("{}", snap.summary_text());
    }
    if let Some(path) = out {
        // Cluster runs nest the loadgen report next to the cluster
        // snapshot, so per-shard accounting rides in the same file.
        let doc = match &cluster_snap {
            Some(snap) => Json::obj([("cluster", snap.to_json()), ("loadgen", report.to_json())]),
            None => report.to_json(),
        };
        let mut text = doc.pretty();
        text.push('\n');
        if let Some(parent) = PathBuf::from(&path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, text)?;
        println!("loadgen report      → {path}");
    }
    // Explicit failures are tolerated only when faults are injected
    // (exhausted retry budgets fail jobs on purpose).  A job that
    // vanished without any result is a bug in every mode.
    if faults_active {
        if report.failures > 0 {
            eprintln!(
                "loadgen: {} job(s) failed explicitly under injected faults",
                report.failures
            );
        }
    } else {
        ensure!(
            report.failures == 0,
            "loadgen: {} job(s) failed verification",
            report.failures
        );
    }
    ensure!(
        report.completed + report.failures == report.accepted,
        "loadgen: {} accepted jobs never produced results",
        report.accepted - report.completed - report.failures
    );
    if assert_no_rejects {
        ensure!(
            report.rejected == 0,
            "loadgen: {} job(s) rejected under --assert-no-rejects",
            report.rejected
        );
    }
    Ok(())
}

fn cmd_cluster(args: &mut Args) -> CliResult {
    let out = args.opt("--out")?;
    let shard_counts = match args.opt("--shards-list")? {
        Some(v) => SweepSpec::parse_shards(&v)?,
        None => vec![1, 2, 4, 8],
    };
    let jobs: usize = args.parse_or("--jobs", 400)?;
    let seed: u64 = args.parse_or("--seed", 7)?;
    let workers: usize = args.parse_or("--workers", 2)?;
    let min_keys: usize = args.parse_or("--min-keys", 500)?;
    let max_keys: usize = args.parse_or("--max-keys", 4_000)?;
    let split_threshold: usize =
        args.parse_or("--split-threshold", ClusterConfig::default().split_threshold)?;
    ensure!(min_keys <= max_keys, "cluster: --min-keys exceeds --max-keys");
    let chaos = cluster_fault_plan(args, ServiceConfig::default().faults.seed)?;
    for &shards in &shard_counts {
        if let Err(e) = chaos.validate(shards) {
            bail!("cluster: at {shards} shard(s): {e}");
        }
    }

    println!(
        "cluster scaling: {jobs} jobs seed {seed}, {workers} worker(s)/shard, \
         shard counts {shard_counts:?}{}",
        if chaos.is_active() { " (chaos injected)" } else { "" }
    );
    let mut rows = Vec::new();
    let mut base_jps = None;
    for &shards in &shard_counts {
        // The same seeded schedule replays at every shard count; only
        // the fleet grows, so jobs/sec isolates shard scaling.
        let gen_cfg = LoadGenConfig {
            jobs,
            seed,
            dimensions: vec![1],
            distributions: vec![Distribution::Random],
            min_elements: min_keys,
            max_elements: max_keys,
            mode: LoadMode::Closed {
                concurrency: 2 * shards,
            },
            ..Default::default()
        };
        let cluster = Cluster::start(ClusterConfig {
            shards,
            split_threshold,
            shard: ServiceConfig {
                workers,
                ..ServiceConfig::default()
            },
            faults: chaos.clone(),
            ..Default::default()
        });
        let report = loadgen::run_on(&cluster, &gen_cfg);
        let (snap, _leftovers) = cluster.shutdown();
        if chaos.is_active() {
            if report.failures > 0 {
                eprintln!(
                    "cluster: {} job(s) failed explicitly under chaos at {shards} shard(s)",
                    report.failures
                );
            }
        } else {
            ensure!(
                report.failures == 0,
                "cluster: {} job(s) failed verification at {shards} shard(s)",
                report.failures
            );
        }
        ensure!(
            report.completed + report.failures == report.accepted,
            "cluster: {} accepted job(s) never produced results at {shards} shard(s)",
            report.accepted - report.completed - report.failures
        );
        let speedup = match base_jps {
            None => {
                base_jps = Some(report.throughput_jps);
                1.0
            }
            Some(base) if base > 0.0 => report.throughput_jps / base,
            Some(_) => 0.0,
        };
        println!(
            "  x{shards}: {:>8.1} jobs/s ({speedup:.2}x), p99 total {:?}, \
             {} routed / {} split, {} cross-shard bytes, {} failovers / {} re-issues",
            report.throughput_jps,
            snap.merged.total.p99,
            snap.routed,
            snap.split_jobs,
            snap.cross_shard_bytes,
            snap.failovers,
            snap.span_reissues
        );
        rows.push(Json::obj([
            ("completed", Json::int(report.completed)),
            ("cross_shard_bytes", Json::int(snap.cross_shard_bytes as usize)),
            ("failover_exhausted", Json::int(snap.failover_exhausted as usize)),
            ("failovers", Json::int(snap.failovers as usize)),
            ("failures", Json::int(report.failures)),
            ("p99_total_ns", Json::int(snap.merged.total.p99.as_nanos() as usize)),
            ("shards", Json::int(shards)),
            ("span_reissues", Json::int(snap.span_reissues as usize)),
            ("speedup", Json::num(speedup)),
            ("split_jobs", Json::int(snap.split_jobs as usize)),
            ("throughput_jps", Json::num(report.throughput_jps)),
        ]));
    }
    if let Some(path) = out {
        let doc = Json::obj([
            ("jobs", Json::int(jobs)),
            ("rows", Json::arr(rows)),
            ("seed", Json::int(seed as usize)),
            ("workers_per_shard", Json::int(workers)),
        ]);
        let mut text = doc.pretty();
        text.push('\n');
        if let Some(parent) = PathBuf::from(&path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, text)?;
        println!("scaling table       → {path}");
    }
    Ok(())
}

fn cmd_baselines(args: &mut Args) -> CliResult {
    use ohhc_qsort::baselines::{hypercube_bitonic_sort, psrs_sort, shared_fork_sort};
    use ohhc_qsort::coordinator::divide_native;
    use ohhc_qsort::sort::quicksort;
    use std::time::Instant;

    let n: usize = args.parse_or("--elements", 1usize << 20)?;
    let skewed = args.flag("--skewed");
    let p = 144; // 2-D OHHC, G = P

    let data: Vec<i32> = if skewed {
        // 95% of keys in a narrow band — the step-point stress test.
        let mut rng = ohhc_qsort::util::rng::Rng::new(77);
        (0..n)
            .map(|_| {
                if rng.below(100) < 95 {
                    rng.range_i64(0, 1000) as i32
                } else {
                    rng.range_i64(0, 1 << 24) as i32
                }
            })
            .collect()
    } else {
        ohhc_qsort::workload::random(n, 77)
    };
    println!(
        "baseline ablation: {n} keys, {} workload, P = {p}",
        if skewed { "skewed" } else { "random" }
    );

    let mut seq = data.clone();
    let t0 = Instant::now();
    quicksort(&mut seq);
    println!("{:<34} {:>12.3?}", "sequential quicksort", t0.elapsed());

    // OHHC step-point sort (full pipeline, waves).
    let cfg = ExperimentConfig {
        dimension: 2,
        construction: Construction::FullGroup,
        elements: n,
        workers: par::available_workers(),
        ..Default::default()
    };
    let sorter = OhhcSorter::new(&cfg)?;
    let w = ohhc_qsort::workload::Workload {
        data: data.clone(),
        distribution: Distribution::Random,
        seed: 77,
    };
    let r = sorter.run_on(&w)?;
    println!(
        "{:<34} {:>12.3?}  imbalance {:.2}",
        "OHHC step-point sort (paper)", r.parallel_time, r.imbalance
    );

    let t0 = Instant::now();
    let psrs = psrs_sort(&data, p);
    ensure!(psrs.sorted == seq, "psrs mismatch");
    println!(
        "{:<34} {:>12.3?}  imbalance {:.2}",
        "PSRS (sample splitters)",
        t0.elapsed(),
        psrs.imbalance
    );

    let t0 = Instant::now();
    let bit = hypercube_bitonic_sort(&data, 7); // 128 processors
    ensure!(bit.sorted == seq, "bitonic mismatch");
    println!(
        "{:<34} {:>12.3?}  {} link traversals / {} stages",
        "hypercube bitonic (128 procs)",
        t0.elapsed(),
        bit.link_traversals,
        bit.stages
    );

    let mut forked = data.clone();
    let t0 = Instant::now();
    shared_fork_sort(&mut forked, 3);
    ensure!(forked == seq, "fork/join mismatch");
    println!(
        "{:<34} {:>12.3?}",
        "fork/join quicksort (depth 3)",
        t0.elapsed()
    );

    let step = divide_native(&data, p)?;
    println!(
        "\ndivision balance: step-point imbalance {:.2} vs PSRS {:.2} — {}",
        step.imbalance(),
        psrs.imbalance,
        if step.imbalance() > 2.0 * psrs.imbalance {
            "sample splitters win on this workload (paper's step points assume near-uniform key ranges)"
        } else {
            "comparable on this workload"
        }
    );
    Ok(())
}

fn cmd_figures(args: &mut Args) -> CliResult {
    let out = PathBuf::from(args.opt("--out")?.unwrap_or_else(|| "results".into()));
    let only = args.opt("--only")?;
    let scale: f64 = args.parse_or("--scale", 0.1)?;
    let repetitions: usize = args.parse_or("--repetitions", 1)?;
    let direct = args.flag("--direct");
    let plot = args.flag("--plot");

    let mut h = FigureHarness::new(scale);
    h.repetitions = repetitions;
    if direct {
        h.workers = 0;
    }
    let ids: Vec<String> = match only {
        Some(list) => list.split(',').map(str::to_string).collect(),
        None => ALL_IDS.iter().map(|s| s.to_string()).collect(),
    };
    for id in &ids {
        let fig = h.generate(id)?;
        let path = fig.write_csv(&out)?;
        println!("{}", fig.to_text());
        if plot {
            println!("{}", ohhc_qsort::metrics::plot::render(&fig, 64, 18));
        }
        println!("  → {}\n", path.display());
    }
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> CliResult {
    use std::io::Write;
    let out = PathBuf::from(args.opt("--out")?.unwrap_or_else(|| "results/sweep.csv".into()));
    let scale: f64 = args.parse_or("--scale", 0.1)?;
    let max_dimension: u32 = args.parse_or("--max-dimension", 4)?;

    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(&out)?;
    writeln!(
        f,
        "dimension,construction,distribution,mb,elements,seq_secs,par_secs,\
         speedup,speedup_pct,efficiency,imbalance,recursions,iterations,swaps,comparisons"
    )?;
    let sizes = ExperimentConfig::paper_sizes(scale);
    let mb = [10, 20, 30, 40, 50, 60];
    let mut runs = 0;
    for d in 1..=max_dimension {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            for dist in Distribution::ALL {
                for (i, &n) in sizes.iter().enumerate() {
                    let cfg = ExperimentConfig {
                        dimension: d,
                        construction: c,
                        distribution: dist,
                        elements: n,
                        workers: par::available_workers(),
                        ..Default::default()
                    };
                    let r = OhhcSorter::new(&cfg)?.run()?;
                    writeln!(
                        f,
                        "{d},{},{},{},{n},{:.6},{:.6},{:.4},{:.2},{:.4},{:.3},{},{},{},{}",
                        c.label(),
                        dist.label(),
                        mb[i],
                        r.sequential_time.as_secs_f64(),
                        r.parallel_time.as_secs_f64(),
                        r.speedup,
                        r.speedup_pct,
                        r.efficiency,
                        r.imbalance,
                        r.counters.recursion_calls,
                        r.counters.iterations,
                        r.counters.swaps,
                        r.counters.comparisons,
                    )?;
                    runs += 1;
                    eprint!("\r{runs} runs");
                }
            }
        }
    }
    eprintln!("\nwrote {}", out.display());
    Ok(())
}

fn cmd_topo(args: &mut Args) -> CliResult {
    let dimension: u32 = args.parse_or("--dimension", 1)?;
    let baselines = args.flag("--baselines");
    for c in [Construction::FullGroup, Construction::HalfGroup] {
        let net = Ohhc::new(dimension, c)?;
        let p = NetworkProperties::compute(net.graph());
        println!("OHHC d={dimension} {:<6} {p}", c.label());
    }
    let hhc_g = hhc::hhc_graph(dimension);
    println!(
        "HHC  d={dimension}        {}",
        NetworkProperties::compute(&hhc_g)
    );
    if baselines {
        let n = Ohhc::new(dimension, Construction::FullGroup)?.total_processors();
        println!(
            "ring({n})          {}",
            NetworkProperties::compute(&ring::ring_graph(n))
        );
        let side = (n as f64).sqrt().round() as usize;
        println!(
            "mesh({side}x{side})        {}",
            NetworkProperties::compute(&mesh::mesh_graph(side, side))
        );
        let dims = (n as f64).log2().floor() as u32;
        println!(
            "hypercube(2^{dims})    {}",
            NetworkProperties::compute(&hypercube::hypercube_graph(dims))
        );
    }
    Ok(())
}

fn cmd_validate() -> CliResult {
    println!("Theorem 3 (communication steps) — DES vs closed forms:");
    println!(
        "{:>3} {:>8} {:>14} {:>14} {:>12} {:>12}",
        "d", "groups", "paper(12Gd-2)", "exact(2(GP-1))", "measured", "optical"
    );
    for d in 1..=4 {
        for c in [Construction::FullGroup, Construction::HalfGroup] {
            let chk = validate::theorem3(d, c);
            println!(
                "{d:>3} {:>8} {:>14} {:>14} {:>12} {:>12}  {}",
                chk.groups,
                chk.paper_form,
                chk.exact_form,
                chk.measured,
                chk.measured_optical,
                c.label()
            );
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &mut Args) -> CliResult {
    let dir = PathBuf::from(args.opt("--dir")?.unwrap_or_else(|| "artifacts".into()));
    let reg = ArtifactRegistry::open(&dir)?;
    println!(
        "platform: {} ({} devices), chunk={}",
        reg.client().platform_name(),
        reg.client().device_count(),
        reg.chunk()
    );
    for name in reg.names() {
        let sig = reg.sig(&name)?;
        println!(
            "  {name:<28} {:>8} B  in={:?} out={:?}",
            sig.bytes,
            sig.inputs.iter().map(|i| &i.1).collect::<Vec<_>>(),
            sig.outputs.iter().map(|o| &o.1).collect::<Vec<_>>()
        );
    }
    Ok(())
}
