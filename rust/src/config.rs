//! Experiment configuration: the knobs the paper sweeps (§5) plus
//! simulator backends.  Configs can be built in code or loaded from a
//! simple `key = value` file (one assignment per line, `#` comments) —
//! see `examples/experiment.conf`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// OHHC construction rule (paper §1.5, Table 1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Construction {
    /// `G = P`: as many groups as processors per group (full OHHC).
    FullGroup,
    /// `G = P/2`: half as many groups as processors per group.
    HalfGroup,
}

impl Construction {
    /// Both construction rules, in the paper's presentation order.
    pub const ALL: [Construction; 2] = [Construction::FullGroup, Construction::HalfGroup];

    /// Number of groups for a given per-group processor count.
    pub fn groups(self, procs_per_group: usize) -> usize {
        match self {
            Construction::FullGroup => procs_per_group,
            Construction::HalfGroup => procs_per_group / 2,
        }
    }

    /// Short label used in figure series / CSV headers.
    pub fn label(self) -> &'static str {
        match self {
            Construction::FullGroup => "G=P",
            Construction::HalfGroup => "G=P/2",
        }
    }

    /// Parse from config text (`full` / `half`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "full" | "g=p" | "full_group" => Ok(Construction::FullGroup),
            "half" | "g=p/2" | "half_group" => Ok(Construction::HalfGroup),
            other => Err(Error::Config(format!("unknown construction `{other}`"))),
        }
    }
}

/// Input distribution: the paper's four (§5: random, sorted, reverse
/// sorted, local) plus the adversarial suite (skewed and attack inputs
/// for the divide-strategy robustness work).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// Uniform random keys.
    Random,
    /// Already sorted ascending.
    Sorted,
    /// Sorted descending (the paper's "reversed sorted").
    ReverseSorted,
    /// The paper's "local distribution": values clustered around their
    /// position so each region of the array spans a narrow value band.
    Local,
    /// Ascending then descending ramp (organ pipe) — classic quicksort
    /// stressor.
    OrganPipe,
    /// Only a handful of distinct values, so buckets tie-break hard.
    FewUniques,
    /// Zipf-distributed ranks (fixed exponent s ≈ 1.2): heavy head,
    /// long tail — the shape of real-world key popularity.
    Zipf,
    /// Adversarial: constructed to dump every key but one into bucket 0
    /// under the paper's fixed step-point divide rule.
    AntiPivot,
}

impl Distribution {
    /// The paper's four distributions in its presentation order (drives
    /// every paper-faithful sweep, figure, and default grid — the
    /// adversarial variants are deliberately excluded).
    pub const ALL: [Distribution; 4] = [
        Distribution::Random,
        Distribution::Sorted,
        Distribution::ReverseSorted,
        Distribution::Local,
    ];

    /// The adversarial suite, mildest to nastiest.
    pub const ADVERSARIAL: [Distribution; 4] = [
        Distribution::OrganPipe,
        Distribution::FewUniques,
        Distribution::Zipf,
        Distribution::AntiPivot,
    ];

    /// Label used in figures / CSV.
    pub fn label(self) -> &'static str {
        match self {
            Distribution::Random => "random",
            Distribution::Sorted => "sorted",
            Distribution::ReverseSorted => "reverse_sorted",
            Distribution::Local => "local",
            Distribution::OrganPipe => "organ_pipe",
            Distribution::FewUniques => "few_uniques",
            Distribution::Zipf => "zipf",
            Distribution::AntiPivot => "anti_pivot",
        }
    }

    /// Parse from config text (delegates to the one shared registry,
    /// [`crate::workload::parse`], so every caller accepts the same
    /// names and reports the same error).
    pub fn parse(s: &str) -> Result<Self> {
        crate::workload::parse(s)
    }
}

/// How the divide stage picks bucket boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DivideStrategy {
    /// The paper's fixed step-point rule (§3.1) — the default, so every
    /// paper-faithful number is unchanged. Vulnerable to skew: an
    /// adversarial input can land nearly all keys in one bucket.
    PaperFixed,
    /// Regular sampling (PSRS-style): a sorted p·(p−1) sample yields
    /// p−1 splitters, bounding max bucket size ≤ 2× ideal on any input.
    RegularSampling,
    /// Run [`DivideStrategy::PaperFixed`] first; if the measured
    /// imbalance breaches the skew guardrail, re-divide with sampled
    /// splitters (counted as a `skew_redivides` stat).
    Adaptive,
}

impl DivideStrategy {
    /// All strategies, paper-faithful first.
    pub const ALL: [DivideStrategy; 3] = [
        DivideStrategy::PaperFixed,
        DivideStrategy::RegularSampling,
        DivideStrategy::Adaptive,
    ];

    /// Imbalance guardrail for [`DivideStrategy::Adaptive`]: re-divide
    /// when max bucket exceeds this multiple of ideal.  Sampling
    /// guarantees ≤ 2×, so any breach beyond 4× signals a divide the
    /// sampled splitters will beat decisively.
    pub const SKEW_GUARDRAIL: f64 = 4.0;

    /// Label used in campaign reports / CSV.
    pub fn label(self) -> &'static str {
        match self {
            DivideStrategy::PaperFixed => "paper",
            DivideStrategy::RegularSampling => "sampling",
            DivideStrategy::Adaptive => "adaptive",
        }
    }

    /// Parse from config text.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "paper" | "fixed" | "paper_fixed" => Ok(DivideStrategy::PaperFixed),
            "sampling" | "sampled" | "regular_sampling" => Ok(DivideStrategy::RegularSampling),
            "adaptive" => Ok(DivideStrategy::Adaptive),
            other => Err(Error::Config(format!(
                "unknown divide strategy `{other}` (valid: paper, sampling, adaptive)"
            ))),
        }
    }
}

/// Which simulation backend executes the parallel algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// OS threads + channels — the paper's own methodology (§5).
    Threaded,
    /// Discrete-event simulation with electrical/optical link models.
    DiscreteEvent,
}

impl Backend {
    /// Both backends, threaded (the paper's method) first.
    pub const ALL: [Backend; 2] = [Backend::Threaded, Backend::DiscreteEvent];

    /// Label used in campaign reports / CSV.
    pub fn label(self) -> &'static str {
        match self {
            Backend::Threaded => "threaded",
            Backend::DiscreteEvent => "des",
        }
    }

    /// Parse from config text.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threaded" => Ok(Backend::Threaded),
            "des" | "discrete_event" => Ok(Backend::DiscreteEvent),
            other => Err(Error::Config(format!("unknown backend `{other}`"))),
        }
    }
}

/// How the array-division (bucket id + histogram) hot path is executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DivideEngine {
    /// Pure-rust implementation (default fast path).
    Native,
    /// The AOT-compiled XLA artifact (L1 Pallas kernel via PJRT).
    Xla,
}

impl DivideEngine {
    /// Parse from config text.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(DivideEngine::Native),
            "xla" => Ok(DivideEngine::Xla),
            other => Err(Error::Config(format!("unknown divide engine `{other}`"))),
        }
    }
}

/// Link timing parameters for the discrete-event backend.
///
/// Defaults follow the optoelectronic literature's usual assumption that an
/// optical OTIS hop has lower latency and much higher bandwidth than an
/// electronic hop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Fixed per-hop latency of an electronic (intra-group) link, in ns.
    pub electrical_latency_ns: f64,
    /// Bytes/ns of an electronic link.
    pub electrical_bandwidth: f64,
    /// Fixed per-hop latency of an optical (inter-group) link, in ns.
    pub optical_latency_ns: f64,
    /// Bytes/ns of an optical link.
    pub optical_bandwidth: f64,
    /// Virtual ns charged per key-comparison of local compute.
    pub compute_ns_per_cmp: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel {
            electrical_latency_ns: 50.0,
            electrical_bandwidth: 1.0, // ~1 GB/s electronic
            optical_latency_ns: 25.0,
            optical_bandwidth: 16.0, // ~16 GB/s optical
            compute_ns_per_cmp: 1.0,
        }
    }
}

/// A single experiment: one cell of the paper's 216-run sweep.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// OHHC dimension `d_h` (paper sweeps 1..=4).
    pub dimension: u32,
    /// `G = P` or `G = P/2`.
    pub construction: Construction,
    /// Input key distribution.
    pub distribution: Distribution,
    /// Number of `i32` keys (paper: 10–60 MB → 2.5–15 M keys).
    pub elements: usize,
    /// RNG seed for workload generation (fixed for reproducibility).
    pub seed: u64,
    /// Simulation backend.
    pub backend: Backend,
    /// Division engine for the scatter phase.
    pub divide_engine: DivideEngine,
    /// How bucket boundaries are chosen (paper step points, sampled
    /// splitters, or adaptive guardrail).
    pub divide_strategy: DivideStrategy,
    /// DES link model (ignored by the threaded backend — the paper's
    /// conclusion notes thread simulation cannot express link speeds).
    pub link_model: LinkModel,
    /// Worker threads for the threaded backend; `0` = one OS thread per
    /// simulated processor (the paper's method, oversubscribed).
    pub workers: usize,
    /// Directory holding `*.hlo.txt` AOT artifacts.
    pub artifact_dir: PathBuf,
    /// Repetitions for timing figures (median reported).
    pub repetitions: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dimension: 1,
            construction: Construction::FullGroup,
            distribution: Distribution::Random,
            elements: 1 << 20,
            seed: 0x0511C0DE,
            backend: Backend::Threaded,
            divide_engine: DivideEngine::Native,
            divide_strategy: DivideStrategy::PaperFixed,
            link_model: LinkModel::default(),
            workers: 0,
            artifact_dir: PathBuf::from("artifacts"),
            repetitions: 1,
        }
    }
}

impl ExperimentConfig {
    /// Processors per OHHC group: `6 * 2^(d-1)` (paper §1.4).
    pub fn procs_per_group(&self) -> usize {
        6 * (1 << (self.dimension as usize - 1))
    }

    /// Number of groups under the configured construction.
    pub fn groups(&self) -> usize {
        self.construction.groups(self.procs_per_group())
    }

    /// Total processors = `G * P` (paper Table 1.1 "# of processors").
    pub fn total_processors(&self) -> usize {
        self.groups() * self.procs_per_group()
    }

    /// Validate the configuration against the paper's parameter space.
    pub fn validate(&self) -> Result<()> {
        if !(1..=6).contains(&self.dimension) {
            return Err(Error::Config(format!(
                "dimension must be 1..=6 (paper sweeps 1..=4), got {}",
                self.dimension
            )));
        }
        if self.elements == 0 {
            return Err(Error::Config("elements must be > 0".into()));
        }
        if self.elements < self.total_processors() {
            return Err(Error::Config(format!(
                "elements ({}) < total processors ({}); every processor needs \
                 a chance at a payload",
                self.elements,
                self.total_processors()
            )));
        }
        Ok(())
    }

    /// Load a config from a `key = value` file (see
    /// `examples/experiment.conf` for all keys).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = ExperimentConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let (key, value) = (key.trim(), value.trim());
            let bad = |e: String| Error::Config(format!("line {}: {e}", lineno + 1));
            match key {
                "dimension" => {
                    cfg.dimension = value.parse().map_err(|e| bad(e.to_string()))?
                }
                "construction" => {
                    cfg.construction = Construction::parse(value).map_err(|e| bad(e.to_string()))?
                }
                "distribution" => {
                    cfg.distribution = Distribution::parse(value).map_err(|e| bad(e.to_string()))?
                }
                "elements" => cfg.elements = value.parse().map_err(|e| bad(e.to_string()))?,
                "seed" => cfg.seed = value.parse().map_err(|e| bad(e.to_string()))?,
                "backend" => {
                    cfg.backend = Backend::parse(value).map_err(|e| bad(e.to_string()))?
                }
                "divide_engine" => {
                    cfg.divide_engine = DivideEngine::parse(value).map_err(|e| bad(e.to_string()))?
                }
                "divide_strategy" => {
                    cfg.divide_strategy =
                        DivideStrategy::parse(value).map_err(|e| bad(e.to_string()))?
                }
                "workers" => cfg.workers = value.parse().map_err(|e| bad(e.to_string()))?,
                "artifact_dir" => cfg.artifact_dir = PathBuf::from(value),
                "repetitions" => {
                    cfg.repetitions = value.parse().map_err(|e| bad(e.to_string()))?
                }
                "electrical_latency_ns" => {
                    cfg.link_model.electrical_latency_ns =
                        value.parse().map_err(|e| bad(e.to_string()))?
                }
                "electrical_bandwidth" => {
                    cfg.link_model.electrical_bandwidth =
                        value.parse().map_err(|e| bad(e.to_string()))?
                }
                "optical_latency_ns" => {
                    cfg.link_model.optical_latency_ns =
                        value.parse().map_err(|e| bad(e.to_string()))?
                }
                "optical_bandwidth" => {
                    cfg.link_model.optical_bandwidth =
                        value.parse().map_err(|e| bad(e.to_string()))?
                }
                "compute_ns_per_cmp" => {
                    cfg.link_model.compute_ns_per_cmp =
                        value.parse().map_err(|e| bad(e.to_string()))?
                }
                other => return Err(bad(format!("unknown key `{other}`"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Paper array sizes: 10–60 MB of `i32` (§5), scaled by `scale` so the
    /// full sweep fits a session budget (`scale = 1.0` is paper scale).
    pub fn paper_sizes(scale: f64) -> Vec<usize> {
        [10usize, 20, 30, 40, 50, 60]
            .iter()
            .map(|mb| ((mb * (1 << 20) / 4) as f64 * scale) as usize)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_1_1_full_group_counts() {
        // Paper Table 1.1, G = P column.
        let expect = [(1, 6, 36), (2, 12, 144), (3, 24, 576), (4, 48, 2304)];
        for (d, groups, total) in expect {
            let cfg = ExperimentConfig {
                dimension: d,
                construction: Construction::FullGroup,
                ..Default::default()
            };
            assert_eq!(cfg.groups(), groups, "d={d} groups");
            assert_eq!(cfg.total_processors(), total, "d={d} processors");
        }
    }

    #[test]
    fn table_1_1_half_group_counts() {
        // Paper Table 1.1, G = P/2 column.
        let expect = [(1, 3, 18), (2, 6, 72), (3, 12, 288), (4, 24, 1152)];
        for (d, groups, total) in expect {
            let cfg = ExperimentConfig {
                dimension: d,
                construction: Construction::HalfGroup,
                ..Default::default()
            };
            assert_eq!(cfg.groups(), groups, "d={d} groups");
            assert_eq!(cfg.total_processors(), total, "d={d} processors");
        }
    }

    #[test]
    fn validation_rejects_bad_dimension() {
        let cfg = ExperimentConfig {
            dimension: 0,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        let cfg = ExperimentConfig {
            dimension: 7,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_tiny_arrays() {
        let cfg = ExperimentConfig {
            dimension: 4,
            elements: 100, // < 2304 processors
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn paper_sizes_scale() {
        let full = ExperimentConfig::paper_sizes(1.0);
        assert_eq!(full[0], 10 * (1 << 20) / 4); // 10 MB of i32
        assert_eq!(full.len(), 6);
        let tenth = ExperimentConfig::paper_sizes(0.1);
        assert!(tenth[5] < full[5] / 9);
    }

    #[test]
    fn config_file_round_trip() {
        let dir = std::env::temp_dir().join("ohhc_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.conf");
        std::fs::write(
            &path,
            "# comment\n\
             dimension = 2\n\
             construction = half   # inline comment\n\
             distribution = sorted\n\
             elements = 123456\n\
             backend = des\n\
             divide_engine = xla\n\
             optical_bandwidth = 32.0\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.dimension, 2);
        assert_eq!(cfg.construction, Construction::HalfGroup);
        assert_eq!(cfg.distribution, Distribution::Sorted);
        assert_eq!(cfg.elements, 123456);
        assert_eq!(cfg.backend, Backend::DiscreteEvent);
        assert_eq!(cfg.divide_engine, DivideEngine::Xla);
        assert_eq!(cfg.link_model.optical_bandwidth, 32.0);
    }

    #[test]
    fn config_file_rejects_unknown_keys() {
        let dir = std::env::temp_dir().join("ohhc_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.conf");
        std::fs::write(&path, "no_such_key = 1\n").unwrap();
        assert!(ExperimentConfig::from_file(&path).is_err());
        std::fs::write(&path, "dimension 2\n").unwrap();
        assert!(ExperimentConfig::from_file(&path).is_err());
    }

    #[test]
    fn enum_parsers() {
        assert!(Construction::parse("full").is_ok());
        assert!(Construction::parse("xxx").is_err());
        assert!(Distribution::parse("reversed").is_ok());
        assert_eq!(
            Distribution::parse("reverse").unwrap(),
            Distribution::ReverseSorted
        );
        assert!(Backend::parse("threaded").is_ok());
        assert_eq!(Backend::parse("des").unwrap().label(), "des");
        assert!(DivideEngine::parse("xla").is_ok());
        assert_eq!(
            DivideStrategy::parse("paper").unwrap(),
            DivideStrategy::PaperFixed
        );
        assert_eq!(
            DivideStrategy::parse("sampling").unwrap(),
            DivideStrategy::RegularSampling
        );
        assert_eq!(
            DivideStrategy::parse("adaptive").unwrap().label(),
            "adaptive"
        );
        assert!(DivideStrategy::parse("xxx")
            .unwrap_err()
            .to_string()
            .contains("paper, sampling, adaptive"));
        assert!(Distribution::parse("anti_pivot").is_ok());
        assert!(Distribution::parse("zipf").is_ok());
    }

    #[test]
    fn config_file_accepts_divide_strategy() {
        let dir = std::env::temp_dir().join("ohhc_cfg_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("strat.conf");
        std::fs::write(&path, "divide_strategy = adaptive\n").unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.divide_strategy, DivideStrategy::Adaptive);
        // Default stays paper-faithful.
        assert_eq!(
            ExperimentConfig::default().divide_strategy,
            DivideStrategy::PaperFixed
        );
    }
}
