//! The flat data plane: arena-backed buckets shared by the coordinator
//! and both simulation backends.
//!
//! The paper's step-point division is order-preserving across buckets
//! (§3.1): concatenating the buckets in rank order and sorting each one
//! in place yields the globally sorted array, no merge required.  That
//! property means the buckets never need to be separate allocations —
//! [`FlatBuckets`] stores every key in **one contiguous arena** in
//! bucket-rank order plus a `P + 1` offset table, so
//!
//! * the divide scatters keys straight into their final resting place,
//! * local sorts run in place on disjoint `&mut [i32]` segments,
//! * the gather is pure bookkeeping (the arena *is* the sorted array),
//!   and message payloads become `(bucket, range)` descriptors.
//!
//! Compared with the previous `Vec<Vec<i32>>` representation this removes
//! `P` heap allocations per divide (up to 2304 at d = 4) and the full
//! `n`-key memcpy the final assemble used to pay.

use std::ops::Range;

/// Arena-backed buckets: one contiguous key buffer in bucket-rank order
/// plus its offset table.
///
/// Bucket `b` occupies `keys[offsets[b]..offsets[b + 1]]`; the offset
/// table is monotone, starts at 0, and ends at the total key count, so
/// bucket sizes and the load-imbalance factor are O(P) reads — no bucket
/// walk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlatBuckets {
    keys: Vec<i32>,
    offsets: Vec<usize>,
}

impl FlatBuckets {
    /// Assemble from a pre-scattered arena and its offset table
    /// (`offsets.len() == num_buckets + 1`).
    pub fn from_parts(keys: Vec<i32>, offsets: Vec<usize>) -> Self {
        debug_assert!(!offsets.is_empty(), "offset table needs a terminator");
        debug_assert_eq!(offsets[0], 0);
        debug_assert_eq!(*offsets.last().unwrap(), keys.len());
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]));
        FlatBuckets { keys, offsets }
    }

    /// Flatten a nested bucket set (compatibility constructor for tests,
    /// benches, and callers still producing `Vec<Vec<i32>>`).
    pub fn from_nested(nested: Vec<Vec<i32>>) -> Self {
        let total = nested.iter().map(Vec::len).sum();
        let mut keys = Vec::with_capacity(total);
        let mut offsets = Vec::with_capacity(nested.len() + 1);
        offsets.push(0);
        for bucket in &nested {
            keys.extend_from_slice(bucket);
            offsets.push(keys.len());
        }
        FlatBuckets { keys, offsets }
    }

    /// Number of buckets.
    pub fn num_buckets(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total keys across all buckets.
    pub fn total_keys(&self) -> usize {
        self.keys.len()
    }

    /// Bucket `b` as a slice.
    pub fn bucket(&self, b: usize) -> &[i32] {
        &self.keys[self.range(b)]
    }

    /// Arena range of bucket `b` — what a gather descriptor ships.
    pub fn range(&self, b: usize) -> Range<usize> {
        self.offsets[b]..self.offsets[b + 1]
    }

    /// Keys in bucket `b` (one subtraction — no bucket walk).
    pub fn size(&self, b: usize) -> usize {
        self.offsets[b + 1] - self.offsets[b]
    }

    /// All bucket sizes in keys (what the DES needs), O(P) off the
    /// offset table.
    pub fn sizes(&self) -> Vec<usize> {
        self.offsets.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// The offset table (`num_buckets + 1` entries, last == total keys).
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// The whole arena in bucket-rank order.
    pub fn arena(&self) -> &[i32] {
        &self.keys
    }

    /// Allocated capacity of the arena buffer (zero-copy witnesses
    /// compare this against the output vector's capacity).
    pub fn arena_capacity(&self) -> usize {
        self.keys.capacity()
    }

    /// Iterate the buckets as slices, rank order.
    pub fn iter(&self) -> impl Iterator<Item = &[i32]> {
        self.offsets.windows(2).map(|w| &self.keys[w[0]..w[1]])
    }

    /// Split the arena into disjoint mutable per-bucket segments — the
    /// in-place local-sort surface.  Segment `b` aliases exactly
    /// `arena[offsets[b]..offsets[b + 1]]`.
    pub fn segments_mut(&mut self) -> Vec<&mut [i32]> {
        let mut out = Vec::with_capacity(self.offsets.len() - 1);
        let mut rest: &mut [i32] = &mut self.keys;
        for w in self.offsets.windows(2) {
            let (seg, tail) = std::mem::take(&mut rest).split_at_mut(w[1] - w[0]);
            out.push(seg);
            rest = tail;
        }
        out
    }

    /// Largest bucket / ideal bucket — the load-imbalance factor, O(P).
    pub fn imbalance(&self) -> f64 {
        let total = self.total_keys();
        let ideal = total as f64 / self.num_buckets() as f64;
        let max = self.sizes().into_iter().max().unwrap_or(0);
        if ideal > 0.0 {
            max as f64 / ideal
        } else {
            0.0
        }
    }

    /// Surrender the arena (and offset table).  After in-place local
    /// sorts the arena in bucket-rank order **is** the globally sorted
    /// array — this is the zero-copy gather terminal.
    pub fn into_arena(self) -> (Vec<i32>, Vec<usize>) {
        (self.keys, self.offsets)
    }

    /// Borrow a contiguous bucket span as its own bucket view — how a
    /// batched (multi-tenant) arena exposes one job's sub-range without
    /// copying.  The span's bucket `b` is this arena's bucket
    /// `buckets.start + b`.
    pub fn span(&self, buckets: Range<usize>) -> FlatSpan<'_> {
        FlatSpan {
            keys: &self.keys[self.offsets[buckets.start]..self.offsets[buckets.end]],
            offsets: &self.offsets[buckets.start..=buckets.end],
        }
    }
}

/// Borrowed view of a contiguous bucket span of a [`FlatBuckets`] arena
/// (see [`FlatBuckets::span`]).  Offsets are the parent arena's —
/// rebased lazily in the accessors — so constructing a span is two slice
/// borrows, no allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlatSpan<'a> {
    keys: &'a [i32],
    offsets: &'a [usize],
}

impl<'a> FlatSpan<'a> {
    /// Buckets in the span.
    pub fn num_buckets(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Keys across the span.
    pub fn total_keys(&self) -> usize {
        self.keys.len()
    }

    /// The span's slice of the arena, bucket-rank order.
    pub fn keys(&self) -> &'a [i32] {
        self.keys
    }

    /// Bucket `b` of the span (`0`-based within the span).
    pub fn bucket(&self, b: usize) -> &'a [i32] {
        let base = self.offsets[0];
        &self.keys[self.offsets[b] - base..self.offsets[b + 1] - base]
    }

    /// Span bucket sizes, O(span) off the parent offset table.
    pub fn sizes(&self) -> Vec<usize> {
        self.offsets.windows(2).map(|w| w[1] - w[0]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FlatBuckets {
        FlatBuckets::from_nested(vec![vec![3, 1], vec![], vec![7, 5, 6], vec![9]])
    }

    #[test]
    fn from_nested_round_trips_layout() {
        let f = sample();
        assert_eq!(f.num_buckets(), 4);
        assert_eq!(f.total_keys(), 6);
        assert_eq!(f.offsets(), &[0, 2, 2, 5, 6]);
        assert_eq!(f.sizes(), vec![2, 0, 3, 1]);
        assert_eq!(f.bucket(0), &[3, 1]);
        assert_eq!(f.bucket(1), &[] as &[i32]);
        assert_eq!(f.bucket(2), &[7, 5, 6]);
        assert_eq!(f.range(2), 2..5);
        assert_eq!(f.arena(), &[3, 1, 7, 5, 6, 9]);
        let collected: Vec<&[i32]> = f.iter().collect();
        assert_eq!(collected.len(), 4);
        assert_eq!(collected[3], &[9]);
    }

    #[test]
    fn segments_are_disjoint_and_writable() {
        let mut f = sample();
        {
            let segs = f.segments_mut();
            assert_eq!(segs.len(), 4);
            assert_eq!(segs.iter().map(|s| s.len()).sum::<usize>(), 6);
            for seg in segs {
                seg.sort_unstable();
            }
        }
        assert_eq!(f.arena(), &[1, 3, 7, 5, 6, 9]);
        assert_eq!(f.bucket(2), &[5, 6, 7]);
    }

    #[test]
    fn into_arena_is_the_same_allocation() {
        let f = sample();
        let ptr = f.arena().as_ptr();
        let (arena, offsets) = f.into_arena();
        assert_eq!(arena.as_ptr(), ptr, "into_arena must not copy");
        assert_eq!(*offsets.last().unwrap(), arena.len());
    }

    #[test]
    fn imbalance_from_offsets() {
        let f = sample();
        // max 3 vs ideal 6/4 = 1.5 → 2.0.
        assert!((f.imbalance() - 2.0).abs() < 1e-12);
        let empty = FlatBuckets::from_nested(vec![Vec::new(); 3]);
        assert_eq!(empty.imbalance(), 0.0);
    }

    #[test]
    fn from_parts_matches_from_nested() {
        let a = sample();
        let b = FlatBuckets::from_parts(vec![3, 1, 7, 5, 6, 9], vec![0, 2, 2, 5, 6]);
        assert_eq!(a, b);
    }

    #[test]
    fn spans_view_bucket_ranges_without_copying() {
        let f = sample(); // buckets [3,1] [] [7,5,6] [9]
        let s = f.span(1..3);
        assert_eq!(s.num_buckets(), 2);
        assert_eq!(s.total_keys(), 3);
        assert_eq!(s.keys(), &[7, 5, 6]);
        assert_eq!(s.bucket(0), &[] as &[i32]);
        assert_eq!(s.bucket(1), &[7, 5, 6]);
        assert_eq!(s.sizes(), vec![0, 3]);
        // A span's keys alias the arena — same addresses, no copy.
        assert_eq!(s.keys().as_ptr(), f.bucket(2).as_ptr());
        // Whole-arena span round-trips.
        let whole = f.span(0..f.num_buckets());
        assert_eq!(whole.keys(), f.arena());
        assert_eq!(whole.num_buckets(), f.num_buckets());
    }
}
