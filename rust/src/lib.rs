//! # ohhc-qsort — Parallel Quick Sort on the OTIS Hyper Hexa-Cell network
//!
//! A full reproduction of *"Implementing Parallel Quick Sort Algorithm on
//! OTIS Hyper Hexa-Cell (OHHC) Interconnection Network"* (Nsour & Fasha,
//! 2021), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the OHHC topology library, a discrete-event
//!   optoelectronic network simulator, a paper-faithful multithreaded
//!   simulation backend, the instrumented sequential Quick Sort, the
//!   scatter / local-sort / three-phase-gather coordinator, workload
//!   generators, metrics, the analytical model (Theorems 1–6) and the
//!   figure-regeneration harness.
//! * **Layer 2 (python/compile/model.py)** — the array-division compute
//!   graph (min/max → SubDivider → bucket-id + histogram) and a bitonic
//!   block sorter, written in JAX.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   partition histogram (MXU-shaped one-hot contraction) and the bitonic
//!   network, lowered with `interpret=True`.
//!
//! Python runs only at `make artifacts`; [`runtime`] loads the AOT HLO via
//! PJRT so the request path is pure rust.
//!
//! ## Quick start
//!
//! ```no_run
//! use ohhc_qsort::config::{Construction, Distribution, ExperimentConfig};
//! use ohhc_qsort::coordinator::OhhcSorter;
//!
//! let cfg = ExperimentConfig {
//!     dimension: 2,
//!     construction: Construction::FullGroup, // G = P
//!     distribution: Distribution::Random,
//!     elements: 1 << 20,
//!     ..Default::default()
//! };
//! let report = OhhcSorter::new(&cfg).unwrap().run().unwrap();
//! println!("sorted {} keys in {:?}", report.elements, report.parallel_time);
//! ```

pub mod analysis;
pub mod baselines;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod figures;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod sort;
pub mod topology;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
