//! # ohhc-qsort — Parallel Quick Sort on the OTIS Hyper Hexa-Cell network
//!
//! A full reproduction of *"Implementing Parallel Quick Sort Algorithm on
//! OTIS Hyper Hexa-Cell (OHHC) Interconnection Network"* (Nsour & Fasha,
//! 2021), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the OHHC topology library, a discrete-event
//!   optoelectronic network simulator, a paper-faithful multithreaded
//!   simulation backend, the instrumented sequential Quick Sort, the
//!   **[`pipeline`] typestate session** (divide → local sort → gather,
//!   one API behind every driver, with per-stage traces and observer
//!   hooks), the thin configuration adapter over it
//!   ([`coordinator::OhhcSorter`]), workload generators, metrics, the
//!   analytical model (Theorems 1–6), the figure-regeneration harness,
//!   the [`campaign`] engine that runs the paper's whole §6 experiment
//!   grid concurrently with shared topology/plan caches, the
//!   [`service`] layer — a multi-tenant sort service (bounded job
//!   queue, per-job tickets, sorter pool, deadline-aware small-job
//!   batching, admission control, latency SLOs) for online serving,
//!   the [`cluster`] layer that scales that service out — N shards
//!   behind a deterministic rendezvous router, with a sampled
//!   scatter/merge path for jobs too big for one shard —
//!   and the persistent work-stealing executor ([`runtime::Executor`])
//!   that every one of those layers submits its parallel work to,
//!   keeping the sort hot path free of thread spawn/teardown after
//!   warmup.
//! * **Layer 2 (python/compile/model.py)** — the array-division compute
//!   graph (min/max → SubDivider → bucket-id + histogram) and a bitonic
//!   block sorter, written in JAX.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   partition histogram (MXU-shaped one-hot contraction) and the bitonic
//!   network, lowered with `interpret=True`.
//!
//! Python runs only at `make artifacts`; [`runtime`] loads the AOT HLO via
//! PJRT so the request path is pure rust (behind the `xla` feature — the
//! default build uses the offline stub in [`xla`]).
//!
//! ## Quick start — the pipeline session
//!
//! Every driver in the crate runs the paper's pipeline through one
//! typestate API: `Session<Configured>` → `divide()` →
//! `Session<Divided>` → `local_sort()` → `Session<Sorted>` →
//! `gather()` → `Outcome`.  Stage order is enforced by the type
//! system, each transition is timed into a
//! [`StageTrace`](pipeline::StageTrace), and the sorted output is the
//! divide arena itself (zero-copy end to end):
//!
//! ```
//! use ohhc_qsort::config::Construction;
//! use ohhc_qsort::pipeline::{Engine, Session};
//! use ohhc_qsort::schedule::TopologyBundle;
//!
//! let bundle = TopologyBundle::build(1, Construction::FullGroup)?; // 36 processors
//! let data = ohhc_qsort::workload::random(50_000, 42);
//! let outcome = Session::single(&bundle.net, &bundle.plans, &data)
//!     .with_engine(Engine::Pooled) // or DirectThreads / DiscreteEvent
//!     .divide()?
//!     .local_sort()?
//!     .gather()?;
//! assert!(outcome.sorted.windows(2).all(|w| w[0] <= w[1]));
//! println!("stages: {:?}", outcome.trace);
//! # Ok::<(), ohhc_qsort::Error>(())
//! ```
//!
//! ## Compatibility path — the experiment driver
//!
//! [`coordinator::OhhcSorter`] keeps the paper-facing configuration
//! surface (dimension, construction, distribution, backend) and drives
//! the same session underneath, adding the measured sequential
//! baseline and the speedup/efficiency report:
//!
//! ```no_run
//! use ohhc_qsort::config::{Construction, Distribution, ExperimentConfig};
//! use ohhc_qsort::coordinator::OhhcSorter;
//!
//! let cfg = ExperimentConfig {
//!     dimension: 2,
//!     construction: Construction::FullGroup, // G = P
//!     distribution: Distribution::Random,
//!     elements: 1 << 20,
//!     ..Default::default()
//! };
//! let report = OhhcSorter::new(&cfg).unwrap().run().unwrap();
//! println!("sorted {} keys in {:?}", report.elements, report.parallel_time);
//! println!("stage breakdown: {:?}", report.stage_times);
//! ```
//!
//! ## Campaign runs
//!
//! ```no_run
//! use ohhc_qsort::campaign::{Campaign, SweepSpec};
//!
//! let mut spec = SweepSpec::default();
//! spec.dimensions = vec![1, 2];
//! spec.sizes = vec![1 << 20];
//! let report = Campaign::new(spec).run().unwrap();
//! println!("{}", report.to_json().dump());
//! ```

// Every unsafe operation inside an `unsafe fn` must sit in its own
// `unsafe {}` block (with its own SAFETY comment — see `repolint`),
// and dropped `Result`s/`MustUse` values are hard errors crate-wide.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unused_must_use)]

pub mod analysis;
pub mod baselines;
pub mod campaign;
pub mod cluster;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod dataplane;
pub mod error;
pub mod figures;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod schedule;
pub mod service;
pub mod sim;
pub mod sort;
pub mod topology;
pub mod util;
pub mod workload;
pub mod xla;

pub use error::{Error, Result, StageError};

/// Boxed-error result for binaries and examples — the crate's `anyhow`
/// substitute (the default build is dependency-free).
pub type CliResult<T = ()> = std::result::Result<T, Box<dyn std::error::Error + Send + Sync>>;

/// Return early from a [`CliResult`] function with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err(::std::convert::From::from(format!($($arg)*)))
    };
}

/// Bail with a formatted error unless `cond` holds ([`CliResult`] contexts).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs(flag: bool) -> CliResult<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn cli_macros_format_and_propagate() {
        assert_eq!(needs(true).unwrap(), 7);
        let err = needs(false).unwrap_err();
        assert_eq!(err.to_string(), "flag was false");
    }

    #[test]
    fn cli_result_accepts_crate_errors() {
        fn run() -> CliResult {
            Err(Error::Config("boom".into()))?;
            Ok(())
        }
        assert!(run().unwrap_err().to_string().contains("boom"));
    }
}
