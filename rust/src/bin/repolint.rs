//! Repo-invariant lint gate — runs [`analysis::repolint::lint_tree`]
//! over the crate and exits nonzero on any violation.
//!
//! ```text
//! cargo run --bin repolint             # lint this crate's src/
//! cargo run --bin repolint -- --json   # machine-readable report
//! cargo run --bin repolint -- <dir>    # lint another crate root
//! ```
//!
//! Wired into `make lint` and CI; the rules themselves (SAFETY
//! comments on unsafe, wall-clock bans in event-clock layers, the
//! thread-spawn allowlist, the unwrap ratchet) are documented on
//! [`analysis::repolint`].

use std::path::PathBuf;
use std::process::ExitCode;

use ohhc_qsort::analysis::repolint;
use ohhc_qsort::util::json::Json;

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                eprintln!("usage: repolint [--json] [crate-root]");
                return ExitCode::SUCCESS;
            }
            other => root = Some(PathBuf::from(other)),
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));

    let violations = match repolint::lint_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("repolint: cannot read {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };

    if json {
        let report = Json::obj([
            ("root", Json::str(root.display().to_string())),
            ("violations", Json::Arr(violations.iter().map(|v| v.to_json()).collect())),
        ]);
        println!("{}", report.dump());
    } else if violations.is_empty() {
        println!("repolint: clean ({})", root.join("src").display());
    } else {
        for v in &violations {
            if v.line > 0 {
                eprintln!("src/{}:{}: [{}] {}", v.file, v.line, v.rule, v.message);
            } else {
                eprintln!("src/{}: [{}] {}", v.file, v.rule, v.message);
            }
        }
        eprintln!("repolint: {} violation(s)", violations.len());
    }
    if violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
