//! Pluggable XLA/PJRT facade.
//!
//! The real PJRT runtime (the `xla` crate plus its `xla_extension` C++
//! libraries) is a heavyweight dependency that offline build environments
//! cannot fetch.  This facade keeps [`crate::runtime`] compiling — and the
//! rest of the crate fully functional — everywhere:
//!
//! * **default build** — the stub below.  [`ArtifactRegistry`] opens and
//!   validates manifests as usual, but compiling or executing an artifact
//!   returns an [`Error`] explaining that the `xla` feature is off.  The
//!   native divide engine (the default hot path) is unaffected.
//! * **`--features xla`** — re-exports the real `xla` crate.  Enabling the
//!   feature requires adding `xla` to `[dependencies]` on a toolchain
//!   image that ships `xla_extension`.
//!
//! [`ArtifactRegistry`]: crate::runtime::ArtifactRegistry

#[cfg(feature = "xla")]
pub use ::xla::*;

#[cfg(not(feature = "xla"))]
pub use stub::*;

#[cfg(not(feature = "xla"))]
mod stub {
    use std::fmt;
    use std::path::Path;

    /// Error surfaced by the stub runtime.
    #[derive(Debug, Clone)]
    pub struct Error(String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            self.0.fmt(f)
        }
    }

    impl std::error::Error for Error {}

    fn disabled<T>(what: &str) -> Result<T, Error> {
        Err(Error(format!(
            "{what}: built without the `xla` feature (PJRT runtime unavailable); \
             use the native divide engine or rebuild with --features xla"
        )))
    }

    /// PJRT client handle (stub: constructible so registries can open and
    /// validate manifests; any compile/execute call fails loudly).
    #[derive(Debug)]
    pub struct PjRtClient;

    impl PjRtClient {
        /// "Create" the CPU client.
        pub fn cpu() -> Result<PjRtClient, Error> {
            Ok(PjRtClient)
        }

        /// Platform label shown by diagnostics.
        pub fn platform_name(&self) -> String {
            "stub (xla feature disabled)".to_string()
        }

        /// Devices available (none on the stub).
        pub fn device_count(&self) -> usize {
            0
        }

        /// Compile a computation — always fails on the stub.
        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            disabled("compile")
        }
    }

    /// Parsed HLO module (never constructible on the stub).
    #[derive(Debug)]
    pub struct HloModuleProto;

    impl HloModuleProto {
        /// Parse HLO text — always fails on the stub.
        pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
            disabled(&format!("load {}", path.as_ref().display()))
        }
    }

    /// XLA computation wrapper.
    #[derive(Debug)]
    pub struct XlaComputation;

    impl XlaComputation {
        /// Wrap a parsed module.
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    /// Compiled executable (never constructible on the stub).
    #[derive(Debug)]
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        /// Execute — always fails on the stub.
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            disabled("execute")
        }
    }

    /// Device buffer handle.
    #[derive(Debug)]
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        /// Copy back to the host — always fails on the stub.
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            disabled("to_literal_sync")
        }
    }

    /// Host literal.
    #[derive(Debug)]
    pub struct Literal;

    impl Literal {
        /// Build a rank-1 literal (accepted and discarded by the stub).
        pub fn vec1<T>(_values: &[T]) -> Literal {
            Literal
        }

        /// Destructure a tuple literal — always fails on the stub.
        pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
            disabled("to_tuple")
        }

        /// Destructure a 1-tuple literal — always fails on the stub.
        pub fn to_tuple1(&self) -> Result<Literal, Error> {
            disabled("to_tuple1")
        }

        /// Copy out as a typed vector — always fails on the stub.
        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            disabled("to_vec")
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn client_opens_but_execution_is_disabled() {
            let client = PjRtClient::cpu().unwrap();
            assert_eq!(client.device_count(), 0);
            assert!(client.platform_name().contains("stub"));
            let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
            assert!(err.to_string().contains("xla"), "{err}");
            let exe = PjRtLoadedExecutable;
            assert!(exe.execute(&[Literal::vec1(&[1i32])]).is_err());
        }
    }
}
