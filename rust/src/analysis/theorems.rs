//! Closed forms of the paper's analytical assessment (§4 / Table 4.1).

/// Theorem 1 — average parallel time complexity `Θ((n/P)·log(n/P))`,
/// returned as the Θ-argument (comparisons).
pub fn theorem1_parallel_work(n: f64, p: f64) -> f64 {
    let chunk = n / p;
    if chunk < 2.0 {
        chunk
    } else {
        chunk * chunk.log2()
    }
}

/// Sequential counterpart `Θ(n·log n)`.
pub fn sequential_work(n: f64) -> f64 {
    if n < 2.0 {
        n
    } else {
        n * n.log2()
    }
}

/// Theorem 3 — total communication steps `12·G·d_h − 2` (source →
/// destinations → source).
///
/// **Fidelity note:** the paper's derivation counts `(d_h − 1)·6` inter-cell
/// steps per group, i.e. it implicitly assumes `P = 6·d_h` processors per
/// group.  That matches the true per-group tree size `P − 1 = 6·2^(d−1) − 1`
/// only for `d_h ≤ 2`; from `d_h = 3` the closed form undercounts the tree
/// the algorithm actually walks.  [`exact_tree_steps`] gives the exact
/// count; `validate::theorem3` compares both against the DES trace.
pub fn theorem3_comm_steps(groups: usize, dimension: u32) -> usize {
    12 * groups * dimension as usize - 2
}

/// Exact link traversals of one scatter+gather over the schedule tree:
/// `2·(G·P − 1)` (every non-master node receives once and sends once).
pub fn exact_tree_steps(groups: usize, procs_per_group: usize) -> usize {
    2 * (groups * procs_per_group - 1)
}

/// Electrical-step component of Theorem 3: `12·G·d_h − 2·G`.
pub fn theorem3_electrical_steps(groups: usize, dimension: u32) -> usize {
    12 * groups * dimension as usize - 2 * groups
}

/// Optical-step component of Theorem 3: `2·G − 2`.
pub fn theorem3_optical_steps(groups: usize) -> usize {
    2 * groups - 2
}

/// Theorem 4 — speedup `Θ(P·log n / (log n − log P))`.
pub fn theorem4_speedup(n: f64, p: f64) -> f64 {
    p * n.log2() / (n.log2() - p.log2())
}

/// Theorem 5 — efficiency `Θ(log n / (log n − log P))`.
pub fn theorem5_efficiency(n: f64, p: f64) -> f64 {
    n.log2() / (n.log2() - p.log2())
}

/// Theorem 6 — message delay `Θ(t · (2·d_h + 3))` with `t = n/P` on
/// average and `t ≈ n` in the worst case of partitioning.
pub fn theorem6_message_delay(t: f64, dimension: u32) -> f64 {
    t * (2.0 * dimension as f64 + 3.0)
}

/// Longest store-and-forward route in links: group diameter, optical hop,
/// group diameter again — `2·(d_h + 1) + 1 = 2·d_h + 3` (the paper's `L`).
pub fn longest_route_links(dimension: u32) -> u32 {
    2 * dimension + 3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem1_matches_hand_values() {
        // n = 1024, P = 4 → chunk 256, work 256·8 = 2048.
        assert!((theorem1_parallel_work(1024.0, 4.0) - 2048.0).abs() < 1e-9);
        assert!((sequential_work(1024.0) - 10240.0).abs() < 1e-9);
    }

    #[test]
    fn theorem3_closed_form_values() {
        // d=1, G=6 (full): 12·6·1 − 2 = 70; electrical 60, optical 10.
        assert_eq!(theorem3_comm_steps(6, 1), 70);
        assert_eq!(theorem3_electrical_steps(6, 1), 60);
        assert_eq!(theorem3_optical_steps(6), 10);
        // Components sum to the total.
        for (g, d) in [(6usize, 1u32), (12, 2), (24, 3), (48, 4)] {
            assert_eq!(
                theorem3_electrical_steps(g, d) + theorem3_optical_steps(g),
                theorem3_comm_steps(g, d)
            );
        }
    }

    #[test]
    fn theorem3_vs_exact_tree() {
        // The paper's form matches the exact tree for d ≤ 2 …
        assert_eq!(theorem3_comm_steps(6, 1), exact_tree_steps(6, 6));
        assert_eq!(theorem3_comm_steps(12, 2), exact_tree_steps(12, 12));
        // … and undercounts from d = 3 (documented fidelity gap).
        assert!(theorem3_comm_steps(24, 3) < exact_tree_steps(24, 24));
        assert!(theorem3_comm_steps(48, 4) < exact_tree_steps(48, 48));
    }

    #[test]
    fn theorem4_5_consistency() {
        // E = S / P must hold between the closed forms.
        for (n, p) in [(1e6, 36.0), (4e6, 144.0), (1.5e7, 2304.0)] {
            let s = theorem4_speedup(n, p);
            let e = theorem5_efficiency(n, p);
            assert!((s / p - e).abs() < 1e-9, "n={n} p={p}");
        }
    }

    #[test]
    fn speedup_grows_with_p_efficiency_shrinks() {
        let n = 1e7;
        let s36 = theorem4_speedup(n, 36.0);
        let s2304 = theorem4_speedup(n, 2304.0);
        assert!(s2304 > s36);
        let e36 = theorem5_efficiency(n, 36.0);
        let e2304 = theorem5_efficiency(n, 2304.0);
        // Efficiency DEGRADES toward … wait: Θ(log n/(log n − log P))
        // *increases* with P — the Θ form hides the constant-factor
        // communication costs that make measured efficiency fall (the
        // paper's Figs 6.12–6.19).  Both behaviours are real; we assert
        // the closed form here and the measured trend in the figures.
        assert!(e2304 > e36);
    }

    #[test]
    fn theorem6_delay_shapes() {
        assert_eq!(longest_route_links(1), 5);
        assert_eq!(longest_route_links(4), 11);
        let avg = theorem6_message_delay(1e6 / 36.0, 1);
        let worst = theorem6_message_delay(1e6, 1);
        assert!(worst / avg > 30.0);
    }
}
