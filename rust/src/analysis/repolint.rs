//! Repo-invariant lint: machine-checks the crate's safety and
//! determinism conventions that clippy cannot see.
//!
//! Four rules, each born from a convention this codebase already
//! follows and must not regress:
//!
//! * **`unsafe-safety-comment`** — every `unsafe` block, fn, or impl
//!   must be preceded by a `// SAFETY:` comment within the previous
//!   [`SAFETY_LOOKBACK`] lines (or carry one on the same line).  The
//!   divide scatter, `util::par`'s slot arrays, and the executor's
//!   lifetime erasure all document their proof obligations this way;
//!   new unsafe must too.
//! * **`wall-clock`** — `Instant::now` / `SystemTime` are banned inside
//!   `sim/` and the cluster's health/fault decision logic.  Those
//!   layers are event-clock driven (deterministic, replayable); wall
//!   time belongs only to measurement instruments.  `sim/threaded.rs`
//!   *is* such an instrument (the paper-faithful timed backend), so it
//!   is exempt wholesale; single measurement-only sites elsewhere carry
//!   an inline `repolint: allow(wall-clock)` waiver.
//! * **`thread-spawn`** — raw `thread::spawn` / `thread::Builder` is
//!   restricted to the deliberate sites (the executor's worker pool,
//!   the paper-threads simulator, the service pool, the cluster's
//!   split/supervisor workers).  Everything else must submit to the
//!   shared executor, which is what keeps the hot path spawn-free.
//! * **`unwrap-budget`** — `.unwrap()` in `service/` and `cluster/`
//!   non-test code is ratcheted against [`UNWRAP_BUDGET`].  The checked
//!   counts are lock poisoning and similar crate-internal invariants;
//!   the budget must never grow, and when a file sheds unwraps the
//!   table must be ratcheted *down* to match (drift in either
//!   direction fails).
//!
//! Rules scan only the non-test region of each file — everything above
//! the first `#[cfg(test)]` line (the crate convention keeps a single
//! trailing test module per file).  Comment lines never trigger rules;
//! they only satisfy them (SAFETY comments, waivers).
//!
//! The `repolint` binary (src/bin/repolint.rs) runs [`lint_tree`] over
//! the crate and exits nonzero on any violation; `make lint` and CI
//! gate on it.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// How far back (in lines) a `SAFETY` comment may sit from its
/// `unsafe` site.
pub const SAFETY_LOOKBACK: usize = 10;

/// Files allowed to call `thread::spawn` / `thread::Builder` directly.
pub const SPAWN_ALLOWLIST: &[&str] = &[
    "runtime/executor.rs", // the pool's worker threads
    "sim/threaded.rs",     // paper-faithful one-thread-per-processor mode
    "service/pool.rs",     // service worker threads
    "cluster/mod.rs",      // split scatter/merge + failover supervisor
];

/// Files under the wall-clock ban (event-clock layers).  `sim/` is
/// matched as a prefix; the exemptions list overrides it.
const WALL_CLOCK_SCOPES: &[&str] = &["sim/", "cluster/health.rs", "cluster/faults.rs"];

/// The wall-clock measurement instrument inside `sim/`: its whole job
/// is timing real threads, so the ban does not apply.
const WALL_CLOCK_EXEMPT: &[&str] = &["sim/threaded.rs"];

/// Inline waiver marker for a single deliberate wall-clock site (same
/// line or the line above).
const WALL_CLOCK_WAIVER: &str = "repolint: allow(wall-clock)";

/// The `.unwrap()` ratchet for `service/` and `cluster/` non-test
/// code: exact counts, checked in.  Files not listed budget zero.
pub const UNWRAP_BUDGET: &[(&str, usize)] = &[
    ("cluster/health.rs", 10),
    ("cluster/mod.rs", 14),
    ("cluster/stats.rs", 4),
    ("service/admission.rs", 1),
    ("service/pool.rs", 5),
    ("service/queue.rs", 9),
    ("service/stats.rs", 16),
    ("service/ticket.rs", 11),
];

/// One broken invariant, pinned to a file and line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Which rule fired (`unsafe-safety-comment`, `wall-clock`,
    /// `thread-spawn`, `unwrap-budget`).
    pub rule: &'static str,
    /// Path relative to `src/`, forward slashes.
    pub file: String,
    /// 1-indexed line (0 for whole-file findings like budget drift).
    pub line: usize,
    /// Human-readable explanation.
    pub message: String,
}

impl Violation {
    /// The violation as a JSON object (for `repolint --json`).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("file", Json::str(&self.file)),
            ("line", Json::int(self.line)),
            ("message", Json::str(&self.message)),
            ("rule", Json::str(self.rule)),
        ])
    }
}

/// Lint every `.rs` file under `<root>/src`, returning all violations
/// sorted by file and line.  `root` is the crate directory (the one
/// holding `Cargo.toml`).
pub fn lint_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let src = root.join("src");
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files)?;
    let mut violations = Vec::new();
    for path in files {
        let text = fs::read_to_string(&path)?;
        let label = path
            .strip_prefix(&src)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        violations.extend(lint_source(&label, &text));
    }
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(violations)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file's source.  `label` is the `src/`-relative path with
/// forward slashes (e.g. `"cluster/health.rs"`).
pub fn lint_source(label: &str, text: &str) -> Vec<Violation> {
    let lines: Vec<&str> = text.lines().collect();
    // The non-test region: everything above the file's (single,
    // trailing) test module.
    let test_start = lines
        .iter()
        .position(|l| l.trim_start().starts_with(concat!("#[cfg(", "test)]")))
        .unwrap_or(lines.len());
    let region = &lines[..test_start];

    let mut v = Vec::new();
    check_unsafe_comments(label, region, &mut v);
    check_wall_clock(label, region, &mut v);
    check_thread_spawn(label, region, &mut v);
    check_unwrap_budget(label, region, &mut v);
    v
}

fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// Does `needle` occur in `line` as a standalone word (not an
/// identifier fragment like `unsafe_op_in_unsafe_fn`)?  Returns the
/// byte offset just past the match.
fn find_word(line: &str, needle: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = line[from..].find(needle) {
        let start = from + rel;
        let end = start + needle.len();
        let boundary = |c: char| !c.is_alphanumeric() && c != '_';
        let before_ok = line[..start].chars().next_back().map_or(true, boundary);
        let after_ok = line[end..].chars().next().map_or(true, boundary);
        if before_ok && after_ok {
            return Some(end);
        }
        from = end;
    }
    None
}

fn check_unsafe_comments(label: &str, region: &[&str], out: &mut Vec<Violation>) {
    let keyword = concat!("uns", "afe");
    for (i, line) in region.iter().enumerate() {
        if is_comment(line) {
            continue;
        }
        let Some(end) = find_word(line, keyword) else {
            continue;
        };
        // Only blocks, fns, and impls need the proof comment; `unsafe`
        // inside a string or attribute has no following token of that
        // shape on the same line in this codebase.
        let rest = line[end..].trim_start();
        let introduces = rest.starts_with('{')
            || rest.starts_with("fn ")
            || rest.starts_with("impl ")
            || rest.starts_with("impl<")
            || rest.is_empty(); // `let run = unsafe` + `{` on the next line
        if !introduces {
            continue;
        }
        let lookback_start = i.saturating_sub(SAFETY_LOOKBACK);
        let documented = line.contains("SAFETY")
            || region[lookback_start..i].iter().any(|l| l.contains("SAFETY"));
        if !documented {
            out.push(Violation {
                rule: "unsafe-safety-comment",
                file: label.to_string(),
                line: i + 1,
                message: format!(
                    "`{keyword}` without a `// SAFETY:` comment in the previous \
                     {SAFETY_LOOKBACK} lines"
                ),
            });
        }
    }
}

fn check_wall_clock(label: &str, region: &[&str], out: &mut Vec<Violation>) {
    let scoped = WALL_CLOCK_SCOPES
        .iter()
        .any(|s| if s.ends_with('/') { label.starts_with(s) } else { label == *s });
    if !scoped || WALL_CLOCK_EXEMPT.contains(&label) {
        return;
    }
    let needles = [concat!("Instant::", "now"), concat!("System", "Time")];
    for (i, line) in region.iter().enumerate() {
        if is_comment(line) {
            continue;
        }
        let Some(needle) = needles.iter().find(|n| line.contains(**n)) else {
            continue;
        };
        let waived = line.contains(WALL_CLOCK_WAIVER)
            || (i > 0 && region[i - 1].contains(WALL_CLOCK_WAIVER));
        if !waived {
            out.push(Violation {
                rule: "wall-clock",
                file: label.to_string(),
                line: i + 1,
                message: format!(
                    "`{needle}` in an event-clock layer (decisions must be driven by \
                     event ids, not wall time); a measurement-only site may carry a \
                     `{WALL_CLOCK_WAIVER}` comment"
                ),
            });
        }
    }
}

fn check_thread_spawn(label: &str, region: &[&str], out: &mut Vec<Violation>) {
    if SPAWN_ALLOWLIST.contains(&label) {
        return;
    }
    let needles = [concat!("thread::", "spawn"), concat!("thread::", "Builder")];
    for (i, line) in region.iter().enumerate() {
        if is_comment(line) {
            continue;
        }
        if let Some(needle) = needles.iter().find(|n| line.contains(**n)) {
            out.push(Violation {
                rule: "thread-spawn",
                file: label.to_string(),
                line: i + 1,
                message: format!(
                    "`{needle}` outside the deliberate-spawn allowlist — submit to \
                     `runtime::Executor::global()` instead"
                ),
            });
        }
    }
}

fn check_unwrap_budget(label: &str, region: &[&str], out: &mut Vec<Violation>) {
    if !label.starts_with("service/") && !label.starts_with("cluster/") {
        return;
    }
    let needle = concat!(".unw", "rap()");
    let count: usize = region
        .iter()
        .filter(|l| !is_comment(l))
        .map(|l| l.matches(needle).count())
        .sum();
    let budget =
        UNWRAP_BUDGET.iter().find(|(f, _)| *f == label).map(|&(_, n)| n).unwrap_or(0);
    if count > budget {
        out.push(Violation {
            rule: "unwrap-budget",
            file: label.to_string(),
            line: 0,
            message: format!(
                "{count} `{needle}` calls in non-test code exceed the checked-in \
                 budget of {budget} — handle the error or use expect with an \
                 invariant message"
            ),
        });
    } else if count < budget {
        out.push(Violation {
            rule: "unwrap-budget",
            file: label.to_string(),
            line: 0,
            message: format!(
                "{count} `{needle}` calls against a stale budget of {budget} — \
                 ratchet UNWRAP_BUDGET down so the count cannot silently regrow"
            ),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn documented_unsafe_passes_and_bare_unsafe_fails() {
        let good = "// SAFETY: slot handed to exactly one task.\nlet x = unsafe { p.read() };\n";
        assert!(lint_source("util/x.rs", good).is_empty());
        let bad = "let x = unsafe { p.read() };\n";
        assert_eq!(rules(&lint_source("util/x.rs", bad)), ["unsafe-safety-comment"]);
        // The comment must be within the lookback window.
        let gap = "\n".repeat(SAFETY_LOOKBACK + 1);
        let far = format!("// SAFETY: too far away.\n{gap}unsafe impl Send for X {{}}\n");
        assert_eq!(rules(&lint_source("util/x.rs", &far)), ["unsafe-safety-comment"]);
    }

    #[test]
    fn unsafe_as_identifier_fragment_or_comment_is_ignored() {
        let attr = "#![deny(unsafe_op_in_unsafe_fn)]\n";
        assert!(lint_source("lib.rs", attr).is_empty());
        let comment = "// unsafe is spelled out here in prose only\n";
        assert!(lint_source("util/x.rs", comment).is_empty());
    }

    #[test]
    fn wall_clock_scoping_exemption_and_waiver() {
        let src = "let t = Instant::now();\n";
        assert_eq!(rules(&lint_source("sim/des.rs", src)), ["wall-clock"]);
        assert_eq!(rules(&lint_source("cluster/health.rs", src)), ["wall-clock"]);
        // Out of scope: wall time is fine elsewhere.
        assert!(lint_source("service/pool.rs", src).is_empty());
        // The measurement instrument is exempt wholesale.
        assert!(lint_source("sim/threaded.rs", src).is_empty());
        // A waiver on the previous line admits a measurement-only site.
        let waived =
            format!("// {WALL_CLOCK_WAIVER} — measurement only\nlet t = Instant::now();\n");
        assert!(lint_source("cluster/health.rs", &waived).is_empty());
        let sys = "let t = SystemTime::now();\n";
        assert_eq!(rules(&lint_source("cluster/faults.rs", sys)), ["wall-clock"]);
    }

    #[test]
    fn spawn_allowlist_is_enforced() {
        let src = "std::thread::spawn(|| {});\n";
        assert_eq!(rules(&lint_source("coordinator/divide.rs", src)), ["thread-spawn"]);
        assert!(lint_source("runtime/executor.rs", src).is_empty());
        let builder = "let h = thread::Builder::new();\n";
        assert_eq!(rules(&lint_source("metrics/mod.rs", builder)), ["thread-spawn"]);
        assert!(lint_source("cluster/mod.rs", builder).is_empty());
    }

    #[test]
    fn unwrap_budget_ratchets_both_directions() {
        // An unlisted service file budgets zero.
        let one = "let x = m.lock().unwrap();\n";
        assert_eq!(rules(&lint_source("service/new_file.rs", one)), ["unwrap-budget"]);
        // Out of scope entirely.
        assert!(lint_source("topology/fault.rs", one).is_empty());
        // Exactly on budget: clean.  service/admission.rs budgets 1.
        assert!(lint_source("service/admission.rs", one).is_empty());
        // Under budget: stale table must be ratcheted down.
        let zero = "let x = 1;\n";
        let v = lint_source("service/admission.rs", zero);
        assert_eq!(rules(&v), ["unwrap-budget"]);
        assert!(v[0].message.contains("stale"), "{}", v[0].message);
    }

    #[test]
    fn test_region_is_not_linted() {
        let src = "fn ok() {}\n#[cfg(test)]\nmod tests {\n    fn f() { \
                   let x = unsafe { p() }; std::thread::spawn(|| {}); }\n}\n";
        assert!(lint_source("service/x.rs", src).is_empty());
    }

    #[test]
    fn violations_serialize_to_json() {
        let v = lint_source("util/x.rs", "let x = unsafe { p.read() };\n");
        let json = v[0].to_json().dump();
        assert!(json.contains("unsafe-safety-comment"), "{json}");
        assert!(json.contains("util/x.rs"), "{json}");
    }
}
