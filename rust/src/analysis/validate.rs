//! Empirical validation of the analytical model against the DES.
//!
//! The paper derives Theorems 3 and 6 but never measures them (its
//! threaded simulation has no link model).  Here the DES trace supplies
//! the measured counterparts, and the comparison is part of the test
//! suite and the `table_4_1` figure output.

use crate::analysis::theorems;
use crate::config::{Construction, LinkModel};
use crate::schedule::gather_plan;
use crate::sim::engine::DesSimulator;
use crate::topology::ohhc::Ohhc;

/// Measured-vs-analytical comparison for one topology.
#[derive(Debug, Clone)]
pub struct Theorem3Check {
    /// OHHC dimension.
    pub dimension: u32,
    /// Groups.
    pub groups: usize,
    /// Paper's closed form `12·G·d_h − 2`.
    pub paper_form: usize,
    /// Exact tree steps `2·(G·P − 1)`.
    pub exact_form: usize,
    /// Steps measured from the DES trace.
    pub measured: usize,
    /// Optical steps measured.
    pub measured_optical: usize,
    /// Paper's optical component `2·G − 2`.
    pub paper_optical: usize,
}

/// Run the DES once on a uniform workload and compare step counts.
pub fn theorem3(dimension: u32, construction: Construction) -> Theorem3Check {
    let net = Ohhc::new(dimension, construction).expect("valid dimension");
    let plans = gather_plan(&net);
    let n = net.total_processors();
    let sizes = vec![64usize; n];
    let out = DesSimulator::new(&net, &plans, LinkModel::default())
        .run(&sizes, None)
        .expect("DES run");
    let (elec, opt) = out.trace.steps();
    Theorem3Check {
        dimension,
        groups: net.groups,
        paper_form: theorems::theorem3_comm_steps(net.groups, dimension),
        exact_form: theorems::exact_tree_steps(net.groups, net.procs_per_group),
        measured: elec + opt,
        measured_optical: opt,
        paper_optical: theorems::theorem3_optical_steps(net.groups),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_steps_equal_exact_tree_form() {
        for d in 1..=3 {
            for c in [Construction::FullGroup, Construction::HalfGroup] {
                let chk = theorem3(d, c);
                assert_eq!(chk.measured, chk.exact_form, "d={d} {c:?}");
            }
        }
    }

    #[test]
    fn paper_form_matches_exact_for_low_dimensions_full_group() {
        // The paper's 12·G·d_h − 2 equals the exact tree count at d ≤ 2
        // (where P = 6·d_h holds), and optical counts match at every d.
        for d in 1..=2 {
            let chk = theorem3(d, Construction::FullGroup);
            assert_eq!(chk.paper_form, chk.measured, "d={d}");
        }
        for d in 1..=4 {
            let chk = theorem3(d, Construction::FullGroup);
            assert_eq!(chk.measured_optical, chk.paper_optical, "d={d}");
        }
    }

    #[test]
    fn paper_form_undercounts_at_high_dimension() {
        let chk = theorem3(3, Construction::FullGroup);
        assert!(chk.paper_form < chk.measured);
    }
}
