//! Analytical model — closed forms of the paper's Theorems 1–6 (§4,
//! Table 4.1) and their validation against the simulators — plus the
//! repo-invariant lint ([`repolint`]) that keeps the crate's safety
//! and determinism conventions machine-checked.

pub mod repolint;
pub mod theorems;
pub mod validate;

pub use theorems::*;
