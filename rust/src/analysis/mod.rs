//! Analytical model — closed forms of the paper's Theorems 1–6 (§4,
//! Table 4.1) and their validation against the simulators.

pub mod theorems;
pub mod validate;

pub use theorems::*;
