//! Baseline parallel sorts the paper's related-work section compares
//! against (multithreaded Quick Sort variants [5–7], hypercube-style
//! network sorts), implemented on the same substrates so the ablation
//! benches can answer *"is the OHHC step-point design the interesting
//! part, or would any parallel sort do?"*
//!
//! * [`shared_fork`] — shared-memory fork/join Quick Sort (the classic
//!   multithreaded variant of refs [5–7]): partition in place, fork the
//!   halves onto new threads down to a depth budget.
//! * [`psrs`] — Parallel Sorting by Regular Sampling: sample-based
//!   splitters instead of the paper's value-range step points; robust to
//!   skew where the step-point divider is not.
//! * [`hypercube_bitonic`] — bitonic compare-split sort on the binary
//!   hypercube (the classic network-sort baseline for interconnection
//!   topologies).

pub mod hypercube_bitonic;
pub mod psrs;
pub mod shared_fork;

pub use hypercube_bitonic::hypercube_bitonic_sort;
pub use psrs::psrs_sort;
pub use shared_fork::shared_fork_sort;
