//! Parallel Sorting by Regular Sampling (Shi & Schaeffer 1992) adapted to
//! the OHHC processor count — the classic sample-based alternative to the
//! paper's value-range step points.
//!
//! Phases (simulated single-address-space, like the paper's threads):
//!
//! 1. split the input into `P` contiguous slices; sort each locally;
//! 2. each slice contributes `P` regular samples; the master sorts the
//!    `P²` samples and picks `P−1` splitters;
//! 3. every slice is partitioned by the splitters; partitions are
//!    exchanged (bucket `b` collects every slice's `b`-th partition);
//! 4. each bucket k-way-merges its sorted runs; concatenation is sorted.
//!
//! The payoff over step points: splitters adapt to the *distribution*,
//! so heavily skewed inputs still balance (see the skew tests and the
//! `parallel_sort` ablation bench).

use crate::sort::{quicksort, SortCounters};

/// Outcome of a PSRS run.
#[derive(Debug)]
pub struct PsrsOutcome {
    /// The sorted keys.
    pub sorted: Vec<i32>,
    /// Summed local-sort counters (phase 1 sorts).
    pub counters: SortCounters,
    /// Largest bucket / ideal bucket (load balance of phase 4).
    pub imbalance: f64,
}

/// Sort with `p` virtual processors (the OHHC's `G·P` in the ablation).
pub fn psrs_sort(data: &[i32], p: usize) -> PsrsOutcome {
    assert!(p >= 1);
    let n = data.len();
    if n == 0 || p == 1 {
        let mut sorted = data.to_vec();
        let counters = quicksort(&mut sorted);
        return PsrsOutcome {
            sorted,
            counters,
            imbalance: 1.0,
        };
    }

    // Phase 1: contiguous slices, local sorts.
    let slice_len = n.div_ceil(p);
    let mut slices: Vec<Vec<i32>> = data.chunks(slice_len).map(<[i32]>::to_vec).collect();
    let mut counters = SortCounters::default();
    for s in &mut slices {
        counters += quicksort(s);
    }

    // Phase 2: regular samples → splitters.
    let mut samples = Vec::with_capacity(p * slices.len());
    for s in &slices {
        if s.is_empty() {
            continue;
        }
        for k in 0..p {
            samples.push(s[k * s.len() / p]);
        }
    }
    samples.sort_unstable();
    let splitters: Vec<i32> = (1..p).map(|k| samples[k * samples.len() / p]).collect();

    // Phase 3: partition every slice by the splitters (binary search on
    // the sorted slice), route partitions to their buckets.
    let mut buckets: Vec<Vec<i32>> = vec![Vec::new(); p];
    for s in &slices {
        let mut start = 0usize;
        for (b, &sp) in splitters.iter().enumerate() {
            let end = start + s[start..].partition_point(|&v| v <= sp);
            buckets[b].extend_from_slice(&s[start..end]);
            start = end;
        }
        buckets[p - 1].extend_from_slice(&s[start..]);
    }

    // Phase 4: each bucket holds ≤ p sorted runs — merge them.
    let ideal = n as f64 / p as f64;
    let imbalance = buckets
        .iter()
        .map(|b| b.len() as f64 / ideal)
        .fold(0.0, f64::max);
    let mut sorted = Vec::with_capacity(n);
    for mut b in buckets {
        // Runs arrive concatenated; a sort_unstable over the bucket is the
        // simulated merge (same comparisons asymptotically, simpler).
        b.sort_unstable();
        sorted.extend_from_slice(&b);
    }

    PsrsOutcome {
        sorted,
        counters,
        imbalance,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::coordinator::divide_native;
    use crate::workload;

    #[test]
    fn sorts_all_distributions() {
        for dist in Distribution::ALL {
            for p in [1, 7, 36, 144] {
                let data = workload::generate(dist, 30_000, 11);
                let out = psrs_sort(&data, p);
                let mut expect = data;
                expect.sort_unstable();
                assert_eq!(out.sorted, expect, "{dist:?} p={p}");
            }
        }
    }

    #[test]
    fn edge_cases() {
        assert!(psrs_sort(&[], 8).sorted.is_empty());
        assert_eq!(psrs_sort(&[3, 1, 2], 8).sorted, vec![1, 2, 3]);
        assert_eq!(psrs_sort(&[5; 100], 4).sorted, vec![5; 100]);
    }

    #[test]
    fn balanced_on_uniform_input() {
        let data = workload::random(100_000, 3);
        let out = psrs_sort(&data, 36);
        assert!(out.imbalance < 1.5, "{}", out.imbalance);
    }

    /// The ablation headline: on a heavily skewed distribution the
    /// paper's value-range step points collapse (most keys share one
    /// bucket) while PSRS splitters adapt.
    #[test]
    fn skew_robustness_vs_step_points() {
        // 95% of keys in a tiny band at the bottom of the range, 5%
        // spread to the top — value-range dividers put ~95% in bucket 0.
        let mut rng = crate::util::rng::Rng::new(9);
        let data: Vec<i32> = (0..100_000)
            .map(|_| {
                if rng.below(100) < 95 {
                    rng.range_i64(0, 1000) as i32
                } else {
                    rng.range_i64(0, 1 << 24) as i32
                }
            })
            .collect();
        let p = 36;
        let step = divide_native(&data, p).unwrap();
        let psrs = psrs_sort(&data, p);
        assert!(
            step.imbalance() > 10.0,
            "step-point should collapse: {}",
            step.imbalance()
        );
        assert!(
            psrs.imbalance < 2.0,
            "psrs should stay balanced: {}",
            psrs.imbalance
        );
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(psrs.sorted, expect);
    }
}
