//! Shared-memory fork/join Quick Sort — the multithreaded baseline of the
//! paper's refs [5–7]: no interconnection topology, just recursive
//! partition with the two halves forked down to a depth budget, then
//! sequential Quick Sort below it.  Forks run as tasks on the persistent
//! executor pool, so the baseline's measured time (like the OHHC path's)
//! contains no thread spawn/teardown.

use crate::runtime::Executor;
use crate::sort::{quicksort, SortCounters};

/// Sort in place with `2^fork_depth` maximum concurrent branches.
/// Returns summed counters from the sequential leaves.
pub fn shared_fork_sort(data: &mut [i32], fork_depth: u32) -> SortCounters {
    fn go(data: &mut [i32], depth: u32) -> SortCounters {
        if data.len() < 2 {
            return SortCounters::default();
        }
        if depth == 0 || data.len() < 4096 {
            return quicksort(data);
        }
        // Three-way partition around the middle element (out-of-place for
        // clarity — this is a baseline, and the buffer is reused by the
        // copy-back).  Equal keys settle in the middle and never recurse.
        let pivot = data[data.len() / 2];
        let mut less = Vec::with_capacity(data.len() / 2);
        let mut greater = Vec::with_capacity(data.len() / 2);
        let mut equal = 0usize;
        for &v in data.iter() {
            match v.cmp(&pivot) {
                std::cmp::Ordering::Less => less.push(v),
                std::cmp::Ordering::Equal => equal += 1,
                std::cmp::Ordering::Greater => greater.push(v),
            }
        }
        let (nl, ng) = (less.len(), greater.len());
        data[..nl].copy_from_slice(&less);
        data[nl..nl + equal].fill(pivot);
        data[nl + equal..].copy_from_slice(&greater);
        let (left, rest) = data.split_at_mut(nl);
        let (_, right) = rest.split_at_mut(equal);
        debug_assert_eq!(right.len(), ng);
        // Fork the left half onto the pool; recurse into the right half
        // on this thread (the scope's helping loop keeps a worker that
        // lands here from idling while it waits).
        let mut left_counters = SortCounters::default();
        let mut right_counters = SortCounters::default();
        {
            let left_slot = &mut left_counters;
            Executor::global().scope(|s| {
                s.submit(move || *left_slot = go(left, depth - 1));
                right_counters = go(right, depth - 1);
            });
        }
        left_counters + right_counters
    }
    go(data, fork_depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::sort::is_sorted;
    use crate::workload;

    #[test]
    fn sorts_all_distributions_at_depths() {
        for dist in Distribution::ALL {
            for depth in [0, 1, 3] {
                let mut v = workload::generate(dist, 50_000, 7);
                let mut expect = v.clone();
                expect.sort_unstable();
                shared_fork_sort(&mut v, depth);
                assert_eq!(v, expect, "{dist:?} depth={depth}");
            }
        }
    }

    #[test]
    fn handles_edge_cases() {
        for v in [vec![], vec![5], vec![2, 1], vec![3; 100]] {
            let mut s = v.clone();
            shared_fork_sort(&mut s, 2);
            assert!(is_sorted(&s));
            assert_eq!(s.len(), v.len());
        }
    }

    #[test]
    fn counters_come_from_leaves() {
        let mut v = workload::random(100_000, 3);
        let c = shared_fork_sort(&mut v, 2);
        assert!(c.comparisons > 0);
        assert!(is_sorted(&v));
    }
}
