//! Bitonic sort on a binary hypercube — the classic network-sort baseline
//! for interconnection-topology papers.
//!
//! `P = 2^k` processors each hold `n/P` keys.  The algorithm is the
//! block-wise bitonic network: for every stage `(k, j)` of the bitonic
//! schedule, processor `i` compare-splits its block with partner `i ⊕ j`
//! across a hypercube link, keeping the low half when it should ascend
//! and the high half otherwise.  Link traversals are counted so the
//! ablation bench can compare against the OHHC gather tree's
//! `2·(G·P − 1)`.

use crate::sort::quicksort;

/// Outcome of a hypercube bitonic sort.
#[derive(Debug)]
pub struct BitonicOutcome {
    /// The sorted keys.
    pub sorted: Vec<i32>,
    /// Hypercube link traversals performed (2 per compare-split: both
    /// partners ship their block).
    pub link_traversals: usize,
    /// Compare-split stages executed: `k(k+1)/2` for `P = 2^k`.
    pub stages: usize,
}

/// Sort on a `2^log_p`-processor hypercube.
pub fn hypercube_bitonic_sort(data: &[i32], log_p: u32) -> BitonicOutcome {
    let p = 1usize << log_p;
    let n = data.len();
    if n == 0 {
        return BitonicOutcome {
            sorted: Vec::new(),
            link_traversals: 0,
            stages: 0,
        };
    }

    // Distribute contiguous blocks, padded so every processor holds the
    // same count (sentinels sort to the top and are stripped at the end).
    let block = n.div_ceil(p);
    let mut blocks: Vec<Vec<i32>> = (0..p)
        .map(|i| {
            let lo = (i * block).min(n);
            let hi = ((i + 1) * block).min(n);
            let mut b = data[lo..hi].to_vec();
            b.resize(block, i32::MAX);
            b
        })
        .collect();

    // Local sorts seed the network.
    for b in &mut blocks {
        quicksort(b);
    }

    let mut traversals = 0usize;
    let mut stages = 0usize;
    let mut k = 2usize;
    while k <= p {
        let mut j = k / 2;
        while j >= 1 {
            stages += 1;
            for i in 0..p {
                let partner = i ^ j;
                if i < partner {
                    let ascending = i & k == 0;
                    compare_split(&mut blocks, i, partner, ascending);
                    traversals += 2; // both blocks cross the link
                }
            }
            j /= 2;
        }
        k *= 2;
    }

    let mut sorted: Vec<i32> = blocks.concat();
    sorted.truncate(n);
    BitonicOutcome {
        sorted,
        link_traversals: traversals,
        stages,
    }
}

/// Merge two sorted blocks; `lo_idx` keeps the low half when `ascending`.
fn compare_split(blocks: &mut [Vec<i32>], lo_idx: usize, hi_idx: usize, ascending: bool) {
    let block = blocks[lo_idx].len();
    let mut merged = Vec::with_capacity(2 * block);
    {
        let (a, b) = (&blocks[lo_idx], &blocks[hi_idx]);
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            if a[i] <= b[j] {
                merged.push(a[i]);
                i += 1;
            } else {
                merged.push(b[j]);
                j += 1;
            }
        }
        merged.extend_from_slice(&a[i..]);
        merged.extend_from_slice(&b[j..]);
    }
    if ascending {
        blocks[lo_idx].copy_from_slice(&merged[..block]);
        blocks[hi_idx].copy_from_slice(&merged[block..]);
    } else {
        blocks[lo_idx].copy_from_slice(&merged[block..]);
        blocks[hi_idx].copy_from_slice(&merged[..block]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::workload;

    #[test]
    fn sorts_all_distributions() {
        for dist in Distribution::ALL {
            for log_p in [0u32, 2, 5] {
                let data = workload::generate(dist, 20_000, 13);
                let out = hypercube_bitonic_sort(&data, log_p);
                let mut expect = data;
                expect.sort_unstable();
                assert_eq!(out.sorted, expect, "{dist:?} 2^{log_p}");
            }
        }
    }

    #[test]
    fn stage_count_is_k_choose_triangle() {
        // P = 2^k → k(k+1)/2 compare-split stages.
        let data = workload::random(4096, 1);
        for (log_p, expect) in [(1u32, 1usize), (2, 3), (3, 6), (4, 10)] {
            let out = hypercube_bitonic_sort(&data, log_p);
            assert_eq!(out.stages, expect, "2^{log_p}");
        }
    }

    #[test]
    fn traversal_count_scales_with_p_log2_p() {
        // Each stage moves every block across a link: P traversals/stage.
        let data = workload::random(4096, 2);
        let out = hypercube_bitonic_sort(&data, 4);
        assert_eq!(out.link_traversals, 16 * 10); // P · stages
    }

    #[test]
    fn uneven_and_tiny_inputs() {
        for n in [0usize, 1, 5, 1000] {
            let data = workload::random(n, n as u64 + 1);
            let out = hypercube_bitonic_sort(&data, 3);
            let mut expect = data;
            expect.sort_unstable();
            assert_eq!(out.sorted, expect, "n={n}");
        }
    }
}
