//! Distribution generators.
//!
//! The paper (§5) sweeps four integer-array types: random, sorted, reverse
//! sorted, and "local distribution".  All generators are deterministic in
//! the seed and produce non-negative keys (the paper's division procedure
//! divides raw values by the step point, which presumes non-negative data;
//! our kernels shift by `min` so signed inputs also work — see ref.py).

use crate::config::Distribution;
use crate::util::rng::Rng;

/// Upper bound on generated keys.  The paper reports key values "in the
/// millions"; `2^24` keeps `max - min` comfortably inside `i32` for the
/// SubDivider arithmetic while still exceeding any array length we sweep.
pub const KEY_RANGE: i32 = 1 << 24;

/// Dispatch on the full distribution menu (paper §5 + adversarial).
pub fn generate(dist: Distribution, n: usize, seed: u64) -> Vec<i32> {
    use super::adversarial;
    match dist {
        Distribution::Random => random(n, seed),
        Distribution::Sorted => sorted(n, seed),
        Distribution::ReverseSorted => reverse_sorted(n, seed),
        Distribution::Local => local_distribution(n, seed),
        Distribution::OrganPipe => adversarial::organ_pipe(n, seed),
        Distribution::FewUniques => adversarial::few_uniques(n, seed),
        Distribution::Zipf => adversarial::zipf(n, seed),
        Distribution::AntiPivot => adversarial::anti_pivot(n, seed),
    }
}

/// Uniform random keys in `[0, KEY_RANGE)`.
pub fn random(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(KEY_RANGE as u64) as i32).collect()
}

/// Ascending sorted keys (random multiset, then sorted).
pub fn sorted(n: usize, seed: u64) -> Vec<i32> {
    let mut v = random(n, seed);
    v.sort_unstable();
    v
}

/// Descending sorted keys — the paper's "reversed sorted".
pub fn reverse_sorted(n: usize, seed: u64) -> Vec<i32> {
    let mut v = sorted(n, seed);
    v.reverse();
    v
}

/// The paper's "local distribution": each position draws from a narrow
/// band centred on a ramp over the key range, so nearby positions hold
/// nearby values (locally clustered, globally unsorted).  This mimics
/// partially-ordered real-world inputs; like the random case it defeats
/// the step-point divider less than fully sorted data, which is why the
/// paper groups its results with `random` (Figs 6.7 / 6.11 / 6.15 / 6.19).
pub fn local_distribution(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let band = (KEY_RANGE as i64 / 16).max(1);
    (0..n)
        .map(|i| {
            let centre = (i as i64 * KEY_RANGE as i64) / n.max(1) as i64;
            let jitter = rng.range_i64(-band, band);
            (centre + jitter).clamp(0, (KEY_RANGE - 1) as i64) as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        for dist in Distribution::ALL {
            assert_eq!(generate(dist, 1000, 7), generate(dist, 1000, 7));
            assert_ne!(
                generate(dist, 1000, 7),
                generate(dist, 1000, 8),
                "{dist:?} ignores the seed"
            );
        }
    }

    #[test]
    fn sorted_is_sorted() {
        let v = sorted(10_000, 1);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn reverse_sorted_is_descending() {
        let v = reverse_sorted(10_000, 1);
        assert!(v.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn reverse_is_reverse_of_sorted() {
        let mut r = reverse_sorted(5_000, 42);
        r.reverse();
        assert_eq!(r, sorted(5_000, 42));
    }

    #[test]
    fn local_is_locally_clustered_but_not_sorted() {
        let v = local_distribution(100_000, 3);
        // Not globally sorted...
        assert!(v.windows(2).any(|w| w[0] > w[1]));
        // ...but a window's spread is far below the global range.
        let window = &v[50_000..50_100];
        let (mn, mx) = (
            *window.iter().min().unwrap(),
            *window.iter().max().unwrap(),
        );
        assert!(((mx - mn) as i64) < KEY_RANGE as i64 / 4);
    }

    #[test]
    fn keys_non_negative_and_bounded() {
        for dist in Distribution::ALL {
            let v = generate(dist, 10_000, 99);
            assert_eq!(v.len(), 10_000);
            assert!(v.iter().all(|&x| (0..KEY_RANGE).contains(&x)), "{dist:?}");
        }
    }

    #[test]
    fn random_spans_most_of_the_range() {
        let v = random(100_000, 5);
        let mx = *v.iter().max().unwrap();
        let mn = *v.iter().min().unwrap();
        assert!(mx > KEY_RANGE - KEY_RANGE / 50);
        assert!(mn < KEY_RANGE / 50);
    }
}
