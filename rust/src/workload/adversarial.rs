//! Adversarial and skewed workload generators — inputs built to break
//! the paper's fixed step-point divide rule.
//!
//! The paper's §5 menu (random / sorted / reverse / local) is friendly
//! to value-range bucketing: keys spread across the range, so the step
//! point lands near the quantiles.  These generators do the opposite —
//! mass concentrates (Zipf, few-uniques), order misleads (organ pipe),
//! or the range itself is weaponised (`anti_pivot`, which plants one
//! sentinel at the top of the key range so the computed step point
//! strands every other key in bucket 0).  All are deterministic in the
//! seed and keep keys in `[0, KEY_RANGE)` like the paper generators, so
//! they drop into every existing harness (campaign, loadgen, figures).

use super::gen::{sorted, KEY_RANGE};
use crate::util::rng::Rng;

/// Distinct values in a [`few_uniques`] workload.
pub const FEW_UNIQUE_VALUES: usize = 8;

/// Distinct ranks a [`zipf`] workload draws from.
pub const ZIPF_RANKS: usize = 1024;

/// Zipf exponent: `P(rank r) ∝ r^-s`.  Fixed (rather than a parameter)
/// so [`crate::config::Distribution`] stays `Copy + Eq + Hash` with a
/// static label; 1.2 is the classic "web popularity" ballpark.
pub const ZIPF_S: f64 = 1.2;

/// Width of the [`anti_pivot`] low band: every non-sentinel key is in
/// `[0, ANTI_PIVOT_BAND)` while one sentinel sits at `KEY_RANGE - 1`.
/// The fixed rule's step point `sub = (max - min) / P` then exceeds the
/// band for every `P <= 4095` — far past the paper's largest machine
/// (d=4, G=P: 2304 processors) — so all `n - 1` band keys land in
/// bucket 0 and the "parallel" sort degenerates to a sequential one.
pub const ANTI_PIVOT_BAND: i32 = 1 << 12;

/// Organ pipe: the sorted multiset laid out ascending then descending.
/// Locally monotone everywhere, yet the second half undoes any gain a
/// divider extracts from the first.
pub fn organ_pipe(n: usize, seed: u64) -> Vec<i32> {
    let s = sorted(n, seed);
    let mut rising: Vec<i32> = s.iter().copied().step_by(2).collect();
    let mut falling: Vec<i32> = s.iter().copied().skip(1).step_by(2).collect();
    falling.reverse();
    rising.append(&mut falling);
    rising
}

/// Only [`FEW_UNIQUE_VALUES`] distinct keys: buckets tie-break hard and
/// whole value classes land on single processors.
pub fn few_uniques(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let values: Vec<i32> = (0..FEW_UNIQUE_VALUES)
        .map(|_| rng.below(KEY_RANGE as u64) as i32)
        .collect();
    (0..n)
        .map(|_| values[rng.below(FEW_UNIQUE_VALUES as u64) as usize])
        .collect()
}

/// Zipf-distributed keys: rank `r` (of [`ZIPF_RANKS`]) drawn with
/// probability `∝ r^-s`, mapped onto evenly spaced key values.  The
/// head ranks soak up most of the mass, so value-range buckets starve.
pub fn zipf(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut cdf = Vec::with_capacity(ZIPF_RANKS);
    let mut total = 0.0f64;
    for r in 1..=ZIPF_RANKS {
        total += (r as f64).powf(-ZIPF_S);
        cdf.push(total);
    }
    let step = KEY_RANGE / ZIPF_RANKS as i32;
    (0..n)
        .map(|_| {
            let u = rng.f64() * total;
            let rank = cdf.partition_point(|&c| c < u).min(ZIPF_RANKS - 1);
            rank as i32 * step
        })
        .collect()
}

/// The attack workload: `n - 1` keys uniform in `[0, ANTI_PIVOT_BAND)`
/// plus one sentinel at `KEY_RANGE - 1` (at a seeded position).  Against
/// the fixed rule this maximises one bucket by construction — max bucket
/// is `n - 1` keys, an imbalance of ≈ `P` — while sampled splitters
/// shrug it off.
pub fn anti_pivot(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut v: Vec<i32> = (0..n)
        .map(|_| rng.below(ANTI_PIVOT_BAND as u64) as i32)
        .collect();
    if !v.is_empty() {
        let sentinel_at = rng.below(v.len() as u64) as usize;
        v[sentinel_at] = KEY_RANGE - 1;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Distribution;
    use crate::workload::generate;

    #[test]
    fn adversarial_deterministic_in_seed() {
        for dist in Distribution::ADVERSARIAL {
            assert_eq!(generate(dist, 1000, 7), generate(dist, 1000, 7));
            assert_ne!(
                generate(dist, 1000, 7),
                generate(dist, 1000, 8),
                "{dist:?} ignores the seed"
            );
        }
    }

    #[test]
    fn adversarial_keys_non_negative_and_bounded() {
        for dist in Distribution::ADVERSARIAL {
            let v = generate(dist, 10_000, 99);
            assert_eq!(v.len(), 10_000);
            assert!(v.iter().all(|&x| (0..KEY_RANGE).contains(&x)), "{dist:?}");
        }
    }

    #[test]
    fn organ_pipe_rises_then_falls() {
        let v = organ_pipe(10_000, 3);
        let peak = 10_000 / 2;
        assert!(v[..peak].windows(2).all(|w| w[0] <= w[1]));
        assert!(v[peak..].windows(2).all(|w| w[0] >= w[1]));
        // Same multiset as the sorted generator.
        let mut back = v;
        back.sort_unstable();
        assert_eq!(back, sorted(10_000, 3));
    }

    #[test]
    fn few_uniques_has_few_uniques() {
        let mut v = few_uniques(50_000, 5);
        v.sort_unstable();
        v.dedup();
        assert!(v.len() <= FEW_UNIQUE_VALUES, "{} distinct", v.len());
        assert!(v.len() > 1);
    }

    #[test]
    fn zipf_is_head_heavy() {
        let v = zipf(100_000, 11);
        let mut counts = std::collections::HashMap::new();
        for &k in &v {
            *counts.entry(k).or_insert(0usize) += 1;
        }
        let top = *counts.values().max().unwrap();
        // Rank 1 alone holds a large share of the mass under s = 1.2.
        assert!(top > v.len() / 10, "head only {top} of {}", v.len());
        assert!(counts.len() > 100, "tail too short: {}", counts.len());
    }

    #[test]
    fn anti_pivot_is_one_sentinel_plus_a_low_band() {
        let v = anti_pivot(20_000, 13);
        let sentinels = v.iter().filter(|&&k| k == KEY_RANGE - 1).count();
        assert_eq!(sentinels, 1);
        assert_eq!(
            v.iter().filter(|&&k| k < ANTI_PIVOT_BAND).count(),
            v.len() - 1
        );
    }

    #[test]
    fn anti_pivot_empty_input_is_fine() {
        assert!(anti_pivot(0, 1).is_empty());
    }
}
