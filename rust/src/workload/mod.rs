//! Input workload generation — the paper's four distributions (§5) at the
//! paper's sizes (10–60 MB of `i32`), the adversarial suite
//! ([`adversarial`]: organ pipe, few-uniques, Zipf, `anti_pivot`), and
//! the one shared distribution-name registry ([`parse`]) every CLI
//! surface resolves names through.  All generators are seeded for
//! reproducibility.

pub mod adversarial;
mod gen;

pub use gen::{generate, local_distribution, random, reverse_sorted, sorted, KEY_RANGE};

use crate::config::Distribution;
use crate::error::{Error, Result};

/// Every recognised distribution name, canonical label first — campaign
/// specs, loadgen, jobfile lines, and the CLI all resolve through this
/// one registry (and its error message), so a name accepted anywhere is
/// accepted everywhere.
pub fn parse(s: &str) -> Result<Distribution> {
    match s {
        "random" => Ok(Distribution::Random),
        "sorted" => Ok(Distribution::Sorted),
        "reverse_sorted" | "reversed" | "reverse" => Ok(Distribution::ReverseSorted),
        "local" => Ok(Distribution::Local),
        "organ_pipe" | "organpipe" => Ok(Distribution::OrganPipe),
        "few_uniques" | "few-uniques" => Ok(Distribution::FewUniques),
        "zipf" => Ok(Distribution::Zipf),
        "anti_pivot" | "antipivot" => Ok(Distribution::AntiPivot),
        other => Err(Error::Config(format!(
            "unknown distribution `{other}` (valid: random, sorted, reverse_sorted, \
             local, organ_pipe, few_uniques, zipf, anti_pivot)"
        ))),
    }
}

/// A generated workload plus its provenance, so figures can label series.
#[derive(Debug, Clone)]
pub struct Workload {
    /// The keys to sort.
    pub data: Vec<i32>,
    /// Which distribution produced it.
    pub distribution: Distribution,
    /// RNG seed used.
    pub seed: u64,
}

impl Workload {
    /// Generate `n` keys from `dist` with `seed`.
    pub fn new(dist: Distribution, n: usize, seed: u64) -> Self {
        Workload {
            data: generate(dist, n, seed),
            distribution: dist,
            seed,
        }
    }

    /// Size in (fractional) megabytes, as the paper's x-axes report.
    pub fn size_mb(&self) -> f64 {
        (self.data.len() * 4) as f64 / (1 << 20) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_label() {
        for dist in Distribution::ALL.iter().chain(&Distribution::ADVERSARIAL) {
            assert_eq!(parse(dist.label()).unwrap(), *dist, "{dist:?}");
        }
    }

    #[test]
    fn parse_accepts_the_historical_aliases() {
        assert_eq!(parse("reversed").unwrap(), Distribution::ReverseSorted);
        assert_eq!(parse("reverse").unwrap(), Distribution::ReverseSorted);
    }

    #[test]
    fn parse_error_lists_every_valid_name() {
        let msg = parse("nope").unwrap_err().to_string();
        for dist in Distribution::ALL.iter().chain(&Distribution::ADVERSARIAL) {
            assert!(msg.contains(dist.label()), "missing {} in {msg}", dist.label());
        }
        assert!(msg.contains("`nope`"));
    }
}
