//! Input workload generation — the paper's four distributions (§5) at the
//! paper's sizes (10–60 MB of `i32`), seeded for reproducibility.

mod gen;

pub use gen::{generate, local_distribution, random, reverse_sorted, sorted};

use crate::config::Distribution;

/// A generated workload plus its provenance, so figures can label series.
#[derive(Debug, Clone)]
pub struct Workload {
    /// The keys to sort.
    pub data: Vec<i32>,
    /// Which distribution produced it.
    pub distribution: Distribution,
    /// RNG seed used.
    pub seed: u64,
}

impl Workload {
    /// Generate `n` keys from `dist` with `seed`.
    pub fn new(dist: Distribution, n: usize, seed: u64) -> Self {
        Workload {
            data: generate(dist, n, seed),
            distribution: dist,
            seed,
        }
    }

    /// Size in (fractional) megabytes, as the paper's x-axes report.
    pub fn size_mb(&self) -> f64 {
        (self.data.len() * 4) as f64 / (1 << 20) as f64
    }
}
