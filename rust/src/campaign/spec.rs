//! Declarative sweep specifications and their grid expansion.

use std::collections::HashSet;
use std::path::Path;

use crate::config::{
    Backend, Construction, Distribution, DivideStrategy, ExperimentConfig, LinkModel,
};
use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::par;

/// One cell of the campaign grid — the cross product of every spec axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GridCell {
    /// OHHC dimension.
    pub dimension: u32,
    /// Construction rule.
    pub construction: Construction,
    /// Input distribution.
    pub distribution: Distribution,
    /// Keys to sort.
    pub elements: usize,
    /// Simulation backend.
    pub backend: Backend,
    /// How the divide picks bucket boundaries.
    pub strategy: DivideStrategy,
    /// Link failures injected into the run, in per-mille of the
    /// topology's links (0 = healthy network).
    pub fault_permille: u32,
    /// Cluster shards the input is scattered over (1 = single OHHC,
    /// the paper's setting; N > 1 splits the input with the sampled
    /// divider and sorts per-shard spans concurrently, charging the
    /// merge traffic at optical prices — see [`crate::cluster`]).
    pub shards: usize,
}

impl GridCell {
    /// Short identifier used in progress lines and error messages.
    pub fn label(&self) -> String {
        let mut base = format!(
            "d={}/{}/{}/{}k/{}",
            self.dimension,
            self.construction.label(),
            self.distribution.label(),
            self.elements / 1000,
            self.backend.label()
        );
        if self.strategy != DivideStrategy::PaperFixed {
            base.push('/');
            base.push_str(self.strategy.label());
        }
        if self.fault_permille > 0 {
            base = format!("{base}/f{}", self.fault_permille);
        }
        if self.shards > 1 {
            base = format!("{base}/x{}", self.shards);
        }
        base
    }

    /// The experiment configuration this cell runs with.
    pub fn config(&self, spec: &SweepSpec) -> ExperimentConfig {
        ExperimentConfig {
            dimension: self.dimension,
            construction: self.construction,
            distribution: self.distribution,
            elements: self.elements,
            seed: spec.seed,
            backend: self.backend,
            divide_strategy: self.strategy,
            link_model: spec.link_model,
            workers: spec.workers,
            repetitions: spec.repetitions,
            ..Default::default()
        }
    }
}

/// A declarative experiment sweep: the §6 grid axes plus run knobs.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// OHHC dimensions to sweep (paper: 1..=4).
    pub dimensions: Vec<u32>,
    /// Construction rules to sweep.
    pub constructions: Vec<Construction>,
    /// Input distributions to sweep.
    pub distributions: Vec<Distribution>,
    /// Array sizes in keys.
    pub sizes: Vec<usize>,
    /// Simulation backends to sweep.
    pub backends: Vec<Backend>,
    /// Divide strategies to sweep (`[PaperFixed]` = the paper's fixed
    /// step points only; add `sampling`/`adaptive` to measure the skew
    /// guardrail against adversarial distributions).
    pub strategies: Vec<DivideStrategy>,
    /// Link-failure rates to sweep, in per-mille of the topology's
    /// links (`[0]` = healthy only).  Nonzero rates build a seeded
    /// connectivity-preserving [`FaultSet`](crate::topology::FaultSet)
    /// per cell, so the report's degradation curve is structurally
    /// monotone in the rate.
    pub fault_permille: Vec<u32>,
    /// Shard counts to sweep (`[1]` = single OHHC only).
    pub shards: Vec<usize>,
    /// Workload seed (same seed ⇒ byte-identical DES outcomes).
    pub seed: u64,
    /// Timing repetitions per cell (median reported).
    pub repetitions: usize,
    /// Worker threads per run; `0` = one OS thread per processor.
    pub workers: usize,
    /// Concurrent campaign jobs (cells in flight at once).
    pub jobs: usize,
    /// DES link model.
    pub link_model: LinkModel,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            dimensions: vec![1, 2, 3, 4],
            constructions: Construction::ALL.to_vec(),
            distributions: Distribution::ALL.to_vec(),
            sizes: ExperimentConfig::paper_sizes(0.1),
            backends: vec![Backend::Threaded],
            strategies: vec![DivideStrategy::PaperFixed],
            fault_permille: vec![0],
            shards: vec![1],
            seed: 0x0511_C0DE,
            repetitions: 1,
            workers: par::available_workers(),
            jobs: 1,
            link_model: LinkModel::default(),
        }
    }
}

/// Split a comma list and parse every entry with `f`.
fn parse_list<T>(s: &str, what: &str, f: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    let items: Vec<T> = s
        .split(',')
        .map(str::trim)
        .filter(|e| !e.is_empty())
        .map(f)
        .collect::<Result<_>>()?;
    if items.is_empty() {
        return Err(Error::Config(format!("empty {what} list `{s}`")));
    }
    Ok(items)
}

impl SweepSpec {
    /// Parse a `--dims` style list (`1,2,4`).
    pub fn parse_dimensions(s: &str) -> Result<Vec<u32>> {
        parse_list(s, "dimension", |e| {
            e.parse()
                .map_err(|err| Error::Config(format!("bad dimension `{e}`: {err}")))
        })
    }

    /// Parse a `--constructions` style list (`full,half`).
    pub fn parse_constructions(s: &str) -> Result<Vec<Construction>> {
        parse_list(s, "construction", Construction::parse)
    }

    /// Parse a `--dists` style list (`random,sorted,reverse,local`).
    pub fn parse_distributions(s: &str) -> Result<Vec<Distribution>> {
        parse_list(s, "distribution", Distribution::parse)
    }

    /// Parse a `--sizes` style list of key counts (`1048576,4194304`).
    pub fn parse_sizes(s: &str) -> Result<Vec<usize>> {
        parse_list(s, "size", |e| {
            e.parse()
                .map_err(|err| Error::Config(format!("bad size `{e}`: {err}")))
        })
    }

    /// Parse a `--backends` style list (`threaded,des`).
    pub fn parse_backends(s: &str) -> Result<Vec<Backend>> {
        parse_list(s, "backend", Backend::parse)
    }

    /// Parse a `--divide-strategies` style list (`paper,sampling,adaptive`).
    pub fn parse_strategies(s: &str) -> Result<Vec<DivideStrategy>> {
        parse_list(s, "divide strategy", DivideStrategy::parse)
    }

    /// Parse a `--fault-rates` style list of per-mille link-failure
    /// rates (`0,100,400`).
    pub fn parse_fault_rates(s: &str) -> Result<Vec<u32>> {
        let rates: Vec<u32> = parse_list(s, "fault rate", |e| {
            e.parse()
                .map_err(|err| Error::Config(format!("bad fault rate `{e}`: {err}")))
        })?;
        if let Some(&bad) = rates.iter().find(|&&r| r > 1000) {
            return Err(Error::Config(format!(
                "fault rate is per-mille, must be <= 1000, got {bad}"
            )));
        }
        Ok(rates)
    }

    /// Parse a `--shards-list` style list of shard counts (`1,2,4,8`).
    pub fn parse_shards(s: &str) -> Result<Vec<usize>> {
        let shards: Vec<usize> = parse_list(s, "shard count", |e| {
            e.parse()
                .map_err(|err| Error::Config(format!("bad shard count `{e}`: {err}")))
        })?;
        if shards.contains(&0) {
            return Err(Error::Config("shard count must be >= 1".into()));
        }
        Ok(shards)
    }

    /// Load a spec from a `key = value` file.  List keys take comma lists;
    /// unknown keys are rejected (same contract as the experiment files).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut spec = SweepSpec::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let (key, value) = (key.trim(), value.trim());
            let bad = |e: Error| Error::Config(format!("line {}: {e}", lineno + 1));
            match key {
                "dimensions" => spec.dimensions = Self::parse_dimensions(value).map_err(bad)?,
                "constructions" => {
                    spec.constructions = Self::parse_constructions(value).map_err(bad)?
                }
                "distributions" => {
                    spec.distributions = Self::parse_distributions(value).map_err(bad)?
                }
                "sizes" => spec.sizes = Self::parse_sizes(value).map_err(bad)?,
                "backends" => spec.backends = Self::parse_backends(value).map_err(bad)?,
                "strategies" => spec.strategies = Self::parse_strategies(value).map_err(bad)?,
                "fault_rates" => {
                    spec.fault_permille = Self::parse_fault_rates(value).map_err(bad)?
                }
                "shards" => spec.shards = Self::parse_shards(value).map_err(bad)?,
                "seed" => {
                    spec.seed = value
                        .parse()
                        .map_err(|e| bad(Error::Config(format!("bad seed: {e}"))))?
                }
                "repetitions" => {
                    spec.repetitions = value
                        .parse()
                        .map_err(|e| bad(Error::Config(format!("bad repetitions: {e}"))))?
                }
                "workers" => {
                    spec.workers = value
                        .parse()
                        .map_err(|e| bad(Error::Config(format!("bad workers: {e}"))))?
                }
                "jobs" => {
                    spec.jobs = value
                        .parse()
                        .map_err(|e| bad(Error::Config(format!("bad jobs: {e}"))))?
                }
                other => {
                    return Err(Error::Config(format!(
                        "line {}: unknown key `{other}`",
                        lineno + 1
                    )))
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Reject empty axes before expansion.
    pub fn validate(&self) -> Result<()> {
        for (name, empty) in [
            ("dimensions", self.dimensions.is_empty()),
            ("constructions", self.constructions.is_empty()),
            ("distributions", self.distributions.is_empty()),
            ("sizes", self.sizes.is_empty()),
            ("backends", self.backends.is_empty()),
            ("divide strategies", self.strategies.is_empty()),
            ("fault rates", self.fault_permille.is_empty()),
            ("shard counts", self.shards.is_empty()),
        ] {
            if empty {
                return Err(Error::Config(format!("sweep spec has no {name}")));
            }
        }
        if let Some(&bad) = self.fault_permille.iter().find(|&&r| r > 1000) {
            return Err(Error::Config(format!(
                "fault rate is per-mille, must be <= 1000, got {bad}"
            )));
        }
        if self.shards.contains(&0) {
            return Err(Error::Config("shard count must be >= 1".into()));
        }
        Ok(())
    }

    /// Expand into the full grid: the cross product of every axis, in
    /// deterministic axis order, with duplicate cells (from repeated list
    /// entries) dropped on first occurrence.
    pub fn expand(&self) -> Result<Vec<GridCell>> {
        self.validate()?;
        let mut seen = HashSet::new();
        let mut cells = Vec::new();
        for &dimension in &self.dimensions {
            for &construction in &self.constructions {
                for &distribution in &self.distributions {
                    for &elements in &self.sizes {
                        for &backend in &self.backends {
                            for &strategy in &self.strategies {
                                for &fault_permille in &self.fault_permille {
                                    for &shards in &self.shards {
                                        let cell = GridCell {
                                            dimension,
                                            construction,
                                            distribution,
                                            elements,
                                            backend,
                                            strategy,
                                            fault_permille,
                                            shards,
                                        };
                                        if seen.insert(cell) {
                                            cells.push(cell);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(cells)
    }

    /// Echo of the spec for the aggregated report.
    pub fn to_json(&self) -> Json {
        Json::obj([
            (
                "backends",
                Json::arr(self.backends.iter().map(|b| Json::str(b.label()))),
            ),
            (
                "constructions",
                Json::arr(self.constructions.iter().map(|c| Json::str(c.label()))),
            ),
            (
                "dimensions",
                Json::arr(self.dimensions.iter().map(|&d| Json::int(d as usize))),
            ),
            (
                "distributions",
                Json::arr(self.distributions.iter().map(|d| Json::str(d.label()))),
            ),
            (
                "fault_rates",
                Json::arr(self.fault_permille.iter().map(|&r| Json::int(r as usize))),
            ),
            ("jobs", Json::int(self.jobs)),
            ("repetitions", Json::int(self.repetitions)),
            // String, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json numbers.
            ("seed", Json::str(self.seed.to_string())),
            (
                "shards",
                Json::arr(self.shards.iter().map(|&n| Json::int(n))),
            ),
            ("sizes", Json::arr(self.sizes.iter().map(|&n| Json::int(n)))),
            (
                "strategies",
                Json::arr(self.strategies.iter().map(|s| Json::str(s.label()))),
            ),
            ("workers", Json::int(self.workers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepSpec {
        SweepSpec {
            dimensions: vec![1, 2],
            constructions: vec![Construction::FullGroup],
            distributions: vec![Distribution::Random, Distribution::Sorted],
            sizes: vec![10_000, 20_000],
            backends: vec![Backend::Threaded, Backend::DiscreteEvent],
            ..Default::default()
        }
    }

    #[test]
    fn expansion_is_exhaustive_cross_product() {
        let cells = tiny().expand().unwrap();
        assert_eq!(cells.len(), 16); // 2 dims × 1 construction × 2 dists × 2 sizes × 2 backends
        // Every combination appears exactly once.
        let set: HashSet<GridCell> = cells.iter().copied().collect();
        assert_eq!(set.len(), cells.len());
        for d in [1, 2] {
            for dist in [Distribution::Random, Distribution::Sorted] {
                for n in [10_000, 20_000] {
                    for b in [Backend::Threaded, Backend::DiscreteEvent] {
                        let cell = GridCell {
                            dimension: d,
                            construction: Construction::FullGroup,
                            distribution: dist,
                            elements: n,
                            backend: b,
                            strategy: DivideStrategy::PaperFixed,
                            fault_permille: 0,
                            shards: 1,
                        };
                        assert!(set.contains(&cell), "{}", cell.label());
                    }
                }
            }
        }
    }

    #[test]
    fn expansion_deduplicates_repeated_entries() {
        let mut spec = tiny();
        spec.dimensions = vec![1, 2, 1, 2, 1];
        spec.sizes = vec![10_000, 10_000, 20_000];
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), tiny().expand().unwrap().len());
    }

    #[test]
    fn expansion_order_is_deterministic() {
        let a = tiny().expand().unwrap();
        let b = tiny().expand().unwrap();
        assert_eq!(a, b);
        // Axis order: dimension outermost, backend innermost.
        assert_eq!(a[0].backend, Backend::Threaded);
        assert_eq!(a[1].backend, Backend::DiscreteEvent);
        assert_eq!(a[0].dimension, 1);
        assert_eq!(a.last().unwrap().dimension, 2);
    }

    #[test]
    fn empty_axis_rejected() {
        let mut spec = tiny();
        spec.backends.clear();
        assert!(spec.expand().is_err());
        assert!(SweepSpec::parse_backends("").is_err());
    }

    #[test]
    fn fault_rate_axis_expands_innermost_and_labels_cells() {
        let mut spec = tiny();
        spec.fault_permille = vec![0, 150, 400];
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 16 * 3, "fault axis multiplies the grid");
        // Innermost: consecutive cells walk the fault axis first.
        assert_eq!(cells[0].fault_permille, 0);
        assert_eq!(cells[1].fault_permille, 150);
        assert_eq!(cells[2].fault_permille, 400);
        assert_eq!(cells[0].backend, cells[2].backend);
        assert!(!cells[0].label().contains("/f"), "healthy cells keep the old label");
        assert!(cells[2].label().ends_with("/f400"), "{}", cells[2].label());
        // Per-mille bounds enforced everywhere.
        assert!(SweepSpec::parse_fault_rates("0,100,400").is_ok());
        assert!(SweepSpec::parse_fault_rates("1500").is_err());
        spec.fault_permille = vec![2000];
        assert!(spec.expand().is_err());
        spec.fault_permille.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn strategy_axis_expands_between_backend_and_fault_rate() {
        let mut spec = tiny();
        spec.strategies = vec![
            DivideStrategy::PaperFixed,
            DivideStrategy::RegularSampling,
            DivideStrategy::Adaptive,
        ];
        spec.fault_permille = vec![0, 200];
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 16 * 3 * 2, "strategy axis multiplies the grid");
        // Fault rate stays innermost; strategy walks just outside it.
        assert_eq!(cells[0].strategy, DivideStrategy::PaperFixed);
        assert_eq!(cells[0].fault_permille, 0);
        assert_eq!(cells[1].fault_permille, 200);
        assert_eq!(cells[2].strategy, DivideStrategy::RegularSampling);
        assert_eq!(cells[0].backend, cells[4].backend);
        // Labels: the paper default keeps the old label, others tag it.
        assert!(!cells[0].label().contains("sampling"));
        assert!(cells[2].label().contains("/sampling"), "{}", cells[2].label());
        assert!(cells[5].label().ends_with("/adaptive/f200"), "{}", cells[5].label());
        // The strategy reaches the cell's experiment config.
        assert_eq!(cells[2].config(&spec).divide_strategy, DivideStrategy::RegularSampling);
        // Parser grammar + JSON echo.
        assert_eq!(
            SweepSpec::parse_strategies("paper, sampling,adaptive").unwrap(),
            DivideStrategy::ALL.to_vec()
        );
        assert!(SweepSpec::parse_strategies("paper,nope").is_err());
        let j = spec.to_json();
        assert_eq!(
            j.get("strategies").unwrap().as_arr().unwrap()[1].as_str(),
            Some("sampling")
        );
        spec.strategies.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn shards_axis_expands_innermost_and_labels_cells() {
        let mut spec = tiny();
        spec.shards = vec![1, 2, 4];
        spec.fault_permille = vec![0, 100];
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 16 * 3 * 2, "shards axis multiplies the grid");
        // Innermost: consecutive cells walk the shard axis first, then
        // the fault axis just outside it.
        assert_eq!(cells[0].shards, 1);
        assert_eq!(cells[1].shards, 2);
        assert_eq!(cells[2].shards, 4);
        assert_eq!(cells[0].fault_permille, 0);
        assert_eq!(cells[3].fault_permille, 100);
        assert_eq!(cells[3].shards, 1);
        assert_eq!(cells[0].backend, cells[5].backend);
        // Labels: single-shard cells keep the old label, sharded ones
        // get the /xN suffix after the fault tag.
        assert!(!cells[0].label().contains("/x"), "{}", cells[0].label());
        assert!(cells[2].label().ends_with("/x4"), "{}", cells[2].label());
        assert!(cells[5].label().ends_with("/f100/x4"), "{}", cells[5].label());
        // Parser grammar + validation.
        assert_eq!(SweepSpec::parse_shards("1, 2,4").unwrap(), [1, 2, 4]);
        assert!(SweepSpec::parse_shards("0").is_err());
        assert!(SweepSpec::parse_shards("2x").is_err());
        spec.shards = vec![0];
        assert!(spec.expand().is_err());
        spec.shards.clear();
        assert!(spec.expand().is_err());
        // JSON echo.
        let j = tiny().to_json();
        assert_eq!(
            j.get("shards").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(1)
        );
    }

    #[test]
    fn list_parsers_accept_cli_grammar() {
        assert_eq!(SweepSpec::parse_dimensions("1, 2,4").unwrap(), [1, 2, 4]);
        assert_eq!(
            SweepSpec::parse_constructions("full,half").unwrap(),
            Construction::ALL.to_vec()
        );
        let dists = SweepSpec::parse_distributions("random,sorted,reverse").unwrap();
        assert_eq!(dists[2], Distribution::ReverseSorted);
        assert_eq!(
            SweepSpec::parse_backends("threaded,des").unwrap(),
            Backend::ALL.to_vec()
        );
        assert!(SweepSpec::parse_sizes("12x").is_err());
        assert!(SweepSpec::parse_dimensions("1,x").is_err());
    }

    #[test]
    fn spec_file_round_trip() {
        let dir = std::env::temp_dir().join("ohhc_sweep_spec");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.conf");
        std::fs::write(
            &path,
            "# the acceptance grid\n\
             dimensions = 1,2\n\
             constructions = full\n\
             distributions = random, reverse\n\
             sizes = 1048576, 4194304\n\
             backends = threaded, des\n\
             fault_rates = 0, 250\n\
             seed = 42\n\
             jobs = 2\n",
        )
        .unwrap();
        let spec = SweepSpec::from_file(&path).unwrap();
        assert_eq!(spec.dimensions, vec![1, 2]);
        assert_eq!(spec.constructions, vec![Construction::FullGroup]);
        assert_eq!(spec.sizes, vec![1_048_576, 4_194_304]);
        assert_eq!(spec.backends, Backend::ALL.to_vec());
        assert_eq!(spec.fault_permille, vec![0, 250]);
        assert_eq!(spec.seed, 42);
        assert_eq!(spec.jobs, 2);
        assert_eq!(spec.expand().unwrap().len(), 2 * 2 * 2 * 2 * 2);

        std::fs::write(&path, "nope = 1\n").unwrap();
        assert!(SweepSpec::from_file(&path).is_err());
    }

    #[test]
    fn cell_config_inherits_spec_knobs() {
        let mut spec = tiny();
        spec.seed = 7;
        spec.workers = 3;
        spec.repetitions = 2;
        let cell = spec.expand().unwrap()[0];
        let cfg = cell.config(&spec);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.repetitions, 2);
        assert_eq!(cfg.dimension, cell.dimension);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn spec_json_echo_lists_axes() {
        let j = tiny().to_json();
        assert_eq!(j.get("dimensions").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("backends").unwrap().as_arr().unwrap()[1].as_str(),
            Some("des")
        );
        assert_eq!(
            j.get("fault_rates").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(0)
        );
    }
}
