//! Shared topology/plan cache keyed by `(dimension, construction)`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::Construction;
use crate::error::Result;
use crate::schedule::TopologyBundle;

/// Cache key: the only inputs a [`TopologyBundle`] depends on.
pub type TopologyKey = (u32, Construction);

/// Thread-safe cache of [`TopologyBundle`]s with build/hit accounting.
///
/// `get_or_build` holds the map lock across the build, so concurrent
/// requests for the same key serialize on one construction — a campaign
/// touching a `(dimension, construction)` pair any number of times builds
/// its topology and gather plans **exactly once** (asserted by the
/// campaign tests).
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: Mutex<HashMap<TopologyKey, Arc<TopologyBundle>>>,
    build_counts: Mutex<HashMap<TopologyKey, usize>>,
    hits: AtomicUsize,
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the bundle for a key, building it on first use.
    pub fn get_or_build(
        &self,
        dimension: u32,
        construction: Construction,
    ) -> Result<Arc<TopologyBundle>> {
        let key = (dimension, construction);
        let mut entries = self.entries.lock().unwrap();
        if let Some(bundle) = entries.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(bundle.clone());
        }
        let bundle = Arc::new(TopologyBundle::build(dimension, construction)?);
        *self.build_counts.lock().unwrap().entry(key).or_insert(0) += 1;
        entries.insert(key, bundle.clone());
        Ok(bundle)
    }

    /// Total topology builds performed.
    pub fn builds(&self) -> usize {
        self.build_counts.lock().unwrap().values().sum()
    }

    /// Build count per key, sorted (for at-most-once assertions).
    pub fn build_counts(&self) -> Vec<(TopologyKey, usize)> {
        let mut counts: Vec<_> = self
            .build_counts
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, &c)| (k, c))
            .collect();
        counts.sort_by_key(|&((d, c), _)| (d, c != Construction::FullGroup));
        counts
    }

    /// Cache hits served without building.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct keys currently cached.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_fetch_hits_and_shares() {
        let cache = PlanCache::new();
        assert!(cache.is_empty());
        let a = cache.get_or_build(1, Construction::FullGroup).unwrap();
        let b = cache.get_or_build(1, Construction::FullGroup).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "cache must share one bundle");
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_keys_build_independently() {
        let cache = PlanCache::new();
        cache.get_or_build(1, Construction::FullGroup).unwrap();
        cache.get_or_build(1, Construction::HalfGroup).unwrap();
        cache.get_or_build(2, Construction::FullGroup).unwrap();
        assert_eq!(cache.builds(), 3);
        assert_eq!(cache.hits(), 0);
        assert_eq!(
            cache.build_counts(),
            vec![
                ((1, Construction::FullGroup), 1),
                ((1, Construction::HalfGroup), 1),
                ((2, Construction::FullGroup), 1),
            ]
        );
    }

    #[test]
    fn concurrent_hammering_builds_each_key_once() {
        let cache = PlanCache::new();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..16 {
                        for c in Construction::ALL {
                            cache.get_or_build(1, c).unwrap();
                        }
                    }
                });
            }
        });
        assert_eq!(cache.builds(), 2, "per-key builds must not race");
        for (_, count) in cache.build_counts() {
            assert_eq!(count, 1);
        }
        assert_eq!(cache.hits(), 8 * 16 * 2 - 2);
    }

    #[test]
    fn invalid_key_errors_and_caches_nothing() {
        let cache = PlanCache::new();
        assert!(cache.get_or_build(0, Construction::FullGroup).is_err());
        assert_eq!(cache.builds(), 0);
        assert!(cache.is_empty());
    }
}
