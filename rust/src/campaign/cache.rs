//! Shared campaign caches: topology/plan bundles keyed by
//! `(dimension, construction)` and sequential baselines keyed by the
//! workload fingerprint `(distribution, elements, seed)`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::{Construction, Distribution};
use crate::coordinator::SeqBaseline;
use crate::error::Result;
use crate::schedule::TopologyBundle;
use crate::workload::Workload;

/// Cache key: the only inputs a [`TopologyBundle`] depends on.
pub type TopologyKey = (u32, Construction);

/// Thread-safe cache of [`TopologyBundle`]s with build/hit accounting.
///
/// `get_or_build` holds the map lock across the build, so concurrent
/// requests for the same key serialize on one construction — a campaign
/// touching a `(dimension, construction)` pair any number of times builds
/// its topology and gather plans **exactly once** (asserted by the
/// campaign tests).
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: Mutex<HashMap<TopologyKey, Arc<TopologyBundle>>>,
    build_counts: Mutex<HashMap<TopologyKey, usize>>,
    hits: AtomicUsize,
    leases: Arc<AtomicUsize>,
}

/// A leased [`TopologyBundle`]: shares the cached bundle and counts as
/// one outstanding lease until dropped.  Service-pool workers hold one
/// lease per `(dimension, construction)` they are actively sorting on,
/// so [`PlanCache::active_leases`] is a live view of how many workers
/// depend on cached topology state.
#[derive(Debug)]
pub struct BundleLease {
    bundle: Arc<TopologyBundle>,
    leases: Arc<AtomicUsize>,
}

impl BundleLease {
    /// The leased bundle.
    pub fn bundle(&self) -> &Arc<TopologyBundle> {
        &self.bundle
    }
}

impl std::ops::Deref for BundleLease {
    type Target = TopologyBundle;

    fn deref(&self) -> &TopologyBundle {
        &self.bundle
    }
}

impl Drop for BundleLease {
    fn drop(&mut self) {
        self.leases.fetch_sub(1, Ordering::Relaxed);
    }
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the bundle for a key, building it on first use.
    pub fn get_or_build(
        &self,
        dimension: u32,
        construction: Construction,
    ) -> Result<Arc<TopologyBundle>> {
        let key = (dimension, construction);
        let mut entries = self.entries.lock().unwrap();
        if let Some(bundle) = entries.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(bundle.clone());
        }
        let bundle = Arc::new(TopologyBundle::build(dimension, construction)?);
        *self.build_counts.lock().unwrap().entry(key).or_insert(0) += 1;
        entries.insert(key, bundle.clone());
        Ok(bundle)
    }

    /// Lease the bundle for a key (building it on first use).  The lease
    /// is counted until dropped — see [`PlanCache::active_leases`].
    pub fn lease(&self, dimension: u32, construction: Construction) -> Result<BundleLease> {
        let bundle = self.get_or_build(dimension, construction)?;
        self.leases.fetch_add(1, Ordering::Relaxed);
        Ok(BundleLease {
            bundle,
            leases: self.leases.clone(),
        })
    }

    /// Outstanding [`BundleLease`]s (not yet dropped).
    pub fn active_leases(&self) -> usize {
        self.leases.load(Ordering::Relaxed)
    }

    /// Total topology builds performed.
    pub fn builds(&self) -> usize {
        self.build_counts.lock().unwrap().values().sum()
    }

    /// Build count per key, sorted (for at-most-once assertions).
    pub fn build_counts(&self) -> Vec<(TopologyKey, usize)> {
        let mut counts: Vec<_> = self
            .build_counts
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, &c)| (k, c))
            .collect();
        counts.sort_by_key(|&((d, c), _)| (d, c != Construction::FullGroup));
        counts
    }

    /// Cache hits served without building.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct keys currently cached.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }
}

/// Cache key for one workload: `(distribution, elements, seed)` — the
/// only inputs workload generation and the sequential baseline depend on.
pub type WorkloadKey = (Distribution, usize, u64);

/// A generated workload together with its measured sequential baseline,
/// shared by every grid cell with the same [`WorkloadKey`].
#[derive(Debug)]
pub struct WorkloadBaseline {
    /// The generated keys.
    pub workload: Workload,
    /// Sequential quicksort time/counters/reference output on those keys.
    pub baseline: SeqBaseline,
}

/// Thread-safe memo of sequential baselines with [`PlanCache`]'s
/// at-most-once contract, but **without** cross-key serialization: the
/// map lock is held only long enough to fetch a per-key slot; the
/// expensive generate + quicksort runs under that slot's own once-lock,
/// so distinct workloads measure concurrently while same-key callers
/// block on exactly one measurement.
///
/// Entries live for the campaign's lifetime (each holds the workload
/// plus its sorted baseline); at paper scale that trades bounded memory
/// — the unique workloads of the grid — for skipping every redundant
/// clone + quicksort.  Drop the `Campaign` to release them.
#[derive(Debug, Default)]
pub struct BaselineCache {
    entries: Mutex<HashMap<WorkloadKey, Arc<OnceLock<Arc<WorkloadBaseline>>>>>,
    measures: AtomicUsize,
    hits: AtomicUsize,
}

impl BaselineCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the workload + baseline for a key, generating and measuring
    /// on first use.
    pub fn get_or_measure(
        &self,
        distribution: Distribution,
        elements: usize,
        seed: u64,
    ) -> Arc<WorkloadBaseline> {
        let key = (distribution, elements, seed);
        let slot = {
            let mut entries = self.entries.lock().unwrap();
            entries.entry(key).or_default().clone()
        };
        let mut measured = false;
        let wb = slot.get_or_init(|| {
            measured = true;
            self.measures.fetch_add(1, Ordering::Relaxed);
            let workload = Workload::new(distribution, elements, seed);
            let baseline = SeqBaseline::measure(&workload.data);
            Arc::new(WorkloadBaseline { workload, baseline })
        });
        if !measured {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        wb.clone()
    }

    /// Baseline measurements performed (unique workloads touched).
    pub fn measures(&self) -> usize {
        self.measures.load(Ordering::Relaxed)
    }

    /// Cache hits served without re-measuring.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct workloads currently cached.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing has been measured yet.
    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_fetch_hits_and_shares() {
        let cache = PlanCache::new();
        assert!(cache.is_empty());
        let a = cache.get_or_build(1, Construction::FullGroup).unwrap();
        let b = cache.get_or_build(1, Construction::FullGroup).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "cache must share one bundle");
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_keys_build_independently() {
        let cache = PlanCache::new();
        cache.get_or_build(1, Construction::FullGroup).unwrap();
        cache.get_or_build(1, Construction::HalfGroup).unwrap();
        cache.get_or_build(2, Construction::FullGroup).unwrap();
        assert_eq!(cache.builds(), 3);
        assert_eq!(cache.hits(), 0);
        assert_eq!(
            cache.build_counts(),
            vec![
                ((1, Construction::FullGroup), 1),
                ((1, Construction::HalfGroup), 1),
                ((2, Construction::FullGroup), 1),
            ]
        );
    }

    #[test]
    fn concurrent_hammering_builds_each_key_once() {
        let cache = PlanCache::new();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..16 {
                        for c in Construction::ALL {
                            cache.get_or_build(1, c).unwrap();
                        }
                    }
                });
            }
        });
        assert_eq!(cache.builds(), 2, "per-key builds must not race");
        for (_, count) in cache.build_counts() {
            assert_eq!(count, 1);
        }
        assert_eq!(cache.hits(), 8 * 16 * 2 - 2);
    }

    #[test]
    fn leases_share_the_cached_bundle_and_count_until_drop() {
        let cache = PlanCache::new();
        assert_eq!(cache.active_leases(), 0);
        let a = cache.lease(1, Construction::FullGroup).unwrap();
        let b = cache.lease(1, Construction::FullGroup).unwrap();
        assert!(Arc::ptr_eq(a.bundle(), b.bundle()), "leases must share");
        assert_eq!(cache.builds(), 1, "leasing must not rebuild");
        assert_eq!(cache.active_leases(), 2);
        assert_eq!(a.net.total_processors(), 36); // Deref surface
        drop(a);
        assert_eq!(cache.active_leases(), 1);
        drop(b);
        assert_eq!(cache.active_leases(), 0);
    }

    #[test]
    fn invalid_key_errors_and_caches_nothing() {
        let cache = PlanCache::new();
        assert!(cache.get_or_build(0, Construction::FullGroup).is_err());
        assert_eq!(cache.builds(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn baseline_measured_once_and_shared() {
        let cache = BaselineCache::new();
        assert!(cache.is_empty());
        let a = cache.get_or_measure(Distribution::Random, 5_000, 9);
        let b = cache.get_or_measure(Distribution::Random, 5_000, 9);
        assert!(Arc::ptr_eq(&a, &b), "cache must share one baseline");
        assert_eq!(cache.measures(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(a.workload.data.len(), 5_000);
        assert_eq!(a.baseline.sorted.len(), 5_000);
        assert!(crate::sort::is_sorted(&a.baseline.sorted));
        // A different fingerprint measures independently.
        cache.get_or_measure(Distribution::Sorted, 5_000, 9);
        cache.get_or_measure(Distribution::Random, 5_000, 10);
        assert_eq!(cache.measures(), 3);
    }

    #[test]
    fn concurrent_baseline_requests_measure_each_key_once() {
        let cache = BaselineCache::new();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..4 {
                        cache.get_or_measure(Distribution::ReverseSorted, 2_000, 3);
                        cache.get_or_measure(Distribution::Local, 2_000, 3);
                    }
                });
            }
        });
        assert_eq!(cache.measures(), 2, "per-key measures must not race");
        assert_eq!(cache.hits(), 8 * 4 * 2 - 2);
    }
}
