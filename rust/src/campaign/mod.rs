//! Experiment-campaign engine: the paper's whole §6 grid in one call.
//!
//! The evaluation section of the paper is a grid — OHHC dimensions ×
//! constructions × input distributions × array sizes — that the original
//! work ran cell by cell.  This module makes the grid a first-class
//! object:
//!
//! * [`SweepSpec`] — a declarative sweep specification (every axis plus
//!   seed / repetitions / worker knobs), parseable from CLI lists or a
//!   `key = value` file;
//! * [`SweepSpec::expand`] — deterministic, deduplicated expansion into
//!   [`GridCell`]s;
//! * [`PlanCache`] — per-`(dimension, construction)` cache of
//!   [`TopologyBundle`]s so repeated cells never rebuild a topology or its
//!   gather plans (the paper's 216-cell sweep needs only 8 builds);
//! * [`BaselineCache`] — per-workload memo of the generated input and its
//!   sequential baseline, so cells sharing a
//!   `(distribution, elements, seed)` fingerprint never re-clone or
//!   re-quicksort an identical workload;
//! * [`Campaign`] — executes the grid across a worker pool, tolerating
//!   per-cell failures, and aggregates everything into a
//!   [`CampaignReport`] with JSON / CSV emitters.
//!
//! The multi-mode grid methodology follows Fasha's comparative Quick Sort
//! study (arXiv:2109.01719); sweeping the topology dimension as a
//! first-class axis follows the OTIS-cube tradition (arXiv:1310.7376).
//!
//! [`TopologyBundle`]: crate::schedule::TopologyBundle

mod cache;
mod engine;
mod report;
mod spec;

pub use cache::{BaselineCache, BundleLease, PlanCache, WorkloadBaseline};
pub use engine::Campaign;
pub use report::{CampaignReport, CellReport, CellStatus, StrategySummary};
pub use spec::{GridCell, SweepSpec};
