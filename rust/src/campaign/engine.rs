//! The campaign executor: a worker pool over the expanded grid.
//!
//! Every cell runs through the typestate pipeline session (via
//! [`OhhcSorter`]'s adapter), so per-stage wall times flow into each
//! [`CellReport`] and the aggregated report's `stage_medians` without
//! any timing code here.

use std::time::{Duration, Instant};

use crate::campaign::cache::{BaselineCache, PlanCache, WorkloadBaseline};
use crate::campaign::report::{CampaignReport, CellReport};
use crate::campaign::spec::{GridCell, SweepSpec};
use crate::cluster::kway_merge;
use crate::config::Backend;
use crate::coordinator::{divide_sampled, OhhcSorter, SortReport};
use crate::error::{Error, Result};
use crate::pipeline::{Engine, Session, StageTrace};
use crate::schedule::TopologyBundle;
use crate::sim::InterShardModel;
use crate::sort::SortCounters;
use crate::topology::fault::FaultSet;
use crate::util::par;

/// Executes a [`SweepSpec`] at a concurrency of `spec.jobs`.
///
/// Cells run as tasks on the shared persistent executor
/// ([`crate::runtime::Executor::global`]) — the campaign owns no threads
/// of its own, so back-to-back sweeps (and sweeps racing service
/// traffic) share one warm pool instead of re-spawning per run.  As
/// before the executor (when concurrent cells' thread teams timeshared
/// the same cores), `jobs > 1` trades per-cell wall-clock fidelity for
/// sweep throughput: a cell's parallel waves can queue behind another
/// cell's tasks.  Timing-grade runs for the paper figures should keep
/// the default `jobs = 1`.  Jobs
/// pull cells work-steal style; every job resolves its topology and
/// gather plans through the shared [`PlanCache`], so each
/// `(dimension, construction)` pair is built at most once per campaign no
/// matter how many cells, repetitions, or concurrent jobs touch it.
/// Likewise every job resolves its workload and sequential baseline
/// through the shared [`BaselineCache`] — cells sharing a
/// `(distribution, elements, seed)` fingerprint never re-generate,
/// re-clone, or re-quicksort the identical input.  Per-cell errors are
/// captured in the report instead of aborting the sweep — one infeasible
/// cell must not cost hours of completed grid.
pub struct Campaign {
    spec: SweepSpec,
    cache: PlanCache,
    baselines: BaselineCache,
}

impl Campaign {
    /// New campaign over a spec.
    pub fn new(spec: SweepSpec) -> Self {
        Campaign {
            spec,
            cache: PlanCache::new(),
            baselines: BaselineCache::new(),
        }
    }

    /// The spec this campaign runs.
    pub fn spec(&self) -> &SweepSpec {
        &self.spec
    }

    /// The shared topology/plan cache (build/hit accounting for tests and
    /// report aggregation).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// The shared workload/baseline cache (measure/hit accounting).
    pub fn baselines(&self) -> &BaselineCache {
        &self.baselines
    }

    /// Run the whole grid; cells report silently.
    pub fn run(&self) -> Result<CampaignReport> {
        self.run_with(|_| {})
    }

    /// Run the whole grid, invoking `progress` as each cell finishes
    /// (from worker threads — keep it cheap and thread-safe).
    pub fn run_with(&self, progress: impl Fn(&CellReport) + Sync) -> Result<CampaignReport> {
        let t0 = Instant::now();
        let cells = self.spec.expand()?;
        let jobs = self.spec.jobs.max(1);
        let reports = par::par_map(cells, jobs, |cell| {
            let report = self.run_cell(&cell);
            progress(&report);
            report
        });
        Ok(CampaignReport {
            spec: self.spec.clone(),
            cells: reports,
            topology_builds: self.cache.builds(),
            cache_hits: self.cache.hits(),
            baseline_measures: self.baselines.measures(),
            baseline_hits: self.baselines.hits(),
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run one cell, mapping infeasibility to `Skipped` and runtime
    /// errors to `Failed`.
    fn run_cell(&self, cell: &GridCell) -> CellReport {
        let cfg = cell.config(&self.spec);
        if let Err(e) = cfg.validate() {
            return CellReport::skipped(cell, e.to_string());
        }
        match self.execute(cell) {
            Ok(runs) => CellReport::from_runs(cell, &runs),
            Err(e) => CellReport::failed(cell, e.to_string()),
        }
    }

    fn execute(&self, cell: &GridCell) -> Result<Vec<SortReport>> {
        let cfg = cell.config(&self.spec);
        let bundle = self.cache.get_or_build(cell.dimension, cell.construction)?;
        // Seeded link faults, nested across the axis: every link failed
        // at rate r is also failed at every r' > r, so degradation is
        // monotone along the curve by construction.
        let faults = (cell.fault_permille > 0)
            .then(|| FaultSet::seeded_links(bundle.net.graph(), cell.fault_permille, self.spec.seed));
        let wb = self
            .baselines
            .get_or_measure(cell.distribution, cell.elements, self.spec.seed);
        if cell.shards > 1 {
            return (0..self.spec.repetitions.max(1))
                .map(|_| self.run_sharded(cell, &bundle, faults.as_ref(), &wb))
                .collect();
        }
        let mut sorter = OhhcSorter::with_bundle(&cfg, bundle)?;
        if let Some(f) = faults {
            sorter = sorter.with_faults(f);
        }
        (0..self.spec.repetitions.max(1))
            .map(|_| sorter.run_on_with_baseline(&wb.workload, &wb.baseline))
            .collect()
    }

    /// One repetition of a sharded cell: the cluster's scatter/merge
    /// path in miniature.  The splitter divide cuts the workload into
    /// `cell.shards` spans, every span runs the full pipeline session on
    /// its own simulated OHHC (all shards lease the same
    /// `(dimension, construction)` bundle — a cluster of identical
    /// networks), and a k-way merge reassembles the output, which must
    /// equal the memoized sequential baseline.  The synthesized
    /// [`SortReport`] counts `shards × per-OHHC` processors; on the DES
    /// backend virtual completion is the slowest shard plus the
    /// inter-shard optical transfer charge, so shard scaling is priced,
    /// not free.
    fn run_sharded(
        &self,
        cell: &GridCell,
        bundle: &TopologyBundle,
        faults: Option<&FaultSet>,
        wb: &WorkloadBaseline,
    ) -> Result<SortReport> {
        let cfg = cell.config(&self.spec);
        let engine = match cell.backend {
            Backend::Threaded if cfg.workers == 0 => Engine::DirectThreads,
            Backend::Threaded => Engine::Pooled,
            Backend::DiscreteEvent => Engine::DiscreteEvent {
                link: cfg.link_model,
            },
        };
        let strategy = cfg.divide_strategy;

        let t0 = Instant::now();
        let divided = divide_sampled(&wb.workload.data, cell.shards)?;
        let divide_time = t0.elapsed();
        let imbalance = divided.imbalance();
        let sizes: Vec<usize> = (0..cell.shards).map(|s| divided.buckets.size(s)).collect();

        let t1 = Instant::now();
        let outcomes = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cell.shards)
                .map(|s| {
                    let span = divided.buckets.bucket(s);
                    scope.spawn(move || {
                        if span.is_empty() {
                            return Ok(None);
                        }
                        let mut session = Session::single(&bundle.net, &bundle.plans, span)
                            .with_divide_strategy(strategy)
                            .with_engine(engine);
                        if let Some(f) = faults {
                            session = session.with_faults(f);
                        }
                        session.divide()?.local_sort()?.gather().map(Some)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .map_err(|_| Error::Invariant("sharded span sort panicked".into()))?
                })
                .collect::<Result<Vec<_>>>()
        })?;
        let shard_wall = t1.elapsed();

        let t2 = Instant::now();
        let parts: Vec<&[i32]> = outcomes
            .iter()
            .flatten()
            .map(|o| o.sorted.as_slice())
            .collect();
        let merged = kway_merge(&parts);
        let merge_wall = t2.elapsed();
        if merged != wb.baseline.sorted {
            return Err(Error::Invariant(
                "sharded merge differs from sequential baseline".into(),
            ));
        }

        // Fold the per-shard outcomes: counters sum, per-stage times take
        // the slowest shard (the concurrent critical path), DES virtual
        // completion takes the slowest shard plus the transfer charge.
        let mut counters = SortCounters::default();
        let mut stage_times = StageTrace {
            divide: divide_time,
            ..StageTrace::default()
        };
        let mut skew_redivides = 0u32;
        let mut detours = 0usize;
        let mut des_completion = 0.0f64;
        let mut des_steps = (0usize, 0usize);
        let mut any_des = false;
        for o in outcomes.iter().flatten() {
            counters += o.counters;
            skew_redivides += o.skew_redivides;
            stage_times.divide += o.trace.divide;
            stage_times.scatter = stage_times.scatter.max(o.trace.scatter);
            stage_times.local_sort = stage_times.local_sort.max(o.trace.local_sort);
            stage_times.gather = stage_times.gather.max(o.trace.gather);
            if let Some(d) = &o.des {
                any_des = true;
                des_completion = des_completion.max(d.completion_ns);
                let (e, op) = d.trace.steps();
                des_steps.0 += e;
                des_steps.1 += op;
                detours += d.detours;
            } else {
                detours += o.detours;
            }
        }
        stage_times.gather += merge_wall;

        // All spans are scattered from one coordinator, so every span
        // except shard 0's crosses the optical boundary both ways.
        let transfer = InterShardModel::new(cfg.link_model).split_transfer(0, &sizes);
        let des_total = des_completion + transfer.transfer_ns;
        let parallel_time = if any_des {
            divide_time + Duration::from_nanos(des_total as u64) + merge_wall
        } else {
            divide_time + shard_wall + merge_wall
        };

        let processors = bundle.net.total_processors() * cell.shards;
        let ts = wb.baseline.time.as_secs_f64();
        let tp = parallel_time.as_secs_f64();
        Ok(SortReport {
            elements: wb.workload.data.len(),
            processors,
            sequential_time: wb.baseline.time,
            parallel_time,
            divide_time,
            stage_times,
            counters,
            sequential_counters: wb.baseline.counters,
            imbalance,
            skew_redivides,
            des_completion_ns: any_des.then_some(des_total),
            des_steps: any_des.then_some(des_steps),
            detours,
            des_trace: None,
            speedup: ts / tp,
            speedup_pct: (ts - tp) / ts * 100.0,
            efficiency: ts / (processors as f64 * tp),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, Construction, Distribution};

    /// A grid small enough for unit tests but wide enough to exercise the
    /// cache, both backends, and skip handling.
    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            dimensions: vec![1],
            constructions: Construction::ALL.to_vec(),
            distributions: vec![Distribution::Random, Distribution::Sorted],
            sizes: vec![12_000],
            backends: vec![Backend::Threaded, Backend::DiscreteEvent],
            workers: 4,
            jobs: 4,
            ..Default::default()
        }
    }

    #[test]
    fn campaign_covers_every_cell() {
        let campaign = Campaign::new(tiny_spec());
        let report = campaign.run().unwrap();
        assert_eq!(report.cells.len(), 8);
        assert_eq!(report.completed(), 8);
        assert_eq!(report.failed(), 0);
        for cell in &report.cells {
            assert!(cell.counters.comparisons > 0, "{}", cell.key());
            assert!(cell.seq_secs > 0.0 && cell.par_secs > 0.0);
            // Stage medians flow out of the session trace on every
            // backend (DES stages are host wall times).
            assert!(cell.sort_secs > 0.0, "{}", cell.key());
            assert!(cell.divide_secs >= cell.scatter_secs, "{}", cell.key());
        }
        assert!(report.stage_medians().unwrap().2 > 0.0);
        // DES cells carry virtual-time outcomes, threaded cells do not.
        for cell in &report.cells {
            match cell.backend {
                Backend::DiscreteEvent => assert!(cell.des_completion_ns.is_some()),
                Backend::Threaded => assert!(cell.des_completion_ns.is_none()),
            }
        }
    }

    #[test]
    fn topologies_build_at_most_once_under_concurrency() {
        let campaign = Campaign::new(tiny_spec());
        let report = campaign.run().unwrap();
        // 8 cells share 2 (dimension, construction) pairs.
        assert_eq!(report.topology_builds, 2);
        for (key, count) in campaign.cache().build_counts() {
            assert_eq!(count, 1, "{key:?} rebuilt");
        }
        assert_eq!(report.cache_hits, 8 - 2);
    }

    #[test]
    fn infeasible_cells_are_skipped_not_fatal() {
        let mut spec = tiny_spec();
        spec.dimensions = vec![1, 4]; // d=4 G=P needs 2304 keys minimum
        spec.constructions = vec![Construction::FullGroup];
        spec.sizes = vec![2_000];
        spec.distributions = vec![Distribution::Random];
        spec.backends = vec![Backend::Threaded];
        let report = Campaign::new(spec).run().unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.completed(), 1);
        assert_eq!(report.skipped(), 1);
        for cell in report.cells.iter().filter(|c| !c.status.is_completed()) {
            assert_eq!(cell.dimension, 4);
            assert!(cell.status.detail().unwrap().contains("processors"));
        }
        // Skipped cells never build topologies.
        assert_eq!(report.topology_builds, 1);
    }

    #[test]
    fn sequential_baseline_measured_once_per_workload() {
        // tiny_spec: 8 cells over 2 distributions × 1 size × 1 seed →
        // exactly 2 unique workloads, each measured once.
        let campaign = Campaign::new(tiny_spec());
        let report = campaign.run().unwrap();
        assert_eq!(campaign.baselines().measures(), 2);
        assert_eq!(campaign.baselines().hits(), 8 - 2);
        assert_eq!(report.baseline_measures, 2);
        assert_eq!(report.baseline_hits, 6);
        // The memoized baseline feeds every cell a real sequential time.
        for cell in &report.cells {
            assert!(cell.seq_secs > 0.0, "{}", cell.key());
        }
    }

    #[test]
    fn progress_fires_once_per_cell() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let campaign = Campaign::new(tiny_spec());
        let report = campaign
            .run_with(|_| {
                calls.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(calls.load(Ordering::Relaxed), report.cells.len());
    }

    #[test]
    fn fault_axis_degrades_des_completion_monotonically() {
        let mut spec = tiny_spec();
        spec.constructions = vec![Construction::FullGroup];
        spec.distributions = vec![Distribution::Random];
        spec.backends = vec![Backend::DiscreteEvent];
        spec.fault_permille = vec![0, 150, 400];
        spec.jobs = 1;
        let report = Campaign::new(spec).run().unwrap();
        assert_eq!(report.cells.len(), 3);
        assert_eq!(report.completed(), 3);
        // Nested seeded fault sets: virtual completion time can only
        // grow with the failure rate, and detours appear as soon as a
        // tree edge is cut.
        let mut cells = report.cells.clone();
        cells.sort_by_key(|c| c.fault_permille);
        let ns: Vec<f64> = cells.iter().map(|c| c.des_completion_ns.unwrap()).collect();
        assert!(ns[0] <= ns[1] && ns[1] <= ns[2], "{ns:?}");
        assert_eq!(cells[0].detours, 0);
        assert!(cells[2].detours > 0);
        // The aggregated report folds the axis into a degradation curve.
        let curve = report.per_fault_rate();
        assert_eq!(curve.len(), 3);
        assert_eq!(curve[0].0, 0);
        assert_eq!(curve[2].0, 400);
    }

    #[test]
    fn sharded_cells_split_merge_and_scale_the_processor_count() {
        let mut spec = tiny_spec();
        spec.constructions = vec![Construction::FullGroup];
        spec.distributions = vec![Distribution::Random];
        spec.shards = vec![1, 4];
        spec.jobs = 1;
        let report = Campaign::new(spec).run().unwrap();
        // 1 construction × 1 distribution × 1 size × 2 backends × 2 shard counts.
        assert_eq!(report.cells.len(), 4);
        assert_eq!(report.completed(), 4);
        for cell in &report.cells {
            // Sharded or not, the merged output was verified against the
            // same memoized sequential baseline, so a completed cell is a
            // correct sort with real work behind it.
            assert!(cell.counters.comparisons > 0, "{}", cell.key());
            assert!(cell.speedup > 0.0, "{}", cell.key());
            if cell.shards == 4 {
                assert!(cell.key().ends_with("/x4"), "{}", cell.key());
                assert_eq!(cell.processors, 4 * 36);
            } else {
                assert!(!cell.key().contains("/x"), "{}", cell.key());
                assert_eq!(cell.processors, 36);
            }
        }
        // The sharded DES completion prices the inter-shard transfer on
        // top of the slowest shard, so it can only exceed a single
        // shard's virtual time for the same workload.
        let des = |shards: usize| {
            report
                .cells
                .iter()
                .find(|c| c.backend == Backend::DiscreteEvent && c.shards == shards)
                .unwrap()
                .des_completion_ns
                .unwrap()
        };
        assert!(des(4) > 0.0);
        // The aggregated report folds the axis into the scaling table.
        let table = report.per_shard_count();
        assert_eq!(table.len(), 2);
        assert_eq!(table[0].0, 1);
        assert_eq!(table[1].0, 4);
    }

    #[test]
    fn repetitions_fold_to_medians() {
        let mut spec = tiny_spec();
        spec.repetitions = 3;
        spec.distributions = vec![Distribution::Random];
        spec.backends = vec![Backend::Threaded];
        spec.constructions = vec![Construction::FullGroup];
        let report = Campaign::new(spec).run().unwrap();
        assert_eq!(report.cells.len(), 1);
        assert_eq!(report.cells[0].repetitions, 3);
        assert!(report.cells[0].speedup > 0.0);
    }
}
