//! Per-cell and aggregated campaign reports.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::campaign::spec::{GridCell, SweepSpec};
use crate::config::{Backend, Construction, Distribution, DivideStrategy};
use crate::coordinator::SortReport;
use crate::error::Result;
use crate::metrics::{write_csv_rows, Histogram, Summary};
use crate::sort::SortCounters;
use crate::util::json::Json;

/// How one grid cell ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CellStatus {
    /// Ran and verified.
    Completed,
    /// Infeasible for this spec (e.g. fewer keys than processors).
    Skipped(String),
    /// Ran and errored.
    Failed(String),
}

impl CellStatus {
    /// Short status label for tables and CSV.
    pub fn label(&self) -> &'static str {
        match self {
            CellStatus::Completed => "completed",
            CellStatus::Skipped(_) => "skipped",
            CellStatus::Failed(_) => "failed",
        }
    }

    /// Reason text for skipped/failed cells.
    pub fn detail(&self) -> Option<&str> {
        match self {
            CellStatus::Completed => None,
            CellStatus::Skipped(r) | CellStatus::Failed(r) => Some(r),
        }
    }

    /// Did the cell produce measurements?
    pub fn is_completed(&self) -> bool {
        *self == CellStatus::Completed
    }
}

/// Everything one grid cell contributes to the aggregated report.
#[derive(Debug, Clone)]
pub struct CellReport {
    /// OHHC dimension.
    pub dimension: u32,
    /// Construction rule.
    pub construction: Construction,
    /// Input distribution.
    pub distribution: Distribution,
    /// Simulation backend.
    pub backend: Backend,
    /// Divide strategy the cell ran with.
    pub strategy: DivideStrategy,
    /// Keys sorted.
    pub elements: usize,
    /// Injected link-failure rate (per-mille; 0 = healthy).
    pub fault_permille: u32,
    /// Cluster shards the input was scattered over (1 = single OHHC).
    pub shards: usize,
    /// Outcome.
    pub status: CellStatus,
    /// Total processors simulated (0 when never built).
    pub processors: usize,
    /// Timing repetitions behind the medians.
    pub repetitions: usize,
    /// Median sequential wall time (s).
    pub seq_secs: f64,
    /// Median parallel wall time (s).
    pub par_secs: f64,
    /// Median divide-phase wall time (s) — classification + scatter.
    pub divide_secs: f64,
    /// Median scatter-stage wall time (s), from the session trace.
    pub scatter_secs: f64,
    /// Median local-sort-stage wall time (s), from the session trace.
    pub sort_secs: f64,
    /// Median gather-stage wall time (s), from the session trace.
    pub gather_secs: f64,
    /// Relative speedup `T_s / T_p` from the medians.
    pub speedup: f64,
    /// The paper's percentage speedup presentation.
    pub speedup_pct: f64,
    /// Efficiency from the medians.
    pub efficiency: f64,
    /// Division load-imbalance factor.
    pub imbalance: f64,
    /// Skew-guardrail re-divides the divide performed (adaptive only).
    pub skew_redivides: u32,
    /// Summed local-sort counters.
    pub counters: SortCounters,
    /// DES virtual completion (ns), DES backend only.
    pub des_completion_ns: Option<f64>,
    /// DES communication steps `(electrical, optical)`.
    pub des_steps: Option<(usize, usize)>,
    /// Detours taken around injected faults (0 on a healthy network).
    pub detours: usize,
}

impl CellReport {
    fn empty(cell: &GridCell, status: CellStatus) -> Self {
        CellReport {
            dimension: cell.dimension,
            construction: cell.construction,
            distribution: cell.distribution,
            backend: cell.backend,
            strategy: cell.strategy,
            elements: cell.elements,
            fault_permille: cell.fault_permille,
            shards: cell.shards,
            status,
            processors: 0,
            repetitions: 0,
            seq_secs: 0.0,
            par_secs: 0.0,
            divide_secs: 0.0,
            scatter_secs: 0.0,
            sort_secs: 0.0,
            gather_secs: 0.0,
            speedup: 0.0,
            speedup_pct: 0.0,
            efficiency: 0.0,
            imbalance: 0.0,
            skew_redivides: 0,
            counters: SortCounters::default(),
            des_completion_ns: None,
            des_steps: None,
            detours: 0,
        }
    }

    /// A cell the spec ruled out before running.
    pub fn skipped(cell: &GridCell, reason: String) -> Self {
        Self::empty(cell, CellStatus::Skipped(reason))
    }

    /// A cell that errored mid-run.
    pub fn failed(cell: &GridCell, reason: String) -> Self {
        Self::empty(cell, CellStatus::Failed(reason))
    }

    /// Fold one or more repeated runs of a cell into its report (medians
    /// over wall-clock quantities; counters and DES outcomes are
    /// deterministic per seed, so the first run speaks for all).
    pub fn from_runs(cell: &GridCell, runs: &[SortReport]) -> Self {
        assert!(!runs.is_empty(), "a completed cell has at least one run");
        let med = |f: &dyn Fn(&SortReport) -> f64| {
            Summary::of(&runs.iter().map(f).collect::<Vec<f64>>()).median
        };
        let seq_secs = med(&|r| r.sequential_time.as_secs_f64());
        let par_secs = med(&|r| r.parallel_time.as_secs_f64());
        let divide_secs = med(&|r| r.divide_time.as_secs_f64());
        let scatter_secs = med(&|r| r.stage_times.scatter.as_secs_f64());
        let sort_secs = med(&|r| r.stage_times.local_sort.as_secs_f64());
        let gather_secs = med(&|r| r.stage_times.gather.as_secs_f64());
        let first = &runs[0];
        CellReport {
            dimension: cell.dimension,
            construction: cell.construction,
            distribution: cell.distribution,
            backend: cell.backend,
            strategy: cell.strategy,
            elements: cell.elements,
            fault_permille: cell.fault_permille,
            shards: cell.shards,
            status: CellStatus::Completed,
            processors: first.processors,
            repetitions: runs.len(),
            seq_secs,
            par_secs,
            divide_secs,
            scatter_secs,
            sort_secs,
            gather_secs,
            speedup: seq_secs / par_secs,
            speedup_pct: (seq_secs - par_secs) / seq_secs * 100.0,
            efficiency: seq_secs / (first.processors as f64 * par_secs),
            imbalance: first.imbalance,
            skew_redivides: first.skew_redivides,
            counters: first.counters,
            des_completion_ns: first.des_completion_ns,
            des_steps: first.des_steps,
            detours: first.detours,
        }
    }

    /// Grid coordinates as a stable string key.
    pub fn key(&self) -> String {
        let mut base = format!(
            "d={}/{}/{}/{}/{}",
            self.dimension,
            self.construction.label(),
            self.distribution.label(),
            self.elements,
            self.backend.label()
        );
        if self.strategy != DivideStrategy::PaperFixed {
            base.push('/');
            base.push_str(self.strategy.label());
        }
        if self.fault_permille > 0 {
            base = format!("{base}/f{}", self.fault_permille);
        }
        if self.shards > 1 {
            base = format!("{base}/x{}", self.shards);
        }
        base
    }

    /// The deterministic fields shared by [`CellReport::fingerprint`] and
    /// [`CellReport::to_json`] — wall-clock quantities excluded.
    fn deterministic_fields(&self) -> BTreeMap<String, Json> {
        let counters = Json::obj([
            ("comparisons", Json::int(self.counters.comparisons as usize)),
            ("iterations", Json::int(self.counters.iterations as usize)),
            ("max_depth", Json::int(self.counters.max_depth as usize)),
            ("recursions", Json::int(self.counters.recursion_calls as usize)),
            ("swaps", Json::int(self.counters.swaps as usize)),
        ]);
        let obj = Json::obj([
            ("backend", Json::str(self.backend.label())),
            ("construction", Json::str(self.construction.label())),
            ("counters", counters),
            (
                "des_completion_ns",
                self.des_completion_ns.map_or(Json::Null, Json::num),
            ),
            (
                "des_steps",
                self.des_steps.map_or(Json::Null, |(e, o)| {
                    Json::arr([Json::int(e), Json::int(o)])
                }),
            ),
            ("detours", Json::int(self.detours)),
            ("dimension", Json::int(self.dimension as usize)),
            ("distribution", Json::str(self.distribution.label())),
            ("elements", Json::int(self.elements)),
            ("fault_permille", Json::int(self.fault_permille as usize)),
            ("imbalance", Json::num(self.imbalance)),
            ("processors", Json::int(self.processors)),
            ("shards", Json::int(self.shards)),
            ("skew_redivides", Json::int(self.skew_redivides as usize)),
            ("status", Json::str(self.status.label())),
            ("strategy", Json::str(self.strategy.label())),
        ]);
        match obj {
            Json::Obj(m) => m,
            _ => unreachable!("Json::obj builds an object"),
        }
    }

    /// The deterministic subset of the report as canonical JSON text:
    /// everything that must be byte-identical between a cold-built and a
    /// cache-served run of the same `(spec, seed)` cell.
    pub fn fingerprint(&self) -> String {
        Json::Obj(self.deterministic_fields()).dump()
    }

    /// The cell as a JSON object (fingerprint fields plus timings).
    pub fn to_json(&self) -> Json {
        let mut obj = self.deterministic_fields();
        obj.insert("seq_secs".into(), Json::num(self.seq_secs));
        obj.insert("par_secs".into(), Json::num(self.par_secs));
        obj.insert("divide_secs".into(), Json::num(self.divide_secs));
        obj.insert("scatter_secs".into(), Json::num(self.scatter_secs));
        obj.insert("sort_secs".into(), Json::num(self.sort_secs));
        obj.insert("gather_secs".into(), Json::num(self.gather_secs));
        obj.insert("speedup".into(), Json::num(self.speedup));
        obj.insert("speedup_pct".into(), Json::num(self.speedup_pct));
        obj.insert("efficiency".into(), Json::num(self.efficiency));
        obj.insert("repetitions".into(), Json::int(self.repetitions));
        if let Some(reason) = self.status.detail() {
            obj.insert("reason".into(), Json::str(reason));
        }
        Json::Obj(obj)
    }

    /// CSV header matching [`CellReport::csv_row`].
    pub const CSV_HEADER: &str = "dimension,construction,distribution,backend,elements,\
         fault_permille,shards,strategy,processors,status,seq_secs,par_secs,divide_secs,speedup,\
         speedup_pct,efficiency,imbalance,skew_redivides,recursions,iterations,swaps,\
         comparisons,des_completion_ns,des_elec_steps,des_opt_steps,detours";

    /// One CSV row per cell.
    pub fn csv_row(&self) -> String {
        let (des_ns, des_e, des_o) = match (self.des_completion_ns, self.des_steps) {
            (Some(ns), Some((e, o))) => (format!("{ns:.1}"), e.to_string(), o.to_string()),
            _ => (String::new(), String::new(), String::new()),
        };
        format!(
            "{},{},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.4},{:.2},{:.4},{:.3},{},{},{},{},{},{},{},{},{}",
            self.dimension,
            self.construction.label(),
            self.distribution.label(),
            self.backend.label(),
            self.elements,
            self.fault_permille,
            self.shards,
            self.strategy.label(),
            self.processors,
            self.status.label(),
            self.seq_secs,
            self.par_secs,
            self.divide_secs,
            self.speedup,
            self.speedup_pct,
            self.efficiency,
            self.imbalance,
            self.skew_redivides,
            self.counters.recursion_calls,
            self.counters.iterations,
            self.counters.swaps,
            self.counters.comparisons,
            des_ns,
            des_e,
            des_o,
            self.detours
        )
    }
}

/// Per-strategy aggregates for the robustness table.
#[derive(Debug, Clone)]
pub struct StrategySummary {
    /// The divide strategy.
    pub strategy: DivideStrategy,
    /// Speedup statistics over completed cells.
    pub speedup: Summary,
    /// Divide load-imbalance statistics over completed cells — the
    /// skew-guardrail witness (`max` is the bound the adversarial CI
    /// smoke asserts on).
    pub imbalance: Summary,
    /// Parallel wall-time statistics (s) over completed cells.
    pub par_secs: Summary,
    /// Total skew-guardrail re-divides across those cells.
    pub skew_redivides: u64,
}

/// The aggregated outcome of one campaign invocation.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// Spec echo (axes + knobs).
    pub spec: SweepSpec,
    /// Every grid cell, in expansion order.
    pub cells: Vec<CellReport>,
    /// Topology/plan builds the cache performed.
    pub topology_builds: usize,
    /// Cache hits served without building.
    pub cache_hits: usize,
    /// Sequential-baseline measurements performed (unique workloads).
    pub baseline_measures: usize,
    /// Baseline cache hits served without re-measuring.
    pub baseline_hits: usize,
    /// Wall time of the whole campaign (s).
    pub wall_secs: f64,
}

impl CampaignReport {
    /// Cells that completed.
    pub fn completed(&self) -> usize {
        self.cells.iter().filter(|c| c.status.is_completed()).count()
    }

    /// Cells skipped as infeasible.
    pub fn skipped(&self) -> usize {
        self.count(|s| matches!(s, CellStatus::Skipped(_)))
    }

    /// Cells that failed.
    pub fn failed(&self) -> usize {
        self.count(|s| matches!(s, CellStatus::Failed(_)))
    }

    fn count(&self, pred: impl Fn(&CellStatus) -> bool) -> usize {
        self.cells.iter().filter(|c| pred(&c.status)).count()
    }

    /// Speedup statistics of completed cells per dimension, sorted.
    pub fn per_dimension(&self) -> Vec<(u32, Summary)> {
        let mut dims: Vec<u32> = self.cells.iter().map(|c| c.dimension).collect();
        dims.sort_unstable();
        dims.dedup();
        dims.into_iter()
            .filter_map(|d| {
                let speedups: Vec<f64> = self
                    .cells
                    .iter()
                    .filter(|c| c.dimension == d && c.status.is_completed())
                    .map(|c| c.speedup)
                    .collect();
                if speedups.is_empty() {
                    None
                } else {
                    Some((d, Summary::of(&speedups)))
                }
            })
            .collect()
    }

    /// The speedup-degradation curve: speedup statistics of completed
    /// cells per injected fault rate, sorted by rate.  With a seeded
    /// nested fault generator the curve is structurally monotone —
    /// higher rates can only remove links, so detour costs (and the
    /// lost speedup) only grow.  One entry when the campaign ran
    /// healthy only.
    pub fn per_fault_rate(&self) -> Vec<(u32, Summary)> {
        let mut rates: Vec<u32> = self.cells.iter().map(|c| c.fault_permille).collect();
        rates.sort_unstable();
        rates.dedup();
        rates
            .into_iter()
            .filter_map(|rate| {
                let speedups: Vec<f64> = self
                    .cells
                    .iter()
                    .filter(|c| c.fault_permille == rate && c.status.is_completed())
                    .map(|c| c.speedup)
                    .collect();
                if speedups.is_empty() {
                    None
                } else {
                    Some((rate, Summary::of(&speedups)))
                }
            })
            .collect()
    }

    /// The shard-scaling table: speedup statistics of completed cells
    /// per shard count, sorted by count.  One entry when the campaign
    /// ran single-OHHC only; the multi-shard entries are the campaign's
    /// view of the cluster layer's scatter/merge path (per-shard spans
    /// sorted concurrently, merge traffic charged at optical prices).
    pub fn per_shard_count(&self) -> Vec<(usize, Summary)> {
        let mut counts: Vec<usize> = self.cells.iter().map(|c| c.shards).collect();
        counts.sort_unstable();
        counts.dedup();
        counts
            .into_iter()
            .filter_map(|shards| {
                let speedups: Vec<f64> = self
                    .cells
                    .iter()
                    .filter(|c| c.shards == shards && c.status.is_completed())
                    .map(|c| c.speedup)
                    .collect();
                if speedups.is_empty() {
                    None
                } else {
                    Some((shards, Summary::of(&speedups)))
                }
            })
            .collect()
    }

    /// The robustness table: speedup, divide imbalance, and parallel
    /// wall-time statistics of completed cells per divide strategy, in
    /// [`DivideStrategy::ALL`] order.  One entry when the campaign ran
    /// the paper's fixed divide only; strategies with no completed
    /// cells are omitted.
    pub fn per_strategy(&self) -> Vec<StrategySummary> {
        DivideStrategy::ALL
            .into_iter()
            .filter_map(|strategy| {
                let done: Vec<&CellReport> = self
                    .cells
                    .iter()
                    .filter(|c| c.strategy == strategy && c.status.is_completed())
                    .collect();
                if done.is_empty() {
                    return None;
                }
                let speedups: Vec<f64> = done.iter().map(|c| c.speedup).collect();
                let imbalances: Vec<f64> = done.iter().map(|c| c.imbalance).collect();
                let pars: Vec<f64> = done.iter().map(|c| c.par_secs).collect();
                Some(StrategySummary {
                    strategy,
                    speedup: Summary::of(&speedups),
                    imbalance: Summary::of(&imbalances),
                    par_secs: Summary::of(&pars),
                    skew_redivides: done.iter().map(|c| c.skew_redivides as u64).sum(),
                })
            })
            .collect()
    }

    /// Median wall time per pipeline stage across completed cells, as
    /// `(classify, scatter, local_sort, gather)` seconds — sourced from
    /// every cell's session [`StageTrace`](crate::pipeline::StageTrace).
    /// `classify` is the divide phase *minus* its scatter pass (the
    /// trace's `divide` component), so the four stages tile the
    /// pipeline without double counting — unlike each cell's
    /// `divide_secs`, which keeps the historical classify + scatter
    /// meaning.  `None` when no cell completed.
    pub fn stage_medians(&self) -> Option<(f64, f64, f64, f64)> {
        let completed: Vec<&CellReport> =
            self.cells.iter().filter(|c| c.status.is_completed()).collect();
        if completed.is_empty() {
            return None;
        }
        let med = |f: &dyn Fn(&CellReport) -> f64| {
            Summary::of(&completed.iter().map(|c| f(c)).collect::<Vec<f64>>()).median
        };
        Some((
            med(&|c| c.divide_secs - c.scatter_secs),
            med(&|c| c.scatter_secs),
            med(&|c| c.sort_secs),
            med(&|c| c.gather_secs),
        ))
    }

    /// Parallel wall times of completed cells as a latency histogram
    /// (ns) — the same [`Histogram`] the service layer reports SLOs
    /// from, so campaign and service latencies compare directly.
    pub fn parallel_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for cell in self.cells.iter().filter(|c| c.status.is_completed()) {
            h.record((cell.par_secs * 1e9) as u64);
        }
        h
    }

    /// The whole campaign as one JSON document.
    pub fn to_json(&self) -> Json {
        let per_dim = self.per_dimension().into_iter().map(|(d, s)| {
            Json::obj([
                ("dimension", Json::int(d as usize)),
                ("max_speedup", Json::num(s.max)),
                ("mean_speedup", Json::num(s.mean)),
                ("median_speedup", Json::num(s.median)),
                ("min_speedup", Json::num(s.min)),
            ])
        });
        let per_fault = self.per_fault_rate().into_iter().map(|(rate, s)| {
            Json::obj([
                ("fault_permille", Json::int(rate as usize)),
                ("max_speedup", Json::num(s.max)),
                ("mean_speedup", Json::num(s.mean)),
                ("median_speedup", Json::num(s.median)),
                ("min_speedup", Json::num(s.min)),
            ])
        });
        let per_shard = self.per_shard_count().into_iter().map(|(shards, s)| {
            Json::obj([
                ("max_speedup", Json::num(s.max)),
                ("mean_speedup", Json::num(s.mean)),
                ("median_speedup", Json::num(s.median)),
                ("min_speedup", Json::num(s.min)),
                ("shards", Json::int(shards)),
            ])
        });
        let per_strategy = self.per_strategy().into_iter().map(|s| {
            Json::obj([
                ("max_imbalance", Json::num(s.imbalance.max)),
                ("median_imbalance", Json::num(s.imbalance.median)),
                ("median_par_secs", Json::num(s.par_secs.median)),
                ("median_speedup", Json::num(s.speedup.median)),
                ("skew_redivides", Json::int(s.skew_redivides as usize)),
                ("strategy", Json::str(s.strategy.label())),
            ])
        });
        let lat = self.parallel_latency();
        let latency = Json::obj([
            ("count", Json::int(lat.count() as usize)),
            ("p50_ns", Json::num(lat.percentile(0.50) as f64)),
            ("p95_ns", Json::num(lat.percentile(0.95) as f64)),
            ("p99_ns", Json::num(lat.percentile(0.99) as f64)),
        ]);
        let stage_medians = match self.stage_medians() {
            Some((classify, scatter, sort, gather)) => Json::obj([
                ("classify_secs", Json::num(classify)),
                ("gather_secs", Json::num(gather)),
                ("local_sort_secs", Json::num(sort)),
                ("scatter_secs", Json::num(scatter)),
            ]),
            None => Json::Null,
        };
        Json::obj([
            ("cells", Json::arr(self.cells.iter().map(CellReport::to_json))),
            ("spec", self.spec.to_json()),
            (
                "summary",
                Json::obj([
                    ("baseline_hits", Json::int(self.baseline_hits)),
                    ("baseline_measures", Json::int(self.baseline_measures)),
                    ("cache_hits", Json::int(self.cache_hits)),
                    ("completed", Json::int(self.completed())),
                    ("failed", Json::int(self.failed())),
                    ("parallel_latency", latency),
                    ("per_dimension", Json::arr(per_dim)),
                    ("per_fault_rate", Json::arr(per_fault)),
                    ("per_shard_count", Json::arr(per_shard)),
                    ("per_strategy", Json::arr(per_strategy)),
                    ("planned", Json::int(self.cells.len())),
                    ("skipped", Json::int(self.skipped())),
                    ("stage_medians", stage_medians),
                    ("topology_builds", Json::int(self.topology_builds)),
                    ("wall_secs", Json::num(self.wall_secs)),
                ]),
            ),
        ])
    }

    /// Write the aggregated JSON report (pretty-printed).
    pub fn write_json(&self, path: &Path) -> Result<PathBuf> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut text = self.to_json().pretty();
        text.push('\n');
        std::fs::write(path, text)?;
        Ok(path.to_path_buf())
    }

    /// Write the per-cell CSV table.
    pub fn write_csv(&self, path: &Path) -> Result<PathBuf> {
        let rows: Vec<String> = self.cells.iter().map(CellReport::csv_row).collect();
        write_csv_rows(path, CellReport::CSV_HEADER, &rows)?;
        Ok(path.to_path_buf())
    }

    /// Human summary for the CLI.
    pub fn summary_text(&self) -> String {
        let mut out = format!(
            "campaign: {} cells ({} completed, {} skipped, {} failed) in {:.2}s\n\
             topology cache: {} builds, {} hits\n\
             baseline cache: {} measures, {} hits\n",
            self.cells.len(),
            self.completed(),
            self.skipped(),
            self.failed(),
            self.wall_secs,
            self.topology_builds,
            self.cache_hits,
            self.baseline_measures,
            self.baseline_hits
        );
        let lat = self.parallel_latency();
        if !lat.is_empty() {
            out.push_str(&format!(
                "parallel latency: p50 {:.3?} p95 {:.3?} p99 {:.3?} over {} cells\n",
                lat.percentile_duration(0.50),
                lat.percentile_duration(0.95),
                lat.percentile_duration(0.99),
                lat.count()
            ));
        }
        if let Some((classify, scatter, sort, gather)) = self.stage_medians() {
            out.push_str(&format!(
                "stage medians: classify {classify:.6}s scatter {scatter:.6}s \
                 sort {sort:.6}s gather {gather:.6}s\n"
            ));
        }
        for (d, s) in self.per_dimension() {
            out.push_str(&format!(
                "  d={d}: speedup median {:.3}x (min {:.3}, max {:.3}) over {} cells\n",
                s.median, s.min, s.max, s.n
            ));
        }
        let curve = self.per_fault_rate();
        if curve.len() > 1 {
            out.push_str("degradation curve (median speedup by injected fault rate):\n");
            for (rate, s) in curve {
                out.push_str(&format!(
                    "  rate {rate:>4}/1000: {:.3}x over {} cells\n",
                    s.median, s.n
                ));
            }
        }
        let scaling = self.per_shard_count();
        if scaling.len() > 1 {
            out.push_str("shard scaling (median speedup by shard count):\n");
            for (shards, s) in scaling {
                out.push_str(&format!(
                    "  x{shards}: {:.3}x over {} cells\n",
                    s.median, s.n
                ));
            }
        }
        let strategies = self.per_strategy();
        if strategies.len() > 1 {
            out.push_str("divide strategies (completed cells):\n");
            for s in strategies {
                out.push_str(&format!(
                    "  {:>8}: speedup {:.3}x, imbalance median {:.2}x max {:.2}x, \
                     {} re-divides over {} cells\n",
                    s.strategy.label(),
                    s.speedup.median,
                    s.imbalance.median,
                    s.imbalance.max,
                    s.skew_redivides,
                    s.speedup.n
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell() -> GridCell {
        GridCell {
            dimension: 1,
            construction: Construction::FullGroup,
            distribution: Distribution::Random,
            elements: 36_000,
            backend: Backend::DiscreteEvent,
            strategy: DivideStrategy::PaperFixed,
            fault_permille: 0,
            shards: 1,
        }
    }

    fn completed_report() -> CellReport {
        let mut r = CellReport::empty(&cell(), CellStatus::Completed);
        r.processors = 36;
        r.repetitions = 1;
        r.seq_secs = 0.2;
        r.par_secs = 0.1;
        r.divide_secs = 0.03;
        r.scatter_secs = 0.01;
        r.sort_secs = 0.06;
        r.gather_secs = 0.005;
        r.speedup = 2.0;
        r.speedup_pct = 50.0;
        r.efficiency = 2.0 / 36.0;
        r.imbalance = 1.1;
        r.counters.comparisons = 123;
        r.des_completion_ns = Some(5000.0);
        r.des_steps = Some((60, 10));
        r
    }

    #[test]
    fn fingerprint_excludes_wall_clock() {
        let a = completed_report();
        let mut b = completed_report();
        b.seq_secs = 9.9;
        b.par_secs = 4.4;
        b.speedup = 99.0;
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = completed_report();
        c.counters.comparisons += 1;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn cell_json_has_coordinates_and_timings() {
        let j = completed_report().to_json();
        assert_eq!(j.get("dimension").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("backend").unwrap().as_str(), Some("des"));
        assert_eq!(j.get("status").unwrap().as_str(), Some("completed"));
        assert!(j.get("seq_secs").unwrap().as_f64().unwrap() > 0.0);
        let steps = j.get("des_steps").unwrap().as_arr().unwrap();
        assert_eq!(steps[0].as_usize(), Some(60));
    }

    #[test]
    fn skipped_cells_carry_reasons() {
        let r = CellReport::skipped(&cell(), "too small".into());
        assert_eq!(r.status.label(), "skipped");
        assert_eq!(r.status.detail(), Some("too small"));
        let j = r.to_json();
        assert_eq!(j.get("reason").unwrap().as_str(), Some("too small"));
    }

    #[test]
    fn csv_row_matches_header_arity() {
        let header_cols = CellReport::CSV_HEADER.split(',').count();
        let completed = completed_report().csv_row();
        assert_eq!(completed.split(',').count(), header_cols);
        let skipped = CellReport::skipped(&cell(), "n/a".into()).csv_row();
        assert_eq!(skipped.split(',').count(), header_cols);
    }

    #[test]
    fn campaign_json_aggregates() {
        let report = CampaignReport {
            spec: SweepSpec::default(),
            cells: vec![
                completed_report(),
                CellReport::skipped(&cell(), "x".into()),
                CellReport::failed(&cell(), "y".into()),
            ],
            topology_builds: 1,
            cache_hits: 2,
            baseline_measures: 1,
            baseline_hits: 2,
            wall_secs: 1.5,
        };
        assert_eq!(report.completed(), 1);
        assert_eq!(report.skipped(), 1);
        assert_eq!(report.failed(), 1);
        let j = report.to_json();
        let summary = j.get("summary").unwrap();
        assert_eq!(summary.get("planned").unwrap().as_usize(), Some(3));
        assert_eq!(summary.get("topology_builds").unwrap().as_usize(), Some(1));
        assert_eq!(summary.get("baseline_measures").unwrap().as_usize(), Some(1));
        assert_eq!(summary.get("baseline_hits").unwrap().as_usize(), Some(2));
        assert!(report.summary_text().contains("baseline cache: 1 measures"));
        let lat = summary.get("parallel_latency").unwrap();
        assert_eq!(lat.get("count").unwrap().as_usize(), Some(1));
        assert!(lat.get("p99_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(report.summary_text().contains("parallel latency: p50"));
        // Per-stage medians ride alongside parallel_latency (only the
        // completed cell contributes).
        let stages = summary.get("stage_medians").unwrap();
        assert_eq!(stages.get("scatter_secs").unwrap().as_f64(), Some(0.01));
        assert_eq!(stages.get("local_sort_secs").unwrap().as_f64(), Some(0.06));
        assert_eq!(stages.get("gather_secs").unwrap().as_f64(), Some(0.005));
        // classify = divide phase minus its scatter pass.
        let classify = stages.get("classify_secs").unwrap().as_f64().unwrap();
        assert!((classify - 0.02).abs() < 1e-12, "{classify}");
        assert!(report.summary_text().contains("stage medians: classify"));
        let per_dim = summary.get("per_dimension").unwrap().as_arr().unwrap();
        assert_eq!(per_dim.len(), 1);
        assert_eq!(per_dim[0].get("dimension").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("cells").unwrap().as_arr().unwrap().len(), 3);
        assert!(report.summary_text().contains("1 completed"));
    }

    #[test]
    fn fault_axis_builds_the_degradation_curve() {
        let healthy = completed_report();
        let mut degraded = completed_report();
        degraded.fault_permille = 400;
        degraded.par_secs = 0.15;
        degraded.speedup = 0.2 / 0.15;
        degraded.detours = 7;
        assert_ne!(healthy.key(), degraded.key(), "fault rate is a grid coordinate");
        assert!(degraded.key().ends_with("/f400"));
        // The fault rate and detour count are deterministic fields.
        assert_ne!(healthy.fingerprint(), degraded.fingerprint());
        let j = degraded.to_json();
        assert_eq!(j.get("fault_permille").unwrap().as_usize(), Some(400));
        assert_eq!(j.get("detours").unwrap().as_usize(), Some(7));
        let report = CampaignReport {
            spec: SweepSpec::default(),
            cells: vec![healthy, degraded],
            topology_builds: 1,
            cache_hits: 0,
            baseline_measures: 1,
            baseline_hits: 0,
            wall_secs: 0.1,
        };
        let curve = report.per_fault_rate();
        assert_eq!(curve.len(), 2);
        assert_eq!((curve[0].0, curve[1].0), (0, 400), "sorted by rate");
        assert!(
            curve[0].1.median > curve[1].1.median,
            "speedup degrades with the fault rate"
        );
        let j = report.to_json();
        let per_fault = j
            .get("summary")
            .unwrap()
            .get("per_fault_rate")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(per_fault.len(), 2);
        assert_eq!(per_fault[1].get("fault_permille").unwrap().as_usize(), Some(400));
        assert!(report.summary_text().contains("degradation curve"));
    }

    #[test]
    fn shards_axis_builds_the_scaling_table() {
        let single = completed_report();
        let mut sharded = completed_report();
        sharded.shards = 4;
        sharded.par_secs = 0.03;
        sharded.speedup = 0.2 / 0.03;
        assert_ne!(single.key(), sharded.key(), "shard count is a grid coordinate");
        assert!(sharded.key().ends_with("/x4"));
        // The shard count is a deterministic field.
        assert_ne!(single.fingerprint(), sharded.fingerprint());
        let j = sharded.to_json();
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(4));
        let report = CampaignReport {
            spec: SweepSpec::default(),
            cells: vec![single, sharded],
            topology_builds: 1,
            cache_hits: 0,
            baseline_measures: 1,
            baseline_hits: 0,
            wall_secs: 0.1,
        };
        let scaling = report.per_shard_count();
        assert_eq!(scaling.len(), 2);
        assert_eq!((scaling[0].0, scaling[1].0), (1, 4), "sorted by shard count");
        assert!(
            scaling[1].1.median > scaling[0].1.median,
            "more shards, more speedup"
        );
        let j = report.to_json();
        let per_shard = j
            .get("summary")
            .unwrap()
            .get("per_shard_count")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(per_shard.len(), 2);
        assert_eq!(per_shard[1].get("shards").unwrap().as_usize(), Some(4));
        assert!(report.summary_text().contains("shard scaling"));
    }

    #[test]
    fn strategy_axis_builds_the_robustness_table() {
        // A paper-fixed cell collapsed by an attack vs. a sampling cell
        // that held the guardrail, plus an adaptive cell that paid one
        // re-divide: the per-strategy table must separate all three.
        let mut attacked = completed_report();
        attacked.imbalance = 30.0;
        attacked.speedup = 1.1;
        let mut sampled = completed_report();
        sampled.strategy = DivideStrategy::RegularSampling;
        sampled.imbalance = 1.3;
        let mut adaptive = completed_report();
        adaptive.strategy = DivideStrategy::Adaptive;
        adaptive.imbalance = 1.4;
        adaptive.skew_redivides = 1;
        assert_ne!(attacked.key(), sampled.key(), "strategy is a grid coordinate");
        assert!(sampled.key().ends_with("/sampling"));
        assert_ne!(attacked.fingerprint(), adaptive.fingerprint());
        let j = adaptive.to_json();
        assert_eq!(j.get("strategy").unwrap().as_str(), Some("adaptive"));
        assert_eq!(j.get("skew_redivides").unwrap().as_usize(), Some(1));
        let report = CampaignReport {
            spec: SweepSpec::default(),
            cells: vec![attacked, sampled, adaptive],
            topology_builds: 1,
            cache_hits: 0,
            baseline_measures: 1,
            baseline_hits: 0,
            wall_secs: 0.1,
        };
        let table = report.per_strategy();
        assert_eq!(table.len(), 3, "one row per strategy, in ALL order");
        assert_eq!(table[0].strategy, DivideStrategy::PaperFixed);
        assert_eq!(table[0].imbalance.max, 30.0);
        assert!(table[1].imbalance.max <= 2.0, "sampling held the guardrail");
        assert_eq!(table[2].skew_redivides, 1);
        let j = report.to_json();
        let per_strategy = j
            .get("summary")
            .unwrap()
            .get("per_strategy")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(per_strategy.len(), 3);
        assert_eq!(per_strategy[1].get("strategy").unwrap().as_str(), Some("sampling"));
        assert_eq!(per_strategy[0].get("max_imbalance").unwrap().as_f64(), Some(30.0));
        assert_eq!(per_strategy[2].get("skew_redivides").unwrap().as_usize(), Some(1));
        assert!(report.summary_text().contains("divide strategies"));
        assert!(report.summary_text().contains("sampling"));
    }

    #[test]
    fn report_files_round_trip() {
        let dir = std::env::temp_dir().join("ohhc_campaign_report");
        let report = CampaignReport {
            spec: SweepSpec::default(),
            cells: vec![completed_report()],
            topology_builds: 1,
            cache_hits: 0,
            baseline_measures: 1,
            baseline_hits: 0,
            wall_secs: 0.1,
        };
        let json_path = report.write_json(&dir.join("campaign.json")).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(json_path).unwrap()).unwrap();
        assert_eq!(
            parsed.get("summary").unwrap().get("completed").unwrap().as_usize(),
            Some(1)
        );
        let csv_path = report.write_csv(&dir.join("campaign.csv")).unwrap();
        let text = std::fs::read_to_string(csv_path).unwrap();
        assert!(text.starts_with("dimension,construction"));
        assert_eq!(text.lines().count(), 2);
    }
}
