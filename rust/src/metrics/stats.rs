//! Small summary statistics for repeated timing runs.

/// Summary of a sample of observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (lower of the middle pair for even n).
    pub median: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Population standard deviation.
    pub std_dev: f64,
}

impl Summary {
    /// Summarize a non-empty sample.
    pub fn of(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "empty sample");
        let n = values.len();
        let mean = values.iter().sum::<f64>() / n as f64;
        let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = values.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            median: sorted[(n - 1) / 2],
            min: sorted[0],
            max: sorted[n - 1],
            std_dev: var.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std_dev - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn singleton_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.std_dev, 0.0);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn empty_sample_panics() {
        Summary::of(&[]);
    }
}
