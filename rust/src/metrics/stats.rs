//! Small summary statistics for repeated timing runs, plus the
//! fixed-bucket latency [`Histogram`] the service layer and campaign
//! reports share.

use std::time::Duration;

/// Summary of a sample of observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (lower of the middle pair for even n).
    pub median: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Population standard deviation.
    pub std_dev: f64,
}

impl Summary {
    /// Summarize a non-empty sample.
    pub fn of(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "empty sample");
        let n = values.len();
        let mean = values.iter().sum::<f64>() / n as f64;
        let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = values.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            median: sorted[(n - 1) / 2],
            min: sorted[0],
            max: sorted[n - 1],
            std_dev: var.sqrt(),
        }
    }
}

/// Sub-bucket resolution: 2³ = 8 linear sub-buckets per power of two.
const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;
/// 8 exact buckets for values 0..8, then 8 sub-buckets for each of the
/// 61 remaining octaves of the `u64` range.
const NUM_BUCKETS: usize = SUB + 61 * SUB;

/// Fixed-bucket log-linear histogram for non-negative integer samples
/// (latencies in ns, sizes in keys, ...).
///
/// Values below 8 land in exact buckets; above that, each power-of-two
/// octave splits into 8 linear sub-buckets, so a bucket's width is at
/// most 1/8 of its lower bound and [`Histogram::percentile`] (which
/// reports bucket midpoints, clamped to the observed min/max) is within
/// ~6.25% of the exact order statistic.  The bucket count is fixed
/// (`496`), so merging histograms from many workers is a cheap
/// element-wise add and memory never depends on the sample count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; NUM_BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index of a value.
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            v as usize
        } else {
            let p = 63 - v.leading_zeros(); // floor(log2 v), ≥ 3
            let group = (p - SUB_BITS + 1) as usize;
            let sub = ((v >> (p - SUB_BITS)) & (SUB as u64 - 1)) as usize;
            group * SUB + sub
        }
    }

    /// Midpoint of bucket `i` (inverse of [`Histogram::index`]).
    fn midpoint(i: usize) -> u64 {
        if i < SUB {
            i as u64
        } else {
            let group = (i / SUB) as u32;
            let sub = (i % SUB) as u64;
            let width = 1u64 << (group - 1);
            (SUB as u64 + sub) * width + width / 2
        }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a duration as nanoseconds.
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.is_empty() {
            0
        } else {
            self.min
        }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Arithmetic mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// The `q`-quantile (`q` in `[0, 1]`), reported as the midpoint of
    /// the bucket holding the order statistic, clamped to the observed
    /// `[min, max]`.  Returns 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.is_empty() {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        // The extreme order statistics are tracked exactly.
        if rank == 1 {
            return self.min;
        }
        if rank == self.total {
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::midpoint(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// [`Histogram::percentile`] as a [`Duration`] (samples in ns).
    pub fn percentile_duration(&self, q: f64) -> Duration {
        Duration::from_nanos(self.percentile(q))
    }

    /// Absorb another histogram (element-wise bucket add).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std_dev - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn singleton_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.std_dev, 0.0);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn empty_sample_panics() {
        Summary::of(&[]);
    }

    /// Exact quantile from a sorted sample — the oracle the histogram is
    /// checked against.
    fn oracle(sorted: &[u64], q: f64) -> u64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    #[test]
    fn histogram_bucket_index_is_monotone_and_invertible() {
        // Indices never decrease with the value, and every bucket's
        // midpoint maps back into that bucket.
        let mut last = 0usize;
        for v in (0..4096u64).chain((12..60).map(|p| (1u64 << p) - 1)) {
            let i = Histogram::index(v);
            assert!(i >= last, "index regressed at {v}");
            last = i;
        }
        for i in 0..NUM_BUCKETS {
            assert_eq!(Histogram::index(Histogram::midpoint(i)), i, "bucket {i}");
        }
    }

    #[test]
    fn histogram_percentiles_track_sorted_vector_oracle() {
        let mut rng = crate::util::rng::Rng::new(0x1157);
        for scale in [100u64, 10_000, 50_000_000] {
            let mut h = Histogram::new();
            let mut values: Vec<u64> = (0..5_000).map(|_| rng.below(scale) + 1).collect();
            for &v in &values {
                h.record(v);
            }
            values.sort_unstable();
            for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let exact = oracle(&values, q);
                let approx = h.percentile(q);
                let tol = exact / 8 + 1;
                assert!(
                    approx.abs_diff(exact) <= tol,
                    "scale {scale} q {q}: approx {approx} vs exact {exact}"
                );
            }
            assert_eq!(h.percentile(1.0), *values.last().unwrap());
            assert_eq!(h.count(), 5_000);
            let mean = values.iter().sum::<u64>() as f64 / values.len() as f64;
            assert!((h.mean() - mean).abs() < 1e-6);
        }
    }

    #[test]
    fn histogram_merge_equals_single_recording() {
        let mut rng = crate::util::rng::Rng::new(9);
        let values: Vec<u64> = (0..2_000).map(|_| rng.below(1 << 30)).collect();
        let mut whole = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &v) in values.iter().enumerate() {
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
        assert_eq!(a.percentile(0.95), whole.percentile(0.95));
    }

    #[test]
    fn many_way_merge_percentiles_track_the_sorted_oracle() {
        // The cluster merges one histogram per shard; whatever the
        // shard count, percentiles of the merged histogram must stay
        // within bucket resolution of the exact order statistic over
        // the union of all shards' samples.
        let mut rng = crate::util::rng::Rng::new(0x5A4D);
        for shards in [2usize, 4, 8] {
            let mut parts: Vec<Histogram> = (0..shards).map(|_| Histogram::new()).collect();
            let mut values = Vec::new();
            for i in 0..4_000u64 {
                // Skewed per-shard ranges, so no single shard sees the
                // full distribution.
                let shard = (i as usize) % shards;
                let v = rng.below(10_000 * (shard as u64 + 1)) + 1;
                parts[shard].record(v);
                values.push(v);
            }
            let mut merged = Histogram::new();
            for p in &parts {
                merged.merge(p);
            }
            values.sort_unstable();
            assert_eq!(merged.count(), values.len() as u64);
            assert_eq!(merged.min(), values[0]);
            assert_eq!(merged.max(), *values.last().unwrap());
            for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
                let exact = oracle(&values, q);
                let approx = merged.percentile(q);
                let tol = exact / 8 + 1;
                assert!(
                    approx.abs_diff(exact) <= tol,
                    "{shards} shards q {q}: approx {approx} vs exact {exact}"
                );
            }
        }
    }

    #[test]
    fn merging_an_empty_histogram_preserves_min_and_max() {
        let mut h = Histogram::new();
        h.record(40);
        h.record(9_000);
        h.merge(&Histogram::new());
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 40);
        assert_eq!(h.max(), 9_000);
        // And the other direction: empty absorbing non-empty adopts
        // its extremes instead of keeping the empty sentinels.
        let mut e = Histogram::new();
        e.merge(&h);
        assert_eq!(e.min(), 40);
        assert_eq!(e.max(), 9_000);
        assert_eq!(e.percentile(1.0), 9_000);
    }

    #[test]
    fn histogram_small_values_are_exact() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 2, 3, 4, 5, 6, 7] {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 7);
        assert_eq!(h.percentile(0.5), 3);
        assert_eq!(h.percentile(1.0), 7);
    }

    #[test]
    fn histogram_empty_and_durations() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.mean(), 0.0);
        let mut h = Histogram::new();
        h.record_duration(Duration::from_micros(250));
        assert_eq!(h.count(), 1);
        let p = h.percentile_duration(0.5);
        assert_eq!(p, Duration::from_nanos(250_000));
    }
}
