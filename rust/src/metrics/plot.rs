//! ASCII line charts for figures — lets `ohhc-qsort figures --plot`
//! render every regenerated paper figure directly in the terminal, next
//! to the CSV.

use super::Figure;

/// Glyphs assigned to series, in order.
const GLYPHS: [char; 8] = ['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render a figure as an ASCII chart of `width × height` characters
/// (plus axes and legend).
pub fn render(fig: &Figure, width: usize, height: usize) -> String {
    let width = width.max(16);
    let height = height.max(6);
    let points: Vec<(f64, f64)> = fig
        .series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .collect();
    if points.is_empty() {
        return format!("{} (no data)\n", fig.id);
    }
    let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y_min, mut y_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &points {
        x_min = x_min.min(x);
        x_max = x_max.max(x);
        y_min = y_min.min(y);
        y_max = y_max.max(y);
    }
    if (x_max - x_min).abs() < f64::EPSILON {
        x_max = x_min + 1.0;
    }
    if (y_max - y_min).abs() < f64::EPSILON {
        y_max = y_min + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, series) in fig.series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        // Plot points and connect consecutive ones with interpolation.
        let mut prev: Option<(usize, usize)> = None;
        let mut pts = series.points.clone();
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        for &(x, y) in &pts {
            let cx = (((x - x_min) / (x_max - x_min)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y_min) / (y_max - y_min)) * (height - 1) as f64).round() as usize;
            let cy = height - 1 - cy; // row 0 is the top
            if let Some((px, py)) = prev {
                // Linear interpolation between chart cells.
                let steps = cx.abs_diff(px).max(cy.abs_diff(py)).max(1);
                for s in 0..=steps {
                    let ix = px as f64 + (cx as f64 - px as f64) * s as f64 / steps as f64;
                    let iy = py as f64 + (cy as f64 - py as f64) * s as f64 / steps as f64;
                    let cell = &mut grid[iy.round() as usize][ix.round() as usize];
                    if *cell == ' ' {
                        *cell = if s == 0 || s == steps { glyph } else { '.' };
                    }
                }
            }
            grid[cy][cx] = glyph;
            prev = Some((cx, cy));
        }
    }

    let mut out = String::new();
    out.push_str(&format!("{} — {}\n", fig.id, fig.title));
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{y_max:>10.2} |")
        } else if r == height - 1 {
            format!("{y_min:>10.2} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>11}+{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>12}{:<10.2}{:>width$.2}  ({})\n",
        "",
        x_min,
        x_max,
        fig.x_label,
        width = width - 10
    ));
    for (si, s) in fig.series.iter().enumerate() {
        out.push_str(&format!(
            "{:>12}{} = {}\n",
            "",
            GLYPHS[si % GLYPHS.len()],
            s.label
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Series;

    fn fig() -> Figure {
        Figure {
            id: "t".into(),
            title: "test".into(),
            x_label: "x".into(),
            y_label: "y".into(),
            series: vec![
                Series {
                    label: "up".into(),
                    points: vec![(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)],
                },
                Series {
                    label: "down".into(),
                    points: vec![(0.0, 4.0), (2.0, 0.0)],
                },
            ],
        }
    }

    #[test]
    fn renders_with_axes_and_legend() {
        let s = render(&fig(), 40, 10);
        assert!(s.contains("t — test"));
        assert!(s.contains("* = up"));
        assert!(s.contains("o = down"));
        assert!(s.contains('|'));
        assert!(s.lines().count() > 12);
    }

    #[test]
    fn handles_degenerate_figures() {
        let empty = Figure {
            id: "e".into(),
            title: "".into(),
            x_label: "".into(),
            y_label: "".into(),
            series: vec![],
        };
        assert!(render(&empty, 40, 10).contains("no data"));
        let flat = Figure {
            series: vec![Series {
                label: "c".into(),
                points: vec![(1.0, 5.0), (2.0, 5.0)],
            }],
            ..fig()
        };
        let s = render(&flat, 30, 8);
        assert!(s.contains('c') || s.contains('*'));
    }

    #[test]
    fn glyphs_appear_in_grid() {
        let s = render(&fig(), 40, 12);
        let body: String = s
            .lines()
            .filter(|l| l.contains('|'))
            .collect::<Vec<_>>()
            .join("\n");
        assert!(body.contains('*'));
        assert!(body.contains('o'));
    }
}
