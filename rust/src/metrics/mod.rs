//! Metrics: the paper's evaluation quantities (§4.3–§4.5, §6) plus basic
//! statistics and CSV emission for the figure harness.

pub mod plot;
mod stats;

pub use stats::{Histogram, Summary};

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// Relative speedup `T_s / T_p` (paper §6.2 definition).
pub fn speedup(ts_secs: f64, tp_secs: f64) -> f64 {
    ts_secs / tp_secs
}

/// The paper's *percentage improvement* presentation of speedup — its
/// figures report "up to 20%" meaning `(T_s − T_p)/T_s`.
pub fn speedup_pct(ts_secs: f64, tp_secs: f64) -> f64 {
    (ts_secs - tp_secs) / ts_secs * 100.0
}

/// Efficiency `E = T_s / (P · T_p)` (paper §4.4 / §6.3).
pub fn efficiency(ts_secs: f64, tp_secs: f64, processors: usize) -> f64 {
    ts_secs / (processors as f64 * tp_secs)
}

/// Write a CSV file: one header line plus pre-formatted rows.  Shared by
/// the campaign reports and any future tabular emitters; parent
/// directories are created as needed.
pub fn write_csv_rows(path: &Path, header: &str, rows: &[String]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

/// A labeled data series destined for one figure.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label (e.g. "d=3").
    pub label: String,
    /// `(x, y)` points.
    pub points: Vec<(f64, f64)>,
}

/// One regenerated figure: id, axis names, series.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Paper identifier ("fig_6_4", "table_1_1", ...).
    pub id: String,
    /// Human title.
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    /// The series.
    pub series: Vec<Series>,
}

impl Figure {
    /// Write the figure as CSV: header `x,<label1>,<label2>,...`, one row
    /// per x value (series are aligned on x).
    pub fn write_csv(&self, dir: &Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "# {} — {}", self.id, self.title)?;
        write!(f, "{}", self.x_label)?;
        for s in &self.series {
            write!(f, ",{}", s.label)?;
        }
        writeln!(f)?;
        // Collect the union of x values, sorted.
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.0))
            .collect();
        xs.sort_by(f64::total_cmp);
        xs.dedup();
        for x in xs {
            write!(f, "{x}")?;
            for s in &self.series {
                match s.points.iter().find(|p| p.0 == x) {
                    Some(&(_, y)) => write!(f, ",{y:.6}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(path)
    }

    /// Render as an aligned text table (what the CLI prints).
    pub fn to_text(&self) -> String {
        let mut out = format!("== {} — {}\n", self.id, self.title);
        out.push_str(&format!("{:>12}", self.x_label));
        for s in &self.series {
            out.push_str(&format!("{:>16}", s.label));
        }
        out.push('\n');
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.0))
            .collect();
        xs.sort_by(f64::total_cmp);
        xs.dedup();
        for x in xs {
            out.push_str(&format!("{x:>12.2}"));
            for s in &self.series {
                match s.points.iter().find(|p| p.0 == x) {
                    Some(&(_, y)) => out.push_str(&format!("{y:>16.4}")),
                    None => out.push_str(&format!("{:>16}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_and_efficiency_formulas() {
        // T_s = 10s, T_p = 5s on 4 processors.
        assert!((speedup(10.0, 5.0) - 2.0).abs() < 1e-12);
        assert!((speedup_pct(10.0, 5.0) - 50.0).abs() < 1e-12);
        assert!((efficiency(10.0, 5.0, 4) - 0.5).abs() < 1e-12);
        // Slower parallel run → negative percentage, as in the paper's
        // low-dimension cells.
        assert!(speedup_pct(10.0, 12.0) < 0.0);
    }

    #[test]
    fn csv_rows_helper_writes_header_and_rows() {
        let path = std::env::temp_dir().join("ohhc_csv_rows").join("t.csv");
        write_csv_rows(&path, "a,b", &["1,2".to_string(), "3,4".to_string()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
    }

    #[test]
    fn csv_round_trip() {
        let fig = Figure {
            id: "fig_test".into(),
            title: "t".into(),
            x_label: "mb".into(),
            y_label: "s".into(),
            series: vec![
                Series {
                    label: "d=1".into(),
                    points: vec![(10.0, 1.0), (20.0, 2.0)],
                },
                Series {
                    label: "d=2".into(),
                    points: vec![(10.0, 0.5)],
                },
            ],
        };
        let dir = std::env::temp_dir().join("ohhc_fig_test");
        let path = fig.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("mb,d=1,d=2"));
        assert!(text.contains("10,1.000000,0.500000"));
        assert!(text.contains("20,2.000000,"));
        let rendered = fig.to_text();
        assert!(rendered.contains("fig_test"));
    }
}
