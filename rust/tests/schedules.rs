//! Schedule-fuzz smoke suite — only compiled with `--features schedules`.
//!
//! Each test sweeps the same scenario across 64 seeds; the seed is
//! embedded in every assertion message, so a CI failure prints the
//! exact seed to replay locally:
//!
//! ```text
//! cargo test --features schedules --test schedules -- --nocapture
//! ```
//!
//! Replay is bit-identical at the decision level: the perturbation at
//! the k-th crossing of a site is a pure function of `(seed, site, k)`
//! (see `runtime::check::decision`), so re-running a failing seed
//! re-injects the same yields and spins at the same crossings.  The
//! slot-level cancel-vs-claim fuzz lives with the ticket unit tests
//! (the slot type is crate-private); this suite drives the public
//! surface: `util::par`, the executor, and the whole sort service.

#![cfg(feature = "schedules")]

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use ohhc_qsort::config::{Construction, Distribution, DivideStrategy};
use ohhc_qsort::runtime::check::{self, Decision};
use ohhc_qsort::runtime::Executor;
use ohhc_qsort::service::{JobSpec, ServiceConfig, SortService};
use ohhc_qsort::util::par::par_map;

const SEEDS: u64 = 64;

fn spec(id: u64) -> JobSpec {
    JobSpec {
        id,
        distribution: Distribution::Random,
        elements: 512,
        seed: 0x5EED + id,
        dimension: 1,
        construction: Construction::FullGroup,
        strategy: DivideStrategy::PaperFixed,
        deadline: None,
    }
}

/// The par_map claim loop under every seed: order preservation and
/// exactly-once slot handoff must survive arbitrary yield/spin
/// placement around the index claim and the slot write.
#[test]
fn par_map_survives_64_fuzzed_schedules() {
    for seed in 0..SEEDS {
        let crossings = check::fuzz(seed, || {
            let v: Vec<usize> = (0..500).collect();
            let out = par_map(v, 8, |x| x * 3);
            let expect: Vec<usize> = (0..500).map(|x| x * 3).collect();
            assert_eq!(out, expect, "par_map broke under schedule seed {seed}");
            check::crossings()
        });
        assert!(crossings > 0, "seed {seed}: no interleave point crossed — harness inert?");
    }
}

/// Executor park/unpark epochs under fuzzing: a burst of tiny scopes
/// forces workers through the scan-then-park window while the seeds
/// shift where the yields land.  Every submitted task must still run
/// exactly once and every scope must return.
#[test]
fn executor_scopes_complete_under_every_seed() {
    for seed in 0..SEEDS {
        check::fuzz(seed, || {
            let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
            Executor::global().scope(|s| {
                for h in &hits {
                    s.submit(move || {
                        h.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            for (i, h) in hits.iter().enumerate() {
                let n = h.load(Ordering::Relaxed);
                assert_eq!(n, 1, "seed {seed}: task {i} ran {n} times");
            }
        });
    }
}

/// The cancel-vs-claim race through the whole service under fuzzing:
/// submit a burst, cancel every ticket immediately, and check the
/// accounting closes — a won cancel never yields a result, a lost one
/// yields exactly one, and nothing is double-delivered or lost.
#[test]
fn service_cancel_storm_accounting_closes_under_every_seed() {
    for seed in 0..SEEDS {
        check::fuzz(seed, || {
            let service = SortService::start(ServiceConfig {
                workers: 2,
                ..Default::default()
            });
            let mut tickets = Vec::new();
            for id in 0..4u64 {
                let sub = service.submit(spec(seed * 100 + id));
                tickets.push(sub.ticket().unwrap_or_else(|| panic!("seed {seed}: job rejected")));
            }
            let cancelled: HashSet<u64> =
                tickets.iter().filter(|t| t.try_cancel()).map(|t| t.id()).collect();
            let mut delivered = HashSet::new();
            while delivered.len() + cancelled.len() < tickets.len() {
                let r = service
                    .next_completion(Duration::from_secs(60))
                    .unwrap_or_else(|| panic!("seed {seed}: completion lost"));
                assert!(
                    !cancelled.contains(&r.id),
                    "seed {seed}: cancelled job {} produced a result",
                    r.id
                );
                assert!(delivered.insert(r.id), "seed {seed}: job {} delivered twice", r.id);
            }
            let (_, leftovers) = service.shutdown();
            assert!(leftovers.is_empty(), "seed {seed}: {} results stranded", leftovers.len());
        });
    }
}

/// The printed-seed replay contract, end to end: recompute the full
/// decision stream a failing test would print and check it is stable
/// across recomputations and distinct across seeds.
#[test]
fn failing_seed_replays_bit_identically() {
    let sites = ["par/claim", "executor/park-announce", "ticket/cancel"];
    for seed in [0u64, 13, 63] {
        for site in sites {
            let first: Vec<Decision> = (0..128).map(|k| check::decision(seed, site, k)).collect();
            let second: Vec<Decision> = (0..128).map(|k| check::decision(seed, site, k)).collect();
            assert_eq!(first, second, "seed {seed} site {site}: replay diverged");
        }
        let other: Vec<Decision> =
            (0..128).map(|k| check::decision(seed ^ 1, "par/claim", k)).collect();
        let this: Vec<Decision> = (0..128).map(|k| check::decision(seed, "par/claim", k)).collect();
        assert_ne!(this, other, "adjacent seeds {seed}/{} collided", seed ^ 1);
    }
}
