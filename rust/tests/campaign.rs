//! Campaign-engine integration tests: the acceptance grid end to end —
//! exhaustive deduplicated expansion, at-most-once topology builds,
//! cache-served runs byte-identical to cold ones, and DES determinism.

use std::collections::HashSet;
use std::sync::Arc;

use ohhc_qsort::analysis::theorems;
use ohhc_qsort::campaign::{Campaign, CellReport, GridCell, PlanCache, SweepSpec};
use ohhc_qsort::config::{Backend, Construction, Distribution};
use ohhc_qsort::coordinator::OhhcSorter;
use ohhc_qsort::schedule::TopologyBundle;
use ohhc_qsort::util::json::Json;
use ohhc_qsort::workload::Workload;

/// The acceptance-criteria grid shape (dims × constructions × dists ×
/// sizes × backends) at test-friendly sizes.
fn acceptance_spec() -> SweepSpec {
    SweepSpec {
        dimensions: vec![1, 2],
        constructions: Construction::ALL.to_vec(),
        distributions: vec![
            Distribution::Random,
            Distribution::Sorted,
            Distribution::ReverseSorted,
        ],
        sizes: vec![8_192, 16_384],
        backends: vec![Backend::Threaded, Backend::DiscreteEvent],
        workers: 4,
        jobs: 4,
        ..Default::default()
    }
}

#[test]
fn acceptance_grid_covers_every_cell_with_at_most_one_build_per_topology() {
    let spec = acceptance_spec();
    let expected_cells = spec.expand().unwrap();
    assert_eq!(expected_cells.len(), 2 * 2 * 3 * 2 * 2);

    let campaign = Campaign::new(spec);
    let report = campaign.run().unwrap();

    // Every expanded cell appears in the report, completed.
    assert_eq!(report.cells.len(), expected_cells.len());
    assert_eq!(report.completed(), expected_cells.len());
    let reported: HashSet<GridCell> = report
        .cells
        .iter()
        .map(|c| GridCell {
            dimension: c.dimension,
            construction: c.construction,
            distribution: c.distribution,
            elements: c.elements,
            backend: c.backend,
        })
        .collect();
    for cell in &expected_cells {
        assert!(reported.contains(cell), "missing {}", cell.label());
    }

    // Each (dimension, construction) topology/plan was built at most once.
    let counts = campaign.cache().build_counts();
    assert_eq!(counts.len(), 4, "4 unique (dimension, construction) pairs");
    for (key, count) in counts {
        assert!(count <= 1, "{key:?} built {count} times");
    }
    assert_eq!(report.topology_builds, 4);
    assert_eq!(report.cache_hits, report.cells.len() - 4);

    // Each (distribution, elements, seed) workload was generated and
    // baseline-measured at most once: 3 dists × 2 sizes = 6 workloads.
    assert_eq!(report.baseline_measures, 6);
    assert_eq!(report.baseline_hits, report.cells.len() - 6);
    assert_eq!(campaign.baselines().measures(), 6);

    // One aggregated JSON document covers the whole grid.
    let json = report.to_json();
    let cells = json.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), expected_cells.len());
    let summary = json.get("summary").unwrap();
    assert_eq!(
        summary.get("planned").unwrap().as_usize(),
        Some(expected_cells.len())
    );
    assert_eq!(
        summary.get("completed").unwrap().as_usize(),
        Some(expected_cells.len())
    );
    // The document round-trips through the parser.
    assert_eq!(Json::parse(&json.pretty()).unwrap(), json);
}

#[test]
fn grid_expansion_is_exhaustive_and_deduplicated() {
    let mut spec = acceptance_spec();
    // Inject duplicates on every axis; expansion must not grow.
    spec.dimensions = vec![1, 2, 2, 1];
    spec.distributions.push(Distribution::Random);
    spec.sizes = vec![8_192, 16_384, 8_192];
    spec.backends = vec![Backend::Threaded, Backend::DiscreteEvent, Backend::Threaded];
    let cells = spec.expand().unwrap();
    assert_eq!(cells.len(), 2 * 2 * 3 * 2 * 2);
    let unique: HashSet<GridCell> = cells.iter().copied().collect();
    assert_eq!(unique.len(), cells.len(), "expansion emitted duplicates");
}

#[test]
fn cached_plans_reproduce_cold_built_reports_byte_identically() {
    let spec = SweepSpec {
        dimensions: vec![1],
        constructions: vec![Construction::FullGroup],
        distributions: vec![Distribution::Random],
        sizes: vec![10_000],
        backends: vec![Backend::DiscreteEvent],
        workers: 4,
        ..Default::default()
    };
    let cell = spec.expand().unwrap()[0];
    let cfg = cell.config(&spec);

    // Cold: private bundle built inside the sorter.
    let cold_runs = [OhhcSorter::new(&cfg).unwrap().run().unwrap()];
    let cold = CellReport::from_runs(&cell, &cold_runs);

    // Cached: bundle served by a shared PlanCache, twice over.
    let cache = PlanCache::new();
    for _ in 0..2 {
        let bundle = cache.get_or_build(cell.dimension, cell.construction).unwrap();
        let sorter = OhhcSorter::with_bundle(&cfg, bundle).unwrap();
        let runs = [sorter.run().unwrap()];
        let cached = CellReport::from_runs(&cell, &runs);
        assert_eq!(
            cold.fingerprint(),
            cached.fingerprint(),
            "cached plans changed the deterministic report"
        );
    }
    assert_eq!(cache.builds(), 1);
    assert_eq!(cache.hits(), 1);

    // Injecting an equivalent hand-built bundle is also byte-identical.
    let bundle = TopologyBundle::build(1, Construction::FullGroup).unwrap();
    let sorter = OhhcSorter::with_bundle(&cfg, Arc::new(bundle)).unwrap();
    let runs = [sorter.run().unwrap()];
    let injected = CellReport::from_runs(&cell, &runs);
    assert_eq!(cold.fingerprint(), injected.fingerprint());
}

#[test]
fn des_campaign_is_deterministic_for_a_fixed_spec_and_seed() {
    let spec = SweepSpec {
        dimensions: vec![1, 2],
        constructions: Construction::ALL.to_vec(),
        distributions: vec![Distribution::Random, Distribution::ReverseSorted],
        sizes: vec![12_000],
        backends: vec![Backend::DiscreteEvent],
        seed: 0xD5,
        workers: 4,
        jobs: 3,
        ..Default::default()
    };
    let a = Campaign::new(spec.clone()).run().unwrap();
    let b = Campaign::new(spec).run().unwrap();
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.key(), y.key());
        // Golden determinism: virtual time and step counts reproduce
        // exactly; the step counts also match the closed form
        // 2·(G·P − 1) from Theorem 3's exact tree count.
        assert_eq!(x.des_completion_ns, y.des_completion_ns, "{}", x.key());
        assert_eq!(x.des_steps, y.des_steps, "{}", x.key());
        assert_eq!(x.counters, y.counters, "{}", x.key());
        assert_eq!(x.fingerprint(), y.fingerprint(), "{}", x.key());
        let (e, o) = x.des_steps.unwrap();
        let groups = x.construction.groups(6 << (x.dimension - 1));
        let procs = 6 << (x.dimension - 1);
        let exact = theorems::exact_tree_steps(groups, procs);
        assert_eq!(e + o, exact, "{}", x.key());
    }
}

#[test]
fn different_seeds_change_the_workload_dependent_outcome() {
    let base = SweepSpec {
        dimensions: vec![1],
        constructions: vec![Construction::FullGroup],
        distributions: vec![Distribution::Random],
        sizes: vec![12_000],
        backends: vec![Backend::DiscreteEvent],
        workers: 4,
        ..Default::default()
    };
    let mut other = base.clone();
    other.seed ^= 1;
    let a = Campaign::new(base).run().unwrap();
    let b = Campaign::new(other).run().unwrap();
    assert_ne!(
        a.cells[0].counters, b.cells[0].counters,
        "seed must reach the workload"
    );
}

#[test]
fn campaign_workload_matches_direct_generation() {
    // The campaign runs the same seeded workloads a hand-rolled loop
    // would — no hidden reseeding inside the engine.
    let spec = SweepSpec {
        dimensions: vec![1],
        constructions: vec![Construction::FullGroup],
        distributions: vec![Distribution::Local],
        sizes: vec![9_000],
        backends: vec![Backend::Threaded],
        workers: 4,
        ..Default::default()
    };
    let report = Campaign::new(spec.clone()).run().unwrap();
    let cell = spec.expand().unwrap()[0];
    let sorter = OhhcSorter::new(&cell.config(&spec)).unwrap();
    let direct = sorter
        .run_on(&Workload::new(cell.distribution, cell.elements, spec.seed))
        .unwrap();
    assert_eq!(report.cells[0].counters, direct.counters);
}
